"""H2O-Danube3-4B — llama+mistral mix with SWA [arXiv:2401.16818].

Assignment row: [dense] 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000, sliding-window attention (mistral-style, window 4096) —
long_500k eligible.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    vocab_size=32000,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    mlp_act="swiglu",
    window=4096,
    source="arXiv:2401.16818 (H2O-Danube series)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-smoke", family="dense", num_layers=2,
        d_model=256, vocab_size=2048, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, mlp_act="swiglu", window=64,
        source=CONFIG.source)
