import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, jax
from repro import configs
from repro.launch import mesh as mesh_lib, specs, hlo_cost
from repro.sharding import context as shctx, policy as policy_lib
cfg = configs.get_config("yi-6b")
shape = configs.INPUT_SHAPES["decode_32k"]
mesh = mesh_lib.make_production_mesh()
policy = policy_lib.make_policy(mesh, fsdp=False); policy.serving = True
step = specs.make_step_fn(cfg, shape)
args, _ = specs.input_specs(cfg, shape)
in_sh, out_sh, donate = specs.step_shardings(cfg, shape, policy)
with mesh, shctx.use_policy(policy):
    compiled = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate).lower(*args).compile()
comps, entry = hlo_cost.parse_module(compiled.as_text())
# multipliers
m = {name: 0.0 for name in comps}; m[entry]=1.0
for _ in range(len(comps)+2):
    new = {name: 0.0 for name in comps}; new[entry]=1.0
    for cname, comp in comps.items():
        if m[cname]==0: continue
        for on in comp.order:
            for callee, mm in hlo_cost._callees(comp.ops[on].line):
                if callee in new: new[callee]+=m[cname]*mm
    m = new
rows=[]
for cname, comp in comps.items():
    if m[cname]==0 or cname.startswith("fused_") or "fused_computation" in cname: continue
    for on in comp.order:
        op = comp.ops[on]
        if 'op_name=' in op.line or op.kind not in hlo_cost._TRAFFIC_OPS: continue
        b = hlo_cost._shape_bytes(op.result_shapes) * m[cname]
        if b > 2**26:
            rows.append((b, m[cname], cname[:24], op.kind, op.line.strip()[:130]))
rows.sort(reverse=True)
for b, w, cname, kind, line in rows[:12]:
    print(f"{b/2**30:7.2f} GiB x{w:5.0f} {cname:24s} {kind:9s} {line[:105]}")
