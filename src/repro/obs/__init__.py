"""Observability substrate shared by the engine and the simulator.

``repro.obs`` is the telemetry layer under the serving stack's
bit-parity twin discipline: the real engine
(``ServingEngine(obs=...)``) and the simulator
(``simulate_continuous(obs=...)``) drive the SAME recorder and the
SAME metrics registry from the same decision points, so

  * the lifecycle EVENT stream (``obs.trace``) compares equal between
    engine and simulator up to wall-clock fields, and
  * every COUNTER both sides emit compares bit-for-bit,

exactly like the dispatch/budget traces in ``_result``/``SimResult``.
Recording is OFF by default (``obs=None`` everywhere): the serve loops
only touch the recorder behind ``if obs is not None`` guards, and the
no-obs serve path is bit-identical to the pre-obs engine
(tests/test_obs.py::test_obs_none_results_unchanged).

Three pieces:

  * ``obs.trace``   — typed per-request lifecycle events + engine
    spans, JSONL sink, Chrome/Perfetto ``trace_event`` exporter;
  * ``obs.metrics`` — counters, gauges, log-bucketed streaming
    histograms with mergeable state and deterministic quantiles (the
    percentile substrate of ``_result``/``SimResult``);
  * ``obs.log``     — rate-limited warnings with countable fallback
    events (``fallback_events`` in serve results).

``Observability`` bundles one recorder + one registry per run; build
one with ``Observability()`` and pass it to ``ServingEngine(obs=...)``
/ ``simulate_continuous(obs=...)``, then export with
``obs.trace.to_jsonl(path)`` and inspect with
``scripts/trace_report.py`` (waterfall + percentile table) or
``ui.perfetto.dev`` (via ``obs.trace.export_perfetto``).
"""

from __future__ import annotations

import time
from typing import Optional

from .log import FALLBACKS, RateLimitedLogger, fallback_count, warn_once
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      percentiles)
from .trace import (EVENT_KINDS, WALL_FIELDS, Event, RequestTimeline,
                    Span, TraceRecorder, timelines)

__all__ = [
    "Counter", "Event", "EVENT_KINDS", "FALLBACKS", "Gauge",
    "Histogram", "MetricsRegistry", "Observability", "RateLimitedLogger",
    "RequestTimeline", "Span", "TraceRecorder", "WALL_FIELDS",
    "fallback_count", "percentiles", "timelines", "warn_once",
]


class Observability:
    """One serve/simulation run's telemetry bundle.

    ``trace`` and ``metrics`` may individually be disabled (``None``);
    the convenience emitters no-op for a disabled piece, so call sites
    need only the single outer ``if obs is not None`` guard.

    ``overhead_s`` accumulates the wall-clock the ENGINE measured
    around its per-iteration emission blocks (``measure()``) — the
    measured-overhead guard: recording never touches the engine's
    virtual clock (events are emitted outside the timed device
    regions), and the measured wall cost is reported alongside the
    results so regressions are visible, not guessed.
    """

    def __init__(self, *, trace: bool = True, metrics: bool = True,
                 max_events: int = 1_000_000):
        self.trace: Optional[TraceRecorder] = \
            TraceRecorder(max_events) if trace else None
        self.metrics: Optional[MetricsRegistry] = \
            MetricsRegistry() if metrics else None
        self.overhead_s = 0.0

    # ------------------------------------------------------------------
    # no-op-safe emitters — each self-times into ``overhead_s``
    # ------------------------------------------------------------------
    def event(self, kind: str, ts: float, task_id=None, step=None,
              **fields) -> None:
        if self.trace is not None:
            t0 = time.perf_counter()
            self.trace.event(kind, ts, task_id, step, **fields)
            self.overhead_s += time.perf_counter() - t0

    def span(self, name: str, ts: float, dur: float,
             track: str = "engine", **fields) -> None:
        if self.trace is not None:
            t0 = time.perf_counter()
            self.trace.span(name, ts, dur, track, **fields)
            self.overhead_s += time.perf_counter() - t0

    def counter_sample(self, name: str, ts: float, value: float) -> None:
        if self.trace is not None:
            t0 = time.perf_counter()
            self.trace.counter(name, ts, value)
            self.overhead_s += time.perf_counter() - t0

    def inc(self, name: str, n: int = 1) -> None:
        if self.metrics is not None:
            t0 = time.perf_counter()
            self.metrics.counter(name).inc(n)
            self.overhead_s += time.perf_counter() - t0

    def gauge(self, name: str, value: float) -> None:
        if self.metrics is not None:
            t0 = time.perf_counter()
            self.metrics.gauge(name).set(value)
            self.overhead_s += time.perf_counter() - t0

    def observe(self, name: str, value: float, n: int = 1) -> None:
        if self.metrics is not None:
            t0 = time.perf_counter()
            self.metrics.histogram(name).record(value, n)
            self.overhead_s += time.perf_counter() - t0

    # ------------------------------------------------------------------
    def measure(self):
        """Context manager accumulating wall time into ``overhead_s``."""
        return _Measure(self)

    def event_count(self) -> int:
        return len(self.trace.events) if self.trace is not None else 0


class _Measure:
    __slots__ = ("obs", "t0")

    def __init__(self, obs: Observability):
        self.obs = obs

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.obs.overhead_s += time.perf_counter() - self.t0
        return False
