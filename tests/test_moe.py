"""MoE: dispatch/combine correctness vs dense oracle, EP vs local path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers, moe


def dense_moe_oracle(params, x, cfg):
    """Every token through its top-k experts, no capacity limit."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(-1, D)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for e in range(E):
        h = jax.nn.silu(xt @ params["w_gate"][e]) * (xt @ params["w_up"][e])
        y = h @ params["w_down"][e]
        w = jnp.where(top_e == e, top_p, 0.0).sum(-1)
        out = out + y * w[:, None].astype(out.dtype)
    if cfg.num_shared_experts:
        out = out + layers.apply_mlp(params["shared"], xt, "swiglu")
    return out.reshape(B, S, D)


@pytest.fixture
def moe_cfg():
    return configs.get_smoke_config("mixtral-8x22b")


def test_moe_matches_dense_oracle_ample_capacity(moe_cfg):
    cfg = moe_cfg.__class__(**{**moe_cfg.__dict__, "capacity_factor": 8.0})
    key = jax.random.PRNGKey(0)
    params = moe.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = moe.apply_moe_local(params, x, cfg)
    want = dense_moe_oracle(params, x, cfg)
    np.testing.assert_allclose(out, want, atol=2e-4, rtol=2e-4)
    assert float(aux["moe_drop_frac"]) == 0.0


def test_moe_capacity_drops_tokens(moe_cfg):
    cfg = moe_cfg.__class__(**{**moe_cfg.__dict__, "capacity_factor": 0.1})
    key = jax.random.PRNGKey(0)
    params = moe.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))
    _, aux = moe.apply_moe_local(params, x, cfg)
    assert float(aux["moe_drop_frac"]) > 0.0


def test_moe_aux_loss_uniform_router_is_one():
    """Load-balance loss == 1 for a perfectly uniform router."""
    cfg = configs.get_smoke_config("mixtral-8x22b")
    key = jax.random.PRNGKey(0)
    params = moe.init_moe(key, cfg, jnp.float32)
    params = dict(params, router=jnp.zeros_like(params["router"]))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    _, aux = moe.apply_moe_local(params, x, cfg)
    # aux = w_lb * load_balance + w_z * z_loss; uniform router gives
    # load_balance == 1 exactly and z_loss == log(E)^2
    import numpy as np
    z = float(np.log(cfg.num_experts)) ** 2
    lb = (float(aux["moe_aux_loss"]) - cfg.router_z_weight * z) \
        / cfg.router_aux_weight
    assert abs(lb - 1.0) < 0.05
