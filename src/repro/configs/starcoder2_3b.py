"""StarCoder2-3B — code LM, GQA + RoPE [arXiv:2402.19173].

Assignment row: [dense] 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152.  Full attention per the assignment row (no SWA listed), so
long_500k is skipped for this arch (see DESIGN.md §4).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    vocab_size=49152,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    mlp_act="gelu",
    rope_theta=100_000.0,
    source="arXiv:2402.19173 (StarCoder 2 and The Stack v2)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke", family="dense", num_layers=2,
        d_model=256, vocab_size=2048, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, mlp_act="gelu", source=CONFIG.source)
