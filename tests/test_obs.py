"""Observability substrate coverage (ISSUE 7).

Acceptance properties:

  * histogram accuracy — ``obs.metrics.Histogram`` quantiles stay
    within one log-bucket's relative width (sqrt(growth) - 1) of the
    exact order statistic, and merging shards is associative;
  * trace schema — the JSONL sink round-trips losslessly, the Perfetto
    export is valid Chrome ``trace_event`` JSON, and the checked-in
    mini trace renders through ``scripts/trace_report.py``;
  * engine-vs-sim event parity — a traced serve and a traced
    simulation of the same workload produce EQUAL event streams up to
    wall-clock fields, and bit-identical counters, at
    ``decode_steps in {1, 4}`` for stall and chunked prefill;
  * off-by-default — ``obs=None`` serves report the same deterministic
    results as traced serves (recording never alters scheduling), and
    the measured recording overhead is reported, not guessed;
  * trace-derived latencies — per-request timelines reconstructed from
    a traced chunked serve reproduce the result dict's TTFT/ITL
    percentiles within histogram tolerance;
  * rate-limited logging — warnings are counted on every occurrence
    but emitted at most once per interval, and ``reset`` re-arms.
"""

import dataclasses
import json
import logging
import os
import sys

import numpy as np
import pytest

import jax

from repro import configs
from repro.core import datagen, personas, priority as prio
from repro.core import scheduler as sched, simulator
from repro.obs import (EVENT_KINDS, Observability, RateLimitedLogger,
                       TraceRecorder, timelines)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               percentiles)
from repro.serving.engine import Request, ServingEngine

SCRIPTS = os.path.join(os.path.dirname(__file__), "..", "scripts")
MINI_TRACE = os.path.join(os.path.dirname(__file__), "data",
                          "mini_trace.jsonl")

SLOTS = 3
MAX_NEW = 6
BUCKET = 8
BS = 4
CAPS = [2, 6, 1, 4, 6, 2, 3, 5, 1, 6, 2, 4]


# ---------------------------------------------------------------------------
# metrics: counters, gauges, histograms
# ---------------------------------------------------------------------------


def test_counter_and_gauge():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = Gauge()
    for v in (2.0, 8.0, 4.0):
        g.set(v)
    assert g.value == 4.0 and g.max == 8.0
    assert g.snapshot() == {"last": 4.0, "max": 8.0,
                            "mean": pytest.approx(14.0 / 3)}


@pytest.mark.parametrize("dist", ["lognormal", "uniform", "heavy"])
def test_histogram_quantile_accuracy(dist):
    """Every quantile stays within one bucket's relative width of the
    exact order statistic at the same rank rule."""
    rng = np.random.default_rng(0)
    vals = {
        "lognormal": rng.lognormal(0.0, 2.0, size=5000),
        "uniform": rng.uniform(1e-6, 10.0, size=5000),
        "heavy": rng.pareto(1.5, size=5000) + 1e-3,
    }[dist]
    h = Histogram()
    h.record_many(vals)
    tol = np.sqrt(h.growth) - 1.0            # bucket half-width bound
    sv = np.sort(vals)
    for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
        exact = sv[int(np.ceil(q * (len(sv) - 1)))]
        est = h.quantile(q)
        assert abs(est - exact) <= tol * exact + 1e-12, (q, est, exact)


def test_histogram_edge_cases():
    h = Histogram()
    assert h.quantile(0.5) == 0.0            # empty
    h.record(0.0)
    h.record(-1.0)                           # zero bucket
    assert h.quantile(0.5) == 0.0
    h.record(5.0, 3)                         # weighted record
    assert h.count == 5
    tol = np.sqrt(h.growth) - 1.0
    assert h.quantile(1.0) == pytest.approx(5.0, rel=tol)
    assert h.quantile(1.0) <= h.max          # clamped to observed max
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        Histogram(growth=1.0)


def test_histogram_merge_associative():
    rng = np.random.default_rng(1)
    shards = [rng.lognormal(0.0, 1.0, size=500) for _ in range(3)]
    hs = []
    for vals in shards:
        h = Histogram()
        h.record_many(vals)
        hs.append(h)

    def fresh(i):
        h = Histogram()
        h.record_many(shards[i])
        return h

    left = fresh(0).merge(fresh(1)).merge(fresh(2))
    right = fresh(0).merge(fresh(1).merge(fresh(2)))
    assert left.buckets == right.buckets
    assert left.count == right.count == 1500
    assert left.min == right.min and left.max == right.max
    for q in (0.5, 0.9, 0.99):
        assert left.quantile(q) == right.quantile(q)
    # merged == recorded-in-one
    pooled = Histogram()
    pooled.record_many(np.concatenate(shards))
    assert pooled.buckets == left.buckets
    with pytest.raises(ValueError):
        left.merge(Histogram(growth=1.5))


def test_registry_parity_view_and_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    for r in (a, b):
        r.counter("sched.admissions").inc(3)
        r.gauge("kv.util").set(0.5)
        r.histogram("ttft").record(1.0)
    assert a.counters() == b.counters() == {"sched.admissions": 3}
    a.merge(b)
    assert a.counters() == {"sched.admissions": 6}
    assert a.histogram("ttft").count == 2
    snap = a.snapshot()
    assert snap["sched.admissions"]["type"] == "counter"
    assert snap["ttft"]["type"] == "histogram"
    h = percentiles([1.0, 2.0, 3.0], a, "extra")
    assert a.histogram("extra") is h and h.count == 3


# ---------------------------------------------------------------------------
# trace: recorder, round-trip, Perfetto export, budget guard
# ---------------------------------------------------------------------------


def _toy_recorder() -> TraceRecorder:
    rec = TraceRecorder()
    rec.event("enqueue", 0.0, 7)
    rec.event("admit", 0.5, 7, 0, slot=1, u=2.25, kv_blocks=3)
    rec.event("prefill_chunk", 0.6, 7, 0, slot=1, start=0, length=8,
              finishes=True, shape_key="(8, 1, 8)")
    rec.event("first_token", 0.6, 7, 0, slot=1)
    rec.event("token", 0.7, 7, 1, slot=1, idx=2)
    rec.event("complete", 0.7, 7, 1, lane="gpu", out_len=2)
    rec.event("evict", 0.8, 7, 1, slot=1)
    rec.span("decode.window", 0.6, 0.1, steps=1, active=1)
    rec.counter("kv.util", 0.6, 0.4)
    return rec


def test_trace_jsonl_roundtrip(tmp_path):
    rec = _toy_recorder()
    path = rec.to_jsonl(str(tmp_path / "t.jsonl"))
    back = TraceRecorder.load_jsonl(path)
    assert back.parity_events() == rec.parity_events()
    assert [e.ts for e in back.events] == [e.ts for e in rec.events]
    assert [(s.name, s.ts, s.dur, s.fields) for s in back.spans] \
        == [(s.name, s.ts, s.dur, s.fields) for s in rec.spans]
    assert back.counters == rec.counters


def test_trace_perfetto_export(tmp_path):
    rec = _toy_recorder()
    doc = rec.to_perfetto()
    json.dumps(doc)                          # serializable
    evs = doc["traceEvents"]
    phases = [e["name"] for e in evs if e.get("ph") == "X"
              and e.get("pid") == 1]
    assert {"queued", "prefill", "decode"} <= set(phases)
    assert any(e.get("ph") == "C" for e in evs)
    path = rec.export_perfetto(str(tmp_path / "t.json"))
    with open(path) as f:
        assert json.load(f)["traceEvents"]


def test_trace_budget_guard():
    rec = TraceRecorder(max_events=3)
    for i in range(6):
        rec.event("token", float(i), 0, 0, slot=0, idx=i)
    assert len(rec.events) == 3 and rec.dropped == 3


def test_event_schema_vocabulary():
    assert {e.kind for e in _toy_recorder().events} <= EVENT_KINDS


def test_timelines_reconstruction():
    tls = timelines(_toy_recorder())
    t = tls[7]
    assert t.queue_wait == pytest.approx(0.5)
    assert t.ttft == pytest.approx(0.6)
    assert t.itls == [pytest.approx(0.1)]
    assert t.chunks == 1


# ---------------------------------------------------------------------------
# trace_report CLI on the checked-in mini trace
# ---------------------------------------------------------------------------


def _trace_report():
    sys.path.insert(0, SCRIPTS)
    try:
        import trace_report
    finally:
        sys.path.pop(0)
    return trace_report


def test_mini_trace_schema_and_report(tmp_path, capsys):
    rec = TraceRecorder.load_jsonl(MINI_TRACE)
    assert rec.events and {e.kind for e in rec.events} <= EVENT_KINDS
    tr = _trace_report()
    out = str(tmp_path / "mini.json")
    assert tr.main([MINI_TRACE, "--perfetto", out]) == 0
    text = capsys.readouterr().out
    assert "waterfall" in text and "ttft_s" in text
    with open(out) as f:
        assert json.load(f)["traceEvents"]
    assert tr.main([MINI_TRACE, "--json"]) == 0
    stats = json.loads(capsys.readouterr().out)
    assert stats["requests"] > 0 and stats["ttft_p50"] > 0


def test_trace_report_rejects_unknown_kind(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"type": "event", "kind": "teleport",
                               "ts": 0.0, "task_id": 0}) + "\n")
    assert _trace_report().main([str(bad)]) == 1


# ---------------------------------------------------------------------------
# Observability bundle + rate-limited logging
# ---------------------------------------------------------------------------


def test_observability_disabled_pieces_noop():
    obs = Observability(trace=False, metrics=False)
    obs.event("enqueue", 0.0, 0)
    obs.span("x", 0.0, 1.0)
    obs.counter_sample("c", 0.0, 1.0)
    obs.inc("n")
    obs.gauge("g", 1.0)
    obs.observe("h", 1.0)
    assert obs.event_count() == 0
    with obs.measure():
        pass
    assert obs.overhead_s >= 0.0


def test_rate_limited_logger():
    lg = logging.getLogger("test.obs.ratelimit")
    rl = RateLimitedLogger(min_interval_s=3600.0)
    with _capture(lg) as records:
        for _ in range(5):
            rl.warn(lg, "k", "warn %d", 1)
        assert rl.count("k") == 5               # every occurrence counted
        assert len(records) == 1                # one emission per interval
        rl.reset("k")
        rl.warn(lg, "k", "warn %d", 2)
        assert len(records) == 2                # reset re-arms emission
        assert rl.count("k") == 6               # ...without clearing counts


def test_warn_once_scoped_ledgers_emit_per_scope():
    """PR 9 regression: with R engines in one process, a fresh
    replica's FIRST fallback must not be rate-suppressed just because
    an earlier replica logged the same key — the innermost scoped
    ledger owns the emission decision, while occurrences count in the
    global ledger AND every active scope."""
    from repro.obs import log as obslog

    lg = logging.getLogger("test.obs.scoped")
    key = "test-scoped-key"                     # unique: no bleed-over
    base_global = obslog.FALLBACKS.count(key)
    led_a, led_b = RateLimitedLogger(), RateLimitedLogger()
    with _capture(lg) as records:
        with obslog.scope(led_a):
            assert obslog.warn_once(lg, key, "a first")
            assert not obslog.warn_once(lg, key, "a repeat")
        # a DIFFERENT ledger's first occurrence emits again, within the
        # global ledger's rate-limit interval
        with obslog.scope(led_b):
            assert obslog.warn_once(lg, key, "b first")
        assert len(records) == 2
    assert led_a.count(key) == 2
    assert led_b.count(key) == 1
    assert obslog.FALLBACKS.count(key) - base_global == 3


def test_warn_once_outside_scope_not_attributed_to_engine(run):
    """Process-global fallback noise (another replica, an unscoped
    caller) must not inflate an engine's own fallback accounting."""
    from repro.obs import log as obslog

    lg = logging.getLogger("test.obs.unscoped")
    eng, res, _ = run()
    before = eng.fallback_ledger.count()
    with _capture(lg):
        obslog.warn_once(lg, "jnp-fallback", "unscoped noise")
    assert eng.fallback_ledger.count() == before
    assert res["fallback_events"] == before


class _capture:
    def __init__(self, logger):
        self.logger, self.records = logger, []

    def __enter__(self):
        class H(logging.Handler):
            def emit(h, record):
                self.records.append(record)
        self.h = H()
        self.logger.addHandler(self.h)
        self.logger.setLevel(logging.WARNING)
        return self.records

    def __exit__(self, *exc):
        self.logger.removeHandler(self.h)


# ---------------------------------------------------------------------------
# simulator: tracing changes nothing, events match schema
# ---------------------------------------------------------------------------


def _persona(batch_size=SLOTS):
    return dataclasses.replace(personas.get_persona("bart"),
                               batch_size=batch_size)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("starcoder2-3b")
    from repro.models import model as model_lib
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 64, seed=0)
    train, test = datagen.train_test_split(corpus, train_frac=0.5)
    persona = _persona()
    profile = sched.offline_profile(train, persona, epochs=15)
    texts = [test[i % 4].text for i in range(len(CAPS))]
    return cfg, params, persona, profile, texts


def _requests(texts, caps):
    return [Request(text=t, arrival=0.0, task_id=i, max_new_tokens=c)
            for i, (t, c) in enumerate(zip(texts, caps))]


def _sim_tasks(texts, caps, profile, persona, xi=2.0):
    out = []
    for i, (t, c) in enumerate(zip(texts, caps)):
        u = profile.predictor.score(t)
        d = prio.priority_point(0.0, len(t.split()), persona.phi,
                                None, xi=xi)
        out.append(prio.SimTask(
            task=Request(text=t, arrival=0.0, task_id=i),
            u=float(max(u, 0.0)), r=0.0, d=d,
            input_len=float(len(t.split())), true_out_len=int(c)))
    return out


def _sim_kwargs(prefill, n, kv_num_blocks):
    """Simulator kwargs mirroring ``_engine_kwargs`` — stall-mode runs
    use a deliberately tight pool (4 slots, 7 blocks) so rejection and
    offload paths are exercised; chunked runs inherit the engine's
    derived pool size."""
    kw = dict(kv_block_size=BS, kv_num_blocks=kv_num_blocks,
              prompt_len=BUCKET, decode_steps=n)
    if prefill == "chunked":
        kw.update(num_slots=SLOTS, prefill="chunked", chunk_size=3,
                  token_budget=8)
    else:
        kw.update(num_slots=4)
    return kw


def test_sim_tracing_changes_nothing(setup):
    """A traced simulation is bit-identical to an untraced one — the
    recorder only observes."""
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    runs = []
    for obs in (None, Observability()):
        runs.append(simulator.simulate_continuous(
            _sim_tasks(texts, CAPS, profile, persona),
            sched.POLICIES["fifo"](persona, pcfg),
            obs=obs, **_sim_kwargs("chunked", 2, 24)))
    plain, traced = runs
    assert [t.task.task_id for t in plain.tasks] \
        == [t.task.task_id for t in traced.tasks]
    assert plain.summary() == traced.summary()
    assert plain.budget_trace == traced.budget_trace
    assert plain.decode_dispatch_trace == traced.decode_dispatch_trace


# ---------------------------------------------------------------------------
# engine: parity, off-by-default, trace-derived latencies
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def run(setup):
    """Memoized traced serve: (prefill, decode_steps, traced) -> one
    serve, keeping the module's device time bounded."""
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    cache = {}

    def _run(prefill="stall", n=1, traced=True):
        key = (prefill, n, traced)
        if key not in cache:
            obs = Observability() if traced else None
            kw = dict(decode_steps=n, obs=obs)
            if prefill == "chunked":
                kw.update(num_slots=SLOTS, prefill="chunked",
                          chunk_size=3, token_budget=8)
            else:
                kw.update(num_slots=4, kv_num_blocks=7)
            eng = ServingEngine(
                params, cfg, sched.POLICIES["fifo"](persona, pcfg),
                profile, input_bucket=BUCKET, max_new_tokens=MAX_NEW,
                mode="continuous", eos_id=-1, kv="paged",
                kv_block_size=BS, **kw)
            cache[key] = (eng, eng.serve(_requests(texts, CAPS)), obs)
        return cache[key]

    return _run


@pytest.mark.parametrize("prefill,n", [("stall", 1), ("stall", 4),
                                       ("chunked", 1), ("chunked", 4)])
def test_engine_vs_sim_event_parity(setup, run, prefill, n):
    """The tentpole acceptance: engine and simulator emit the SAME
    lifecycle event stream (equal up to wall-clock fields) and
    bit-identical counters."""
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng, res, eobs = run(prefill, n)
    sobs = Observability()
    sim = simulator.simulate_continuous(
        _sim_tasks(texts, CAPS, profile, persona),
        sched.POLICIES["fifo"](persona, pcfg), obs=sobs,
        **_sim_kwargs(prefill, n, eng.kv_num_blocks))
    assert res["completion_order"] == [t.task.task_id for t in sim.tasks]
    ee, se = eobs.trace.parity_events(), sobs.trace.parity_events()
    assert len(ee) == len(se)
    assert ee == se
    assert eobs.metrics.counters() == sobs.metrics.counters()
    # the counters cross-check the result-dict mirrors
    c = eobs.metrics.counters()
    assert c["sched.completions"] == len(CAPS)
    assert c["prefill.dispatches"] == res["prefill_dispatches"]
    assert {e.kind for e in eobs.trace.events} <= EVENT_KINDS
    assert sim.fallback_events == 0


def test_obs_none_results_unchanged(setup, run):
    """obs=None serves produce the same deterministic results as traced
    serves — recording never alters scheduling decisions."""
    _, plain, none_obs = run("stall", 1, traced=False)
    _, traced, obs = run("stall", 1, traced=True)
    assert none_obs is None
    for key in ("completion_order", "prefill_dispatches",
                "prefill_dispatch_trace", "decode_dispatches",
                "decode_dispatch_trace", "decode_steps_executed",
                "rejected_for_memory", "exec_cache_hits",
                "exec_cache_misses", "fallback_events"):
        assert plain[key] == traced[key], key
    assert plain["obs_overhead_s"] == 0.0
    assert traced["obs_overhead_s"] >= 0.0


def test_traced_serve_reconstructs_latencies(setup, run, tmp_path):
    """Acceptance: a traced chunked serve exports a valid Chrome trace
    whose event stream reconstructs the result dict's TTFT/ITL
    percentiles within histogram tolerance."""
    eng, res, obs = run("chunked", 4)
    # valid Chrome trace_event JSON
    path = obs.trace.export_perfetto(str(tmp_path / "serve.json"))
    with open(path) as f:
        doc = json.load(f)
    assert doc["traceEvents"]
    # JSONL round-trip preserves the stream
    jl = obs.trace.to_jsonl(str(tmp_path / "serve.jsonl"))
    back = TraceRecorder.load_jsonl(jl)
    assert back.parity_events() == obs.trace.parity_events()
    # timelines -> per-request TTFT / pooled ITL / queue wait
    tls = timelines(back)
    assert len(tls) == len(CAPS)
    ttft_h, itl_h, qw_h = Histogram(), Histogram(), Histogram()
    for t in tls.values():
        assert t.ttft is not None and t.queue_wait is not None
        ttft_h.record(t.ttft)
        qw_h.record(t.queue_wait)
        for v in t.itls:
            itl_h.record(v)
    tol = np.sqrt(Histogram.GROWTH) - 1.0
    for key, h, q in (("ttft_p50", ttft_h, 0.50),
                      ("ttft_p90", ttft_h, 0.90),
                      ("ttft_p99", ttft_h, 0.99),
                      ("itl_p50", itl_h, 0.50),
                      ("itl_p90", itl_h, 0.90),
                      ("itl_p99", itl_h, 0.99),
                      ("queue_wait_p50", qw_h, 0.50),
                      ("queue_wait_p99", qw_h, 0.99)):
        assert res[key] == pytest.approx(h.quantile(q), rel=2 * tol), key
    # chunked admissions run through the chunk queue: every request saw
    # at least one prefill_chunk event
    assert all(t.chunks >= 1 for t in tls.values())
