"""The lightweight (LW) uncertainty-score predictor (paper §III-B, Alg. 1).

A pure-JAX MLP with hidden sizes [100, 200, 200, 100] (paper §V-A),
trained with Adam at lr=1e-4 to minimize MSE between the predicted and
true output lengths:  u_J = m_theta(RULEGEN(J)).

Inputs are the 6 rule intensities + input length (rulegen.features);
features are z-normalized with training-set statistics held inside the
predictor state.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from . import datagen, rulegen

HIDDEN = (100, 200, 200, 100)


def init_mlp(key, in_dim: int = rulegen.FEATURE_DIM,
             hidden: Sequence[int] = HIDDEN) -> list:
    sizes = (in_dim,) + tuple(hidden) + (1,)
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (a, b), jnp.float32) * (2.0 / a) ** 0.5
        params.append({"w": w, "b": jnp.zeros((b,), jnp.float32)})
    return params


def mlp_apply(params: list, x: jnp.ndarray) -> jnp.ndarray:
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h[..., 0]


import functools


@functools.partial(jax.jit, static_argnames=("quantile",))
def _loss(params, x, y, quantile=None):
    pred = mlp_apply(params, x)
    if quantile is None:
        return jnp.mean(jnp.square(pred - y))
    # pinball loss — beyond-paper: a tail-aware predictor (e.g. P90 of the
    # output-length distribution) lets the scheduler consolidate/offload
    # on the statistic that actually sets batched-decode latency (the
    # batch MAX), not the mean.
    err = y - pred
    return jnp.mean(jnp.maximum(quantile * err, (quantile - 1.0) * err))


@functools.partial(jax.jit, static_argnames=("quantile",))
def _adam_step(params, m, v, t, x, y, lr=1e-4, b1=0.9, b2=0.999, eps=1e-8,
               quantile=None):
    loss, grads = jax.value_and_grad(_loss)(params, x, y, quantile)
    t = t + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, v, grads)
    tf = t.astype(jnp.float32)
    params = jax.tree.map(
        lambda p, m_, v_: p - lr * (m_ / (1 - b1 ** tf))
        / (jnp.sqrt(v_ / (1 - b2 ** tf)) + eps),
        params, m, v)
    return params, m, v, t, loss


@dataclasses.dataclass
class Predictor:
    params: list
    mean: np.ndarray
    std: np.ndarray
    train_losses: list

    def score(self, text: str) -> float:
        """u_J = m_theta(RULEGEN(J)) — predicted output length (tokens)."""
        f = (rulegen.features(text) - self.mean) / self.std
        return float(mlp_apply(self.params, jnp.asarray(f[None]))[0])

    def score_batch(self, texts: Sequence[str]) -> np.ndarray:
        f = np.stack([rulegen.features(t) for t in texts])
        f = (f - self.mean) / self.std
        return np.asarray(mlp_apply(self.params, jnp.asarray(f)))


def extract_xy(tasks: Sequence[datagen.Task], persona: str):
    x = np.stack([rulegen.features(t.text) for t in tasks])
    y = np.array([t.out_lens[persona] for t in tasks], np.float32)
    return x, y


def train_predictor(tasks: Sequence[datagen.Task], persona: str,
                    *, epochs: int = 100, batch_size: int = 64,
                    lr: float = 1e-3, seed: int = 0,
                    quantile=None) -> Predictor:
    x, y = extract_xy(tasks, persona)
    mean = x.mean(axis=0)
    std = x.std(axis=0) + 1e-6
    xn = (x - mean) / std

    key = jax.random.PRNGKey(seed)
    params = init_mlp(key)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    t = jnp.zeros((), jnp.int32)

    n = len(xn)
    rng = np.random.default_rng(seed)
    losses: List[float] = []
    xj, yj = jnp.asarray(xn), jnp.asarray(y)
    for epoch in range(epochs):
        perm = rng.permutation(n)
        ep_loss = 0.0
        nb = 0
        for s in range(0, n, batch_size):
            idx = jnp.asarray(perm[s:s + batch_size])
            params, m, v, t, loss = _adam_step(
                params, m, v, t, xj[idx], yj[idx], lr=lr,
                quantile=quantile)
            ep_loss += float(loss)
            nb += 1
        losses.append(ep_loss / max(nb, 1))
    return Predictor(params=params, mean=mean, std=std, train_losses=losses)


def fit_weighted_rule(tasks: Sequence[datagen.Task],
                      persona: str) -> np.ndarray:
    """§III-B 'weighted rule': least-squares weights over the features."""
    x, y = extract_xy(tasks, persona)
    w, *_ = np.linalg.lstsq(
        np.concatenate([x, np.ones((len(x), 1))], axis=1), y, rcond=None)
    return w.astype(np.float32)
