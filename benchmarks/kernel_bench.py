"""Kernel microbenchmarks: chunked-jnp substrate path wall-clock on CPU
(the Pallas kernels themselves are TPU artifacts; interpret mode is a
correctness harness, not a performance proxy — see EXPERIMENTS.md)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def attention_bench():
    key = jax.random.PRNGKey(0)
    rows = {}
    for (B, S, H, KV, D) in [(1, 512, 8, 2, 64), (1, 1024, 8, 2, 64),
                             (2, 2048, 8, 8, 128)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
        t_sub = _time(ops.flash_attention, q, k, v, use_pallas=False)
        t_ref = _time(jax.jit(lambda a, b, c: ref.attention_ref(a, b, c)),
                      q, k, v)
        flops = 2 * 2 * B * H * S * S * D * 0.5
        rows[f"B{B}_S{S}_H{H}kv{KV}_D{D}"] = {
            "chunked_ms": round(t_sub * 1e3, 2),
            "naive_ms": round(t_ref * 1e3, 2),
            "chunked_gflops": round(flops / t_sub / 1e9, 1),
        }
    return rows


def rmsnorm_bench():
    key = jax.random.PRNGKey(1)
    rows = {}
    for (N, D) in [(4096, 1024), (16384, 4096)]:
        x = jax.random.normal(key, (N, D), jnp.float32)
        w = jnp.zeros(D)
        t = _time(ops.rms_norm, x, w, use_pallas=False)
        gbps = 2 * x.nbytes / t / 1e9
        rows[f"N{N}_D{D}"] = {"ms": round(t * 1e3, 3),
                              "effective_GBps": round(gbps, 1)}
    return rows
