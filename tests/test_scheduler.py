"""Algorithm-1 behaviors: Fig. 4 prioritization example, consolidation
segmentation, offload thresholding, queue conservation."""

import pytest

from repro.core import priority as prio, scheduler as sched
from repro.core.personas import Persona

PERSONA = Persona("test", batch_size=4, malicious_tau=20.0, eta=1.0,
                  phi=0.0, base_output=0, uncertainty_gain=1, noise_std=0,
                  setup_time=0.0, cpu_slowdown=3.0, item_time=0.0)


def mk(u, r=0.0, d=10.0, out=None):
    return prio.SimTask(task=None, u=u, r=r, d=d, input_len=1.0,
                        true_out_len=int(out if out is not None else u))


def pcfg(**kw):
    return sched.PolicyConfig(u_scale=10.0, tau=kw.pop("tau", 1e18), **kw)


# ---------------------------------------------------------------------------
# Fig. 4: UP beats HPF and LUF on priority-point misses
# ---------------------------------------------------------------------------


def test_fig4_up_fewer_misses_than_hpf_luf():
    """Five simultaneous tasks; serial execution (batch size 1)."""
    persona = Persona("fig4", batch_size=1, malicious_tau=1e9, eta=1.0,
                      phi=0.0, base_output=0, uncertainty_gain=1,
                      noise_std=0, setup_time=0.0, cpu_slowdown=3.0,
                      item_time=0.0)
    # (exec_time, priority point): mixture where HPF runs a long job first
    jobs = [(5.0, 6.0), (1.0, 9.0), (2.0, 4.0), (1.0, 13.0), (3.0, 12.0)]

    def run(order):
        t, missed = 0.0, 0
        for i in order:
            t += jobs[i][0]
            missed += t > jobs[i][1]
        return missed

    hpf = sorted(range(5), key=lambda i: jobs[i][1])
    luf = sorted(range(5), key=lambda i: jobs[i][0])
    up = sorted(range(5), key=lambda i: (1 - jobs[i][0] / 5.0)
                / max(jobs[i][1] - jobs[i][0], 1e-6), reverse=True)
    assert run(up) <= run(hpf)
    assert run(up) <= run(luf)


# ---------------------------------------------------------------------------
# consolidation / segmentation (Alg. 1 lines 18-25)
# ---------------------------------------------------------------------------


def test_consolidation_reaches_batch_size_despite_lambda():
    """The lambda cut never starves the executor below C (line 22 is a
    disjunction)."""
    policy = sched.UPC(PERSONA, pcfg(lam=1.01, b=2.0))
    queue = [mk(u) for u in (1, 3, 9, 27, 81, 243, 729, 2187)]
    gpu, cpu, rest = policy.select(queue, now=0.0)
    assert len(gpu) == PERSONA.batch_size
    assert not cpu
    assert len(rest) == len(queue) - len(gpu)


def test_consolidation_extends_homogeneous_batches():
    policy = sched.UPC(PERSONA, pcfg(lam=1.5, b=1.8))
    queue = [mk(u) for u in (10, 10.1, 10.2, 10.3, 10.4, 10.5, 10.6)]
    gpu, _, rest = policy.select(queue, now=0.0)
    # b*C = 7.2 -> all 7 homogeneous tasks fit one consolidated batch
    assert len(gpu) == 7


def test_consolidation_cuts_at_lambda_gap_beyond_C():
    policy = sched.UPC(PERSONA, pcfg(lam=1.5, b=2.0))
    queue = [mk(u) for u in (1, 1.1, 1.2, 1.3, 1.35, 100, 110, 120)]
    gpu, _, rest = policy.select(queue, now=0.0)
    assert len(gpu) == 5           # C=4 guaranteed, 1.35 joins, 100 cut
    assert {t.u for t in rest} == {100, 110, 120}


def test_batch_sorted_ascending_uncertainty():
    policy = sched.UPC(PERSONA, pcfg())
    queue = [mk(u) for u in (7, 3, 11, 5, 2, 13)]
    gpu, _, _ = policy.select(queue, now=0.0)
    us = [t.u for t in gpu]
    assert us == sorted(us)


# ---------------------------------------------------------------------------
# strategic offloading (Alg. 1 lines 15-16)
# ---------------------------------------------------------------------------


def test_offload_above_tau_when_congested():
    policy = sched.RTLM(PERSONA, pcfg(tau=20.0, b=1.5))
    queue = [mk(u) for u in (1, 2, 3, 25, 4, 30, 5, 6, 7, 8)]
    gpu, cpu, rest = policy.select(queue, now=0.0)
    assert {t.u for t in cpu} == {25, 30}
    assert all(t.u <= 20 for t in gpu)


def test_no_offload_when_uncongested():
    policy = sched.RTLM(PERSONA, pcfg(tau=20.0, b=1.5))
    queue = [mk(u) for u in (1, 25, 3)]        # below b*C backlog
    gpu, cpu, rest = policy.select(queue, now=0.0)
    assert not cpu


def test_select_conserves_tasks():
    for cls in (sched.Policy, sched.HPF, sched.LUF, sched.MUF,
                sched.SlackEq2, sched.UP, sched.UPC, sched.RTLM):
        policy = cls(PERSONA, pcfg(tau=6.0))
        queue = [mk(float(u)) for u in range(1, 12)]
        gpu, cpu, rest = policy.select(queue, now=0.0)
        got = sorted(t.u for t in gpu + cpu + rest)
        assert got == sorted(t.u for t in queue), cls.name
        assert len(gpu) <= int(PERSONA.batch_size * policy.pcfg.b) + 1


# ---------------------------------------------------------------------------
# Eq. 2 / Eq. 3
# ---------------------------------------------------------------------------


def test_eq3_prefers_short_jobs_same_slack():
    p_small = prio.eq3_priority(d=10, r=0, u=1, eta=0.0, alpha=1.0,
                                u_scale=10)
    p_large = prio.eq3_priority(d=10, r=0, u=9, eta=0.0, alpha=1.0,
                                u_scale=10)
    assert p_small > p_large


def test_eq3_alpha_zero_reduces_to_slack():
    for u in (1.0, 5.0, 9.0):
        assert prio.eq3_priority(10, 0, u, 0.5, 0.0, 10) == pytest.approx(
            prio.eq2_priority(10, 0, u, 0.5))


def test_priority_point_uses_deadline_when_given():
    assert prio.priority_point(5.0, 10, 0.1, deadline=42.0) == 42.0
    assert prio.priority_point(5.0, 10, 0.1, None, xi=2.0) == \
        pytest.approx(5.0 + 2.0 + 1.0)
