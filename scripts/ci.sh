#!/usr/bin/env bash
# CI entry point (also runnable locally): quickest signal first (the
# chunked-prefill subsystem module), then the fast lane, then the full
# tier-1 suite.
#
#   scripts/ci.sh          # prefill module + fast lane + full tier-1
#   CI_FAST_ONLY=1 scripts/ci.sh   # prefill module + fast lane only
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== chunked-prefill subsystem (quick signal) =="
scripts/run_tier1.sh -m "not slow" tests/test_chunked_prefill.py

echo "== fast lane (-m 'not slow') =="
scripts/run_tier1.sh -m "not slow" --ignore=tests/test_chunked_prefill.py

if [[ "${CI_FAST_ONLY:-0}" != "1" ]]; then
  echo "== full tier-1 =="
  scripts/run_tier1.sh
fi
