"""Mixtral 8x22B — sparse MoE, 8 experts top-2, SWA [arXiv:2401.04088].

Assignment row: [moe] 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, sliding-window attention (window 4096, as in
the Mistral/Mixtral lineage) — which bounds decode KV state and makes the
long_500k shape eligible.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    vocab_size=32768,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    mlp_act="swiglu",
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=16384,
    window=4096,
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088 (Mixtral of Experts)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke", family="moe", num_layers=2, d_model=256,
        vocab_size=2048, num_heads=8, num_kv_heads=2, head_dim=32, d_ff=512,
        mlp_act="swiglu", num_experts=4, experts_per_token=2, moe_d_ff=512,
        window=64, source=CONFIG.source)
