"""Core neural building blocks, pure JAX.

Everything here is functional: params are pytrees of jnp arrays created by
``init_*`` helpers and consumed by the matching ``apply`` functions.  The
attention implementation is the *chunked* (flash-style, O(S*chunk) memory)
pure-jnp reference; the Pallas TPU kernels in ``repro.kernels`` implement the
same contract and are validated against ``repro.kernels.ref`` oracles.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding import context as shctx

Array = jax.Array

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key: Array, shape, dtype, scale: float = 0.02) -> Array:
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rms_norm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def gated_rms_norm(x: Array, z: Array, weight: Array, eps: float = 1e-6) -> Array:
    """Mamba-2 style norm: RMSNorm(x * silu(z))."""
    return rms_norm(x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                    weight, eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10_000.0) -> Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                      # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — chunked flash-style reference (pure jnp)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def expand_kv(k: Array, num_heads: int) -> Array:
    """(B, S, KV, D) -> (B, S, H, D) by repeating each KV head G times.

    The H-expanded formulation keeps every attention einsum sharded purely
    on the H axis (logical "heads"), avoiding (KV, G) reshapes of a sharded
    dimension that GSPMD would have to re-layout with collectives.
    """
    KV = k.shape[2]
    if KV == num_heads:
        return k
    return jnp.repeat(k, num_heads // KV, axis=2)


def _attend_chunk(q, k, v, qpos, kpos, scale, causal, window):
    """One (q-chunk, kv-chunk) tile.  q: (B, Sq, H, D); k/v: (B, Sk, H, D)."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones(s.shape[-2:], dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask, s, NEG_INF)
    return s


def chunked_attention(q: Array, k: Array, v: Array, *,
                      q_positions: Array, kv_positions: Array,
                      causal: bool = True, window: Optional[int] = None,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      kv_valid_len: Optional[Array] = None) -> Array:
    """Flash-style attention with O(chunk) score memory.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D); GQA via H = KV * G.
    q_positions: (Sq,) absolute positions; kv_positions: (Sk,).
    kv_valid_len: optional scalar — keys at kv index >= valid_len are masked
      (ring-buffer caches).
    Returns (B, Sq, H, D).
    """
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    scale = 1.0 / (D ** 0.5)
    k = shctx.constrain(expand_kv(k, H), ("batch", None, "heads", None))
    v = shctx.constrain(expand_kv(v, H), ("batch", None, "heads", None))

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    # pad to chunk multiples
    pq, pk = nq * q_chunk - Sq, nk * kv_chunk - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pq), constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pk),
                               constant_values=2**30)

    qs = q.reshape(B, nq, q_chunk, H, D).transpose(1, 0, 2, 3, 4)
    qp = q_positions.reshape(nq, q_chunk)
    ks = k.reshape(B, nk, kv_chunk, H, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_chunk, H, D).transpose(1, 0, 2, 3, 4)
    kp = kv_positions.reshape(nk, kv_chunk)
    kidx = jnp.arange(nk * kv_chunk).reshape(nk, kv_chunk)

    def q_body(_, qc):
        qi, qpi = qc

        def kv_body(carry, kc):
            m, l, acc = carry
            ki, vi, kpi, kii = kc
            s = _attend_chunk(qi, ki, vi, qpi, kpi, scale, causal, window)
            if kv_valid_len is not None:
                s = jnp.where(kii[None, None, None, :] < kv_valid_len,
                              s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vi.dtype), vi,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, D), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_body, (m0, l0, a0), (ks, vs, kp, kidx))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = lax.scan(q_body, None, (qs, qp))          # (nq,B,H,qc,D)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq]


def windowed_attention(q: Array, k: Array, v: Array, *,
                       q_positions: Array, kv_positions: Array,
                       window: int, q_chunk: int = 1024) -> Array:
    """Sliding-window causal attention with O(S*window) FLOPs.

    Each q chunk attends only to the kv slice [chunk_start - window,
    chunk_end), gathered with dynamic_slice — genuinely sub-quadratic.
    Requires q and kv aligned (Sq == Sk, same positions) — i.e. prefill.
    """
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    if window >= Sk:  # degenerate: full attention is cheaper
        return chunked_attention(q, k, v, q_positions=q_positions,
                                 kv_positions=kv_positions, causal=True,
                                 window=window, q_chunk=q_chunk)
    scale = 1.0 / (D ** 0.5)
    k = shctx.constrain(expand_kv(k, H), ("batch", None, "heads", None))
    v = shctx.constrain(expand_kv(v, H), ("batch", None, "heads", None))
    q_chunk = min(q_chunk, Sq)
    nq = -(-Sq // q_chunk)
    pq = nq * q_chunk - Sq
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pq), constant_values=-1)
    span = window + q_chunk
    # pad kv on the left by `window` so every chunk's slice is in range
    k = jnp.pad(k, ((0, 0), (window, pq), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (window, pq), (0, 0), (0, 0)))
    kv_positions = jnp.pad(kv_positions, (window, pq),
                           constant_values=2**30)
    kv_positions = kv_positions.at[:window].set(-(2**30))

    qr = q.reshape(B, nq, q_chunk, H, D).transpose(1, 0, 2, 3, 4)
    qp = q_positions.reshape(nq, q_chunk)
    starts = jnp.arange(nq) * q_chunk

    def body(_, xs):
        qi, qpi, start = xs
        ki = lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vi = lax.dynamic_slice_in_dim(v, start, span, axis=1)
        kpi = lax.dynamic_slice_in_dim(kv_positions, start, span, axis=0)
        s = _attend_chunk(qi, ki, vi, qpi, kpi, scale, True, window)
        out = jnp.einsum("bhqk,bkhd->bhqd",
                         jax.nn.softmax(s, axis=-1).astype(vi.dtype), vi,
                         preferred_element_type=jnp.float32)
        return None, out.astype(q.dtype)

    _, outs = lax.scan(body, None, (qr, qp, starts))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq]


def decode_attention(q: Array, k_cache: Array, v_cache: Array, *,
                     q_position: Array, kv_positions: Array,
                     valid_len: Array, window: Optional[int] = None) -> Array:
    """Single-step attention against a KV cache.

    q: (B, 1, H, D); caches: (B, Smax, KV, D); valid_len: scalar int —
    number of populated cache slots; kv_positions: (Smax,) absolute
    positions of cache entries (ring buffers make these non-monotonic).

    Per-slot (continuous-batching) form: q_position (B,), valid_len (B,)
    and kv_positions (B, Smax) — every batch row tracks an independent
    sequence, so the validity mask is computed per row.
    """
    B, _, H, D = q.shape
    _, Sm, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / (D ** 0.5)
    # grouped-GQA formulation: the cache is consumed at its stored (KV)
    # width — never materialize the H-expanded copy (the decode step is
    # cache-bandwidth-bound; an 8x expansion is an 8x memory-term hit.
    # The Pallas decode kernel achieves the same via its BlockSpec
    # index_map on TPU).
    qr = q.reshape(B, KV, G, D)
    policy = shctx.current()
    seq_sharded = (policy is not None
                   and policy.resolve(KV, "kv_heads") is None)
    if seq_sharded:
        # the cache is stored sequence-sharded (kv heads don't divide the
        # model axis).  Pin the score row to the same layout so GSPMD
        # reduces over the sharded seq dim with one small (B, H, D)
        # all-reduce instead of all-gathering the cache — for kimi
        # decode_32k this is ~110 GiB -> ~0.1 GiB of per-step collective
        # traffic (EXPERIMENTS.md §Perf).
        qr = policy.constrain(qr, ("batch", None, None, None))
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if seq_sharded:
        s = policy.constrain(s, ("batch", None, None, "kv_seq"))
    idx = jnp.arange(Sm)
    q_pos = jnp.asarray(q_position)
    if q_pos.ndim:                          # per-slot decode: (B,) state
        mask = ((idx[None, :] < jnp.asarray(valid_len)[:, None])
                & (kv_positions <= q_pos[:, None]))
        if window is not None:
            mask &= (q_pos[:, None] - kv_positions) < window
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    else:
        mask = (idx < valid_len) & (kv_positions <= q_position)
        if window is not None:
            mask &= (q_position - kv_positions) < window
        s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + norm)
# ---------------------------------------------------------------------------


def init_attention(key: Array, cfg, dtype) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (D, H, hd), dtype),
        "wk": dense_init(ks[1], (D, KV, hd), dtype),
        "wv": dense_init(ks[2], (D, KV, hd), dtype),
        "wo": dense_init(ks[3], (H, hd, D), dtype),
    }


def attention_qkv(params: dict, x: Array, positions: Array,
                  rope_theta: float) -> tuple[Array, Array, Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    policy = shctx.current()
    if policy is not None:
        q = policy.constrain(
            q, policy.attn_q_axes(q.shape[1], q.shape[2]))
    k = shctx.constrain(k, ("batch", None, "kv_heads", None))
    v = shctx.constrain(v, ("batch", None, "kv_heads", None))
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def attention_out(params: dict, attn: Array) -> Array:
    return jnp.einsum("bshk,hkd->bsd", attn, params["wo"])


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key: Array, d_model: int, d_ff: int, act: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d_model, d_ff), dtype),
         "w_down": dense_init(ks[1], (d_ff, d_model), dtype)}
    if act == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def apply_mlp(params: dict, x: Array, act: str) -> Array:
    up = x @ params["w_up"]
    if act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * up
    elif act == "gelu":
        h = jax.nn.gelu(up)
    else:
        h = jax.nn.relu(up)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def init_embedding(key: Array, cfg, dtype) -> dict:
    V, D = cfg.padded_vocab, cfg.d_model
    ks = jax.random.split(key, 2)
    # unit-variance after the sqrt(D) input multiplier; keeps tied logits
    # at O(|x|) magnitude so the initial loss is ~log(V).
    p = {"embedding": dense_init(ks[0], (V, D), dtype,
                                 scale=1.0 / (D ** 0.5))}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (D, V), dtype)
    return p


def embed(params: dict, tokens: Array, cfg) -> Array:
    x = params["embedding"][tokens]
    return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)


def logits(params: dict, x: Array, cfg) -> Array:
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, params["embedding"])
    else:
        out = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    # mask vocab padding
    V = cfg.padded_vocab
    if V != cfg.vocab_size:
        mask = jnp.arange(V) < cfg.vocab_size
        out = jnp.where(mask, out, NEG_INF)
    return out
