"""Windowed SLO attainment + uncertainty calibration on a mixed-class
workload (PR 8 observability tentpole).

Two measurements land in experiments/bench/slo_calibration.json:

  * ``sim``    — a Poisson-ramp mixed-class workload (3:1
    interactive:batch, per-class targets declared via
    ``workload.make_traffic_classes``) through the chunked continuous
    simulator with the SLO monitor + calibration ledger + periodic
    health snapshots on: per-class cumulative and live-window
    attainment, predictor MAE/bias, per-u-bucket reliability rows, and
    the windowed drift score;
  * ``parity`` — the acceptance discipline asserted IN-benchmark: a
    small all-at-t0 classed workload served by the real engine and by
    the simulator produces bit-for-bit identical per-class SLO
    counters, calibration counters, and snapshot observation vectors
    (targets pinned to +inf / -1.0 so ok/total judgments are invariant
    to the wall-derived clock skew between the two sides).

    PYTHONPATH=src python -m benchmarks.slo_calibration [--seed N]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

from repro.core import (priority as prio, scheduler as sched, simulator,
                        workload)
from repro.obs import Observability, SLOSpec
from repro.serving.engine import Request

from . import common

N_SIM = 400
SIM_SLOTS = 8
SIM_BUCKET = 64
SIM_MAX_OUT = 48
SNAPSHOT_EVERY = 32
PERSONA = "bart"
VARIANCE = "normal"
SEED = 0

# per-class targets: interactive is judged on responsiveness (TTFT +
# inter-token cadence + end-to-end), batch only on a looser e2e bound —
# pinned near the workload's p80-p95 latencies so the attainment
# fractions discriminate (all-1.0 tables measure nothing)
CLASS_SPEC = {
    "interactive": {"slo": {"ttft_s": 0.4, "itl_s": 0.06, "e2e_s": 1.5},
                    "weight": 3.0},
    "batch": {"slo": {"e2e_s": 2.0}},
}

# the parity column's fixture (mirrors tests/test_slo.py)
PAR_CAPS = [2, 6, 1, 4, 6, 2, 3, 5, 1, 6, 2, 4]
PAR_SLOTS = 3
PAR_MAX_NEW = 6
PAR_BUCKET = 8
PAR_BS = 4


def _sim_tasks(test, caps, arrivals, cls_assign, profile, persona):
    out = []
    for i, (t, c, a) in enumerate(zip(test, caps, arrivals)):
        text = t if isinstance(t, str) else t.text
        u = profile.predictor.score(text)
        d = prio.priority_point(float(a), len(text.split()), persona.phi,
                                None, xi=2.0)
        out.append(prio.SimTask(
            task=Request(text=text, arrival=float(a), task_id=i,
                         traffic_class=cls_assign[i]),
            u=float(max(u, 0.0)), r=float(a), d=d,
            input_len=float(len(text.split())), true_out_len=int(c)))
    return out


def run_sim(seed=SEED):
    """Mixed-class chunked simulation with the full PR-8 surface on."""
    persona = common.personas.get_persona(PERSONA)
    _, test = common.corpus(VARIANCE, seed=seed)
    test = test[:N_SIM]
    profile = common.profile(VARIANCE, PERSONA, seed=seed)
    classes = workload.make_traffic_classes(CLASS_SPEC)
    cls_assign = workload.assign_classes(len(test), classes, seed=seed)
    caps = [max(1, min(int(t.out_lens[PERSONA]), SIM_MAX_OUT))
            for t in test]
    betas = common.persona_betas(PERSONA, VARIANCE)
    arrivals = workload.poisson_trace(len(test), betas=betas,
                                      seed=seed + 1)
    obs = Observability(slo=workload.slo_targets(classes),
                        calibration=True,
                        snapshot_every_steps=SNAPSHOT_EVERY)
    pcfg = profile.policy_config()
    res = simulator.simulate_continuous(
        _sim_tasks(test, caps, arrivals, cls_assign, profile, persona),
        sched.POLICIES["fifo"](persona, pcfg),
        obs=obs, num_slots=SIM_SLOTS, prompt_len=SIM_BUCKET,
        decode_steps=4, prefill="chunked", chunk_size=SIM_BUCKET // 2,
        token_budget=SIM_SLOTS + SIM_BUCKET,
        kv_block_size=16, kv_num_blocks=SIM_SLOTS * 8)
    assert res.slo_attainment and res.calibration["count"] == len(test)
    assert res.health_trace, "no snapshots fired"
    return {
        "n_tasks": len(test),
        "class_counts": {c.name: cls_assign.count(c.name)
                         for c in classes},
        "attainment": res.slo_attainment,
        "windowed_attainment": obs.slo.windowed_attainment(),
        "calibration": res.calibration,
        "snapshots": len(res.health_trace),
        "last_health": {k: v for k, v in res.health_trace[-1].items()
                        if k != "attainment"},
        "obs_overhead_s": obs.overhead_s,
    }


def run_parity(seed=SEED):
    """Engine-vs-sim bit-parity of SLO/calibration/snapshot counters,
    asserted here so the recorded JSON carries a checked claim."""
    import jax

    from repro import configs
    from repro.core import datagen, personas
    from repro.models import model as model_lib
    from repro.serving.engine import ServingEngine

    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 64, seed=seed)
    train, test = datagen.train_test_split(corpus, train_frac=0.5)
    persona = dataclasses.replace(personas.get_persona(PERSONA),
                                  batch_size=PAR_SLOTS)
    profile = sched.offline_profile(train, persona, epochs=15, seed=seed)
    texts = [test[i % 4].text for i in range(len(PAR_CAPS))]
    cls_assign = ["interactive", "batch"] * (len(PAR_CAPS) // 2)
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    # judgment-invariant targets: +inf always attains, -1.0 never does
    # (latencies are >= 0), so ok/total cannot depend on clock skew
    targets = {"interactive": SLOSpec(),
               "batch": SLOSpec(ttft_s=-1.0, itl_s=-1.0, e2e_s=-1.0,
                                queue_wait_s=-1.0)}

    def make_obs():
        return Observability(slo=dict(targets), calibration=True,
                             snapshot_every_steps=2)

    eobs, sobs = make_obs(), make_obs()
    eng = ServingEngine(
        params, cfg, sched.POLICIES["fifo"](persona, pcfg), profile,
        input_bucket=PAR_BUCKET, max_new_tokens=PAR_MAX_NEW,
        mode="continuous", eos_id=-1, kv="paged", kv_block_size=PAR_BS,
        num_slots=PAR_SLOTS, prefill="chunked", chunk_size=3,
        token_budget=8, decode_steps=4, obs=eobs)
    res = eng.serve([Request(text=t, arrival=0.0, task_id=i,
                             max_new_tokens=c, traffic_class=cls_assign[i])
                     for i, (t, c) in enumerate(zip(texts, PAR_CAPS))])
    sim = simulator.simulate_continuous(
        _sim_tasks(texts, PAR_CAPS, [0.0] * len(PAR_CAPS), cls_assign,
                   profile, persona),
        sched.POLICIES["fifo"](persona, pcfg), obs=sobs,
        num_slots=PAR_SLOTS, prompt_len=PAR_BUCKET, decode_steps=4,
        prefill="chunked", chunk_size=3, token_budget=8,
        kv_block_size=PAR_BS, kv_num_blocks=eng.kv_num_blocks)

    assert res["completion_order"] == [t.task.task_id for t in sim.tasks]
    assert eobs.trace.parity_events() == sobs.trace.parity_events()
    assert eobs.slo.parity_counters() == sobs.slo.parity_counters()
    assert eobs.calibration.parity() == sobs.calibration.parity()
    assert len(eobs.health_trace) == len(sobs.health_trace) > 0
    for a, b in zip(eobs.health_trace, sobs.health_trace):
        for k in ("step", "queue_depth", "active", "kv_util", "drift",
                  "calibration_count"):
            assert a[k] == b[k], (k, a, b)
    return {
        "n_requests": len(PAR_CAPS),
        "events": len(eobs.trace.parity_events()),
        "snapshots": len(eobs.health_trace),
        "slo_counters": eobs.slo.parity_counters(),
        "calibration_counters": eobs.calibration.parity(),
        "counters_match": True,
    }


def main(seed=SEED):
    t0 = time.time()
    sim = run_sim(seed=seed)
    parity = run_parity(seed=seed)
    payload = {
        "seed": seed,
        "classes": CLASS_SPEC,
        "snapshot_every_steps": SNAPSHOT_EVERY,
        "sim": sim,
        "parity": parity,
    }
    common.save("slo_calibration", payload)
    att = sim["attainment"]
    cal = sim["calibration"]
    common.emit(
        "slo_calibration", time.time() - t0,
        f"interactive_e2e={att['interactive']['e2e']['frac']:.3f},"
        f"batch_e2e={att['batch']['e2e']['frac']:.3f},"
        f"mae={cal['mae']:.2f},bias={cal['bias']:+.2f},"
        f"drift={cal['drift']:.3f},"
        f"snapshots={sim['snapshots']},"
        f"parity_counters_match={parity['counters_match']}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=SEED)
    main(seed=ap.parse_args().seed)
