"""Shared fixtures and suite-wide markers.

Fast lane: the dry-run lowering and model-family smoke tests each take
>1 min on a CPU container; they are auto-marked ``slow`` below, so

    pytest -m "not slow"          # fast lane (~seconds per module)
    pytest                        # full tier-1 suite

(or use scripts/run_tier1.sh, which also pins PYTHONPATH=src).

NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
the single real CPU device; only launch/dryrun.py (and the dedicated
dry-run subprocess tests) use 512 placeholder devices.
"""

import jax
import pytest

SLOW_MODULES = ("test_dryrun", "test_models_smoke")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: >1-min tests (dry-run lowering, model-family "
        'smoke); deselect with -m "not slow"')


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.module.__name__ in SLOW_MODULES:
            item.add_marker(pytest.mark.slow)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
