import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, jax
from repro import configs
from repro.launch import mesh as mesh_lib, specs, hlo_cost
from repro.sharding import context as shctx, policy as policy_lib

arch, shape_name = sys.argv[1], sys.argv[2]
fsdp = "--no-fsdp" not in sys.argv
cfg = configs.get_config(arch)
shape = configs.INPUT_SHAPES[shape_name]
mesh = mesh_lib.make_production_mesh()
policy = policy_lib.make_policy(mesh, fsdp=fsdp)
step = specs.make_step_fn(cfg, shape)
args, _ = specs.input_specs(cfg, shape)
in_sh, out_sh, donate = specs.step_shardings(cfg, shape, policy)
with mesh, shctx.use_policy(policy):
    compiled = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=donate).lower(*args).compile()
cost = hlo_cost.module_cost(compiled.as_text(), breakdown=True)
print(f"== {arch} x {shape_name} fsdp={fsdp}: traffic={cost.traffic_bytes/2**30:.1f}GiB "
      f"flops={cost.flops:.2e} coll={cost.collective_bytes/2**30:.2f}GiB")
print("-- top traffic by op_name --")
for k, v in sorted(cost.traffic_by_meta.items(), key=lambda kv: -kv[1])[:14]:
    print(f"  {v/2**30:9.2f} GiB  {k}")
print("-- top collectives by op_name --")
for k, v in sorted(cost.collective_by_meta.items(), key=lambda kv: -kv[1])[:10]:
    print(f"  {v/2**30:9.2f} GiB  {k}")
print("-- top flops by op_name --")
for k, v in sorted(cost.flops_by_meta.items(), key=lambda kv: -kv[1])[:8]:
    print(f"  {v:9.2e}      {k}")
