"""Per-assigned-architecture smoke tests (deliverable f).

For each of the 10 architectures: instantiate the REDUCED same-family
variant and run one forward/train step + one prefill/decode step on CPU,
asserting output shapes and the absence of NaNs.
"""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import model as model_lib
from repro.training import data as data_lib
from repro.training import optimizer as opt_lib, train_step as ts_lib

B, S = 2, 24


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jnp.concatenate(
        [tokens[:, 1:], -jnp.ones((B, 1), jnp.int32)], axis=1)
    batch = {"tokens": tokens, "labels": labels}
    return data_lib.add_modality_stub(batch, cfg)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_train_step(arch, rng_key):
    cfg = configs.get_smoke_config(arch)
    params = model_lib.init_params(rng_key, cfg)
    batch = _batch(cfg, rng_key)

    loss, metrics = model_lib.lm_loss(params, cfg, batch)
    assert loss.shape == ()
    assert not jnp.isnan(loss), arch
    assert 1.0 < float(loss) < 20.0, (arch, float(loss))

    opt = opt_lib.make_optimizer("adamw", 1e-3)
    step = jax.jit(ts_lib.make_train_step(cfg, opt, remat=False))
    params2, _, m2 = step(params, opt.init(params), batch)
    assert not jnp.isnan(m2["loss"])
    assert float(m2["grad_norm"]) > 0.0
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b: bool(jnp.any(a.astype(jnp.float32)
                                  != b.astype(jnp.float32))),
        params, params2)
    assert any(jax.tree.leaves(moved)), arch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_decode_step(arch, rng_key):
    cfg = configs.get_smoke_config(arch)
    params = model_lib.init_params(rng_key, cfg)
    batch = _batch(cfg, rng_key)
    max_len = S + 8 + (cfg.num_patch_tokens
                       if cfg.frontend == "vision" else 0)
    cache, last_logits = model_lib.prefill(params, cfg, batch, max_len)
    assert last_logits.shape == (B, cfg.padded_vocab)
    assert not jnp.isnan(last_logits).any(), arch
    # padded vocab positions masked (when padding exists)
    if cfg.padded_vocab > cfg.vocab_size:
        assert float(last_logits[:, cfg.vocab_size:].max()) < -1e20

    tok = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
    nt, logits, cache = model_lib.decode_step(params, cfg, cache, tok)
    assert nt.shape == (B, 1)
    assert logits.shape == (B, cfg.padded_vocab)
    assert not jnp.isnan(logits).any(), arch
    assert (nt >= 0).all() and (nt < cfg.vocab_size).all()
