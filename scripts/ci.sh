#!/usr/bin/env bash
# CI entry point (also runnable locally): the fast lane first for quick
# signal, then the full tier-1 suite.
#
#   scripts/ci.sh          # fast lane + full tier-1
#   CI_FAST_ONLY=1 scripts/ci.sh   # fast lane only
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== fast lane (-m 'not slow') =="
scripts/run_tier1.sh -m "not slow"

if [[ "${CI_FAST_ONLY:-0}" != "1" ]]; then
  echo "== full tier-1 =="
  scripts/run_tier1.sh
fi
