"""Multi-replica serving: R independent engines behind the Router.

``ReplicatedEngine`` owns R ``ServingEngine`` instances — each with
its OWN KV pool, ``BlockAllocator``, ``PrefixCache`` and continuous
decode loop (nothing is shared but the model parameters, the policy
object and the observability bundle) — and a front-end
``repro.serving.router.Router`` that places every arriving request on
exactly one replica.

Placement protocol (the engine half of the parity discipline with
``repro.core.simulator.simulate_replicated``):

  1. requests are sorted by arrival (stable, as every serve loop does);
  2. for each request, the front-end computes the router inputs the
     simulator computes for its twin task — ``u`` from the offline
     profile's predictor (the engine's own ``_to_sim_task`` recipe) and
     ``need`` from the paged admission gate's reservation formula
     (``blocks_for_tokens(input_bucket + cap - 1, block_size)``);
  3. ``Router.place`` scores per-replica ``ReplicaView``s built from
     placement bookkeeping (placed counts, running ``u_load`` sums,
     pool capacities).  On all-at-t0 traces every placement precedes
     any engine work, so these views are bitwise identical to the
     simulator's live views and the decisions parity-match;
  4. a ``route`` event ``{replica, score, policy}`` fires per placement
     (R > 1 only — R=1 traces stay byte-identical to single-engine);
  5. each replica then serves its group with ``obs.replica_label`` set
     (R > 1 only), so every event/counter/SLO observation lands in that
     replica's parity substream
     (``TraceRecorder.parity_events(replica=r)``).

Device mapping is metadata, not magic: ``replica_devices()`` exposes
``repro.launch.mesh.replica_groups`` — contiguous data-parallel device
slices when the host has >= R devices, shared-device (thread-level)
replicas otherwise (the CPU case: R engine instances time-share one
host device, which is exactly what this in-process front-end models).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.kvcache import blocks_for_tokens
from repro.obs import Observability

from .engine import Request, ServingEngine
from .router import ReplicaView, Router


class ReplicatedEngine:
    """R independent ``ServingEngine`` replicas behind one ``Router``.

    ``engine_kwargs`` forward verbatim to every replica's
    ``ServingEngine`` constructor (equal pools — ``kv_num_blocks`` is
    PER replica, as in ``simulate_replicated``).
    """

    def __init__(self, params, cfg, policy, profile, *,
                 replicas: int = 1,
                 router: Optional[Router] = None,
                 obs: Optional[Observability] = None,
                 **engine_kwargs):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.R = int(replicas)
        self.router = router if router is not None else Router(self.R)
        if self.router.R != self.R:
            raise ValueError(f"router expects R={self.router.R}, got "
                             f"replicas={self.R}")
        self.obs = obs
        self.profile = profile
        self.engines = [ServingEngine(params, cfg, policy, profile,
                                      obs=obs, **engine_kwargs)
                        for _ in range(self.R)]
        self.placements: List[int] = []

    # ------------------------------------------------------------------
    def replica_devices(self) -> List[list]:
        """Device group per replica (``launch.mesh.replica_groups``)."""
        from repro.launch.mesh import replica_groups
        return replica_groups(self.R)

    def _need(self, req: Request) -> int:
        """The arrival's worst-case block reservation — the SAME
        formula the paged admission gate applies (0 when unpaged)."""
        eng = self.engines[0]
        if eng.kv != "paged":
            return 0
        return blocks_for_tokens(eng.input_bucket + eng._cap(req) - 1,
                                 eng.kv_block_size)

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence[Request]) -> Dict:
        """Place every request, then serve each replica's group.

        Returns a pool-level result dict wrapping the per-replica
        ``ServingEngine`` results (``None`` for a replica that received
        no requests — an idle replica runs nothing).
        """
        reqs = sorted(requests, key=lambda q: q.arrival)
        label = self.obs is not None and self.R > 1
        placed: List[List[Request]] = [[] for _ in range(self.R)]
        u_placed: List[List[float]] = [[] for _ in range(self.R)]
        placements: List[int] = []
        for req in reqs:
            # router inputs, computed exactly as the simulator twin
            # computes them for its SimTask
            u = float(max(self.profile.predictor.score(req.text), 0.0))
            need = self._need(req)
            views = [ReplicaView(
                replica=r,
                queued=len(placed[r]),
                active=0,
                free_blocks=(self.engines[r].kv_num_blocks
                             if self.engines[r].kv == "paged" else 0),
                num_blocks=(self.engines[r].kv_num_blocks
                            if self.engines[r].kv == "paged" else 0),
                u_load=float(sum(u_placed[r])),
                is_bulk=self.router.is_bulk(r))
                for r in range(self.R)]
            d = self.router.place(views, u=u, cls=req.traffic_class,
                                  need=need)
            placements.append(d.replica)
            if label:
                self.obs.event("route", req.arrival, req.task_id, None,
                               replica=d.replica, score=d.score,
                               policy=d.policy)
            placed[d.replica].append(req)
            u_placed[d.replica].append(u)
        self.placements = placements

        results: List[Optional[Dict]] = []
        for r in range(self.R):
            if not placed[r]:
                results.append(None)
                continue
            if label:
                self.obs.replica_label = r
            try:
                results.append(self.engines[r].serve(placed[r]))
            finally:
                if self.obs is not None:
                    self.obs.replica_label = None
        return {
            "mode": "replicated",
            "replicas": self.R,
            "router_policy": self.router.policy,
            "n_tasks": len(reqs),
            "placements": placements,
            "placement_counts": [len(g) for g in placed],
            "per_replica": results,
            "completion_orders": [
                res["completion_order"] if res is not None else []
                for res in results],
            "rejected_for_memory": sum(
                res["rejected_for_memory"] for res in results
                if res is not None),
            "fallback_events": sum(
                res["fallback_events"] for res in results
                if res is not None),
        }
