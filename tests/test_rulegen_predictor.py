"""RULEGEN rules on the paper's Table I examples + predictor learning."""

import numpy as np
import pytest

from repro.core import datagen, personas, predictor, rulegen, scheduler

TABLE_I = {
    "structural": "John saw a boy in the park with a telescope.",
    "syntactic": "Rice flies like sand.",
    "semantic": "What's the best way to deal with bats?",
    "vague": "Tell me about the history of art.",
    "open_ended": ("What are the causes and consequences of poverty in "
                   "developing countries?"),
    "multi_part": ("How do cats and dogs differ in behavior, diet, and "
                   "social interaction?"),
}


@pytest.mark.parametrize("utype", list(TABLE_I))
def test_table1_examples_fire_their_rule(utype):
    scores = rulegen.rulegen(TABLE_I[utype])
    idx = rulegen.UNCERTAINTY_TYPES.index(utype)
    assert scores[idx] > 0, (utype, scores)


def test_plain_sentence_scores_low():
    plain = rulegen.rulegen("i had pasta for dinner yesterday.")
    loaded = rulegen.rulegen(TABLE_I["open_ended"])
    assert plain.sum() < loaded.sum()


def test_single_rule_fallback_is_input_length():
    text = "the cat sat on the mat."
    r = rulegen.rulegen(text)
    if r.max() <= 0:
        assert rulegen.single_rule_score(text) == rulegen.input_length(text)


def test_features_shape():
    f = rulegen.features("hello world")
    assert f.shape == (rulegen.FEATURE_DIM,)
    assert np.isfinite(f).all()


# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def corpus():
    tasks = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["large"], 1200, seed=0)
    return datagen.train_test_split(tasks)


def test_predictor_learns_output_length(corpus):
    train, test = corpus
    pred = predictor.train_predictor(train, "dialogpt", epochs=60, seed=0)
    assert pred.train_losses[-1] < 0.3 * pred.train_losses[0]
    scores = pred.score_batch([t.text for t in test])
    truth = np.array([t.out_lens["dialogpt"] for t in test], np.float32)
    corr = np.corrcoef(scores, truth)[0, 1]
    assert corr > 0.85, corr  # paper Fig. 2d: "almost linearly dependent"


def test_weighted_rule_beats_single_rule(corpus):
    """Fig. 2 ordering: weighted-rule correlation >= single-rule."""
    train, test = corpus
    w = predictor.fit_weighted_rule(train, "dialogpt")
    truth = np.array([t.out_lens["dialogpt"] for t in test], np.float32)
    single = np.array([rulegen.single_rule_score(t.text) for t in test])
    weighted = np.array(
        [float(np.r_[rulegen.features(t.text), 1.0] @ w) for t in test])
    c_single = np.corrcoef(single, truth)[0, 1]
    c_weighted = np.corrcoef(weighted, truth)[0, 1]
    assert c_weighted >= c_single - 0.02, (c_single, c_weighted)


def test_offline_profile_tau_is_quantile(corpus):
    train, _ = corpus
    persona = personas.get_persona("bart")
    prof = scheduler.offline_profile(train, persona, epochs=15, k=0.9)
    scores = prof.predictor.score_batch([t.text for t in train])
    frac_above = float(np.mean(scores > prof.tau))
    assert 0.05 < frac_above < 0.15
