"""RecurrentGemma-9B — Griffin: RG-LRU + local attention 1:2
[arXiv:2402.19427].

Assignment row: [hybrid] 38L d_model=4096 16H (GQA kv=1 = MQA)
d_ff=12288, vocab=256000.  Block pattern (rec, rec, attn_local) with a
2048-token local-attention window; recurrent state + windowed KV are both
bounded, so long_500k is eligible.  38 = 12x3 + 2 -> 12 scanned
superblocks plus a (rec, rec) tail.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    vocab_size=256000,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    mlp_act="swiglu",
    block_pattern=("rec", "rec", "attn_local"),
    lru_width=4096,
    local_window=2048,
    ssm_conv_width=4,
    source="arXiv:2402.19427 (Griffin / RecurrentGemma)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke", family="hybrid", num_layers=3,
        d_model=256, vocab_size=2048, num_heads=8, num_kv_heads=1,
        head_dim=32, d_ff=512, mlp_act="swiglu",
        block_pattern=("rec", "rec", "attn_local"), lru_width=256,
        local_window=64, source=CONFIG.source)
