"""Deterministic fault injection + failure-aware serving machinery.

This module is the single source of truth for the failure story shared
VERBATIM by ``ReplicatedEngine`` and ``simulate_replicated(faults=...)``:
the same ``FaultPlan`` drives both sides at the same decision points so
every new counter and trace event stays bit-for-bit parity-comparable.

Fault model
-----------

* **Crash** (``CrashFault``): a replica dies when its *local* decode
  ``step`` counter — the shared engine/sim iteration coordinate stamped
  on every trace event — reaches ``at_step``.  In-flight requests free
  their KV blocks (``BlockAllocator.free_all``), every unfinished
  request on the replica becomes a *survivor* and is re-dispatched
  through the ``Router`` with capped exponential backoff and a bounded
  retry budget (or dead-lettered when the budget/eligible set is
  exhausted).  A crash fires at most once per replica.
* **Straggler** (``SlowFault``): the replica's per-step latency is
  multiplied by ``factor`` over a step range.  Only the virtual clock is
  affected — wall/time fields are excluded from ``parity_events()`` by
  construction, so slowdowns are parity-safe.
* **Transient dispatch error** (``TransientFault``): the N-th placement
  decision fails once; the request retries against the remaining
  replicas and the breaker records a consecutive failure.

Coordinates are chosen for determinism, *not* wall time: crashes key on
the replica-local step counter, recovery and breaker cooldown key on the
pool-level placement counter.  Both counters advance identically in the
engine and the simulator.

Circuit breaker
---------------

Per-replica health is ``closed`` → (crash / ``failure_threshold``
consecutive transient failures) → ``open`` → after
``cooldown_placements`` further pool placements → ``half_open`` (one
probe placement allowed) → ``closed`` on success / re-``open`` on a dead
probe.  ``ReplicaView.health`` carries the state into ``Router.place``;
all policies skip ``open`` replicas.  When every eligible replica is
open the request is *dead-lettered* (counted, never hung).

Shedding order
--------------

``shed_pass`` runs before admission on both sides: (1) doomed-request
shedding — queued requests already past their class deadline
(``arrival + e2e`` target) time out; (2) under queue pressure
(``len(queue) > ShedPolicy.queue_depth``) bulk classes shed first, then
the highest-``u`` requests predicted to miss their deadline — the
paper's uncertainty signal as a graceful-degradation mechanism.

Everything here is pure host-side bookkeeping: no jax, no engine
imports (mirroring ``router.py``), so the simulator exercises identical
code without touching the device path.
"""

from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .router import NoEligibleReplica, ReplicaView, Router

__all__ = [
    "CrashFault", "SlowFault", "TransientFault", "RetryPolicy",
    "ShedPolicy", "ReplicaFaults", "FaultPlan", "CircuitBreaker",
    "FaultCoordinator", "shed_pass", "deadline_of", "random_plan",
]


# ---------------------------------------------------------------------------
# fault declarations


@dataclasses.dataclass(frozen=True)
class CrashFault:
    """Replica ``replica`` dies when its local decode-step counter
    reaches ``at_step``.  It becomes probe-eligible again (breaker
    half-open) after ``recover_after_placements`` further pool
    placement decisions (``None`` = stays down forever)."""
    replica: int
    at_step: int
    recover_after_placements: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class SlowFault:
    """Multiply per-step latency by ``factor`` for local steps in
    ``[from_step, until_step)``."""
    replica: int
    from_step: int
    until_step: int
    factor: float = 2.0


@dataclasses.dataclass(frozen=True)
class TransientFault:
    """The placement whose pool-level index equals ``at_placement``
    fails once (only when the chosen replica matches ``replica``, any
    replica when ``None``); the request retries elsewhere."""
    at_placement: int
    replica: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with deterministic seeded jitter.

    ``backoff_s(task_id, attempt)`` is a pure function of the seed and
    the (task, attempt) pair — no RNG state, no wall clock — so both
    sides stamp identical backoff fields on ``retry`` events."""
    budget: int = 2
    base_s: float = 0.5
    cap_s: float = 8.0
    jitter_frac: float = 0.25
    seed: int = 0

    def backoff_s(self, task_id, attempt: int) -> float:
        base = min(self.cap_s, self.base_s * (2.0 ** max(0, attempt - 1)))
        mix = zlib.crc32(
            f"{self.seed}:{task_id}:{attempt}".encode()) & 0xFFFFFFFF
        jitter = self.jitter_frac * (mix / float(0x100000000))
        return base * (1.0 + jitter)


@dataclasses.dataclass(frozen=True)
class ShedPolicy:
    """Uncertainty-aware load shedding under sustained queue pressure.

    When the admission queue exceeds ``queue_depth``, shed bulk-class
    requests first (queue order), then the highest-``u`` requests whose
    predicted finish ``now + u * eta_s`` misses their deadline."""
    queue_depth: int = 64
    bulk_classes: Tuple[str, ...] = ()
    eta_s: float = 0.05


@dataclasses.dataclass(frozen=True)
class ReplicaFaults:
    """The per-replica slice of a ``FaultPlan`` threaded into one
    serve/sim loop (``ServingEngine(faults=...)`` / ``_ReplicaSim``)."""
    crash_at_step: Optional[int] = None
    slowdowns: Tuple[SlowFault, ...] = ()
    shed: Optional[ShedPolicy] = None
    deadlines: bool = False

    def slow_factor(self, step: int) -> float:
        f = 1.0
        for s in self.slowdowns:
            if s.from_step <= step < s.until_step:
                f *= s.factor
        return f


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, fully deterministic fault schedule for an R-replica
    pool plus the failure-handling policy knobs."""
    crashes: Tuple[CrashFault, ...] = ()
    slowdowns: Tuple[SlowFault, ...] = ()
    transients: Tuple[TransientFault, ...] = ()
    retry: RetryPolicy = RetryPolicy()
    shed: Optional[ShedPolicy] = None
    deadlines: bool = False
    failover: bool = True
    health_gating: bool = True
    failure_threshold: int = 3
    cooldown_placements: int = 4

    def validate(self, R: int) -> None:
        seen = set()
        for c in self.crashes:
            if not 0 <= c.replica < R:
                raise ValueError(f"crash replica {c.replica} out of "
                                 f"range for R={R}")
            if c.replica in seen:
                raise ValueError(
                    f"multiple crashes for replica {c.replica}; at most "
                    f"one crash per replica is supported")
            seen.add(c.replica)
            if c.at_step < 0:
                raise ValueError("crash at_step must be >= 0")
        for s in self.slowdowns:
            if not 0 <= s.replica < R:
                raise ValueError(f"slowdown replica {s.replica} out of "
                                 f"range for R={R}")
            if s.factor <= 0.0:
                raise ValueError("slowdown factor must be > 0")
        if self.retry.budget < 0:
            raise ValueError("retry budget must be >= 0")

    def crash_for(self, r: int) -> Optional[CrashFault]:
        for c in self.crashes:
            if c.replica == r:
                return c
        return None

    def for_replica(self, r: int) -> ReplicaFaults:
        c = self.crash_for(r)
        return ReplicaFaults(
            crash_at_step=None if c is None else c.at_step,
            slowdowns=tuple(s for s in self.slowdowns if s.replica == r),
            shed=self.shed, deadlines=self.deadlines)


def random_plan(rng, R: int, *, max_step: int = 32,
                seed: int = 0) -> FaultPlan:
    """A random-but-seeded ``FaultPlan`` for property tests: 0..R-1
    crashes at random steps, optional slowdowns/transients."""
    crashes = tuple(
        CrashFault(replica=int(r), at_step=int(rng.integers(0, max_step)),
                   recover_after_placements=(
                       None if rng.random() < 0.5
                       else int(rng.integers(1, 8))))
        for r in sorted(rng.choice(R, size=int(rng.integers(0, R)),
                                   replace=False)))
    slowdowns = tuple(
        SlowFault(replica=int(rng.integers(0, R)),
                  from_step=int(rng.integers(0, max_step)),
                  until_step=int(rng.integers(0, max_step)) + 1,
                  factor=float(1.0 + rng.random() * 3.0))
        for _ in range(int(rng.integers(0, 3))))
    transients = tuple(
        TransientFault(at_placement=int(rng.integers(0, 16)))
        for _ in range(int(rng.integers(0, 3))))
    return FaultPlan(
        crashes=crashes, slowdowns=slowdowns, transients=transients,
        retry=RetryPolicy(budget=int(rng.integers(0, 4)), seed=seed),
        shed=(None if rng.random() < 0.5
              else ShedPolicy(queue_depth=int(rng.integers(1, 8)))),
        deadlines=bool(rng.random() < 0.5),
        failover=bool(rng.random() < 0.8),
        health_gating=bool(rng.random() < 0.8))


# ---------------------------------------------------------------------------
# deadline + shed pass (shared by both serve loops)


def _task_cls(t) -> Optional[str]:
    return getattr(getattr(t, "task", None), "traffic_class", None)


def _task_id(t):
    return getattr(getattr(t, "task", None), "task_id", None)


def deadline_of(arrival: float, cls: Optional[str], slo) -> float:
    """Absolute deadline = arrival + the class's e2e SLO target.

    ``inf`` (no SLO / unknown class without a default target) means the
    request never times out; a negative target (e.g. the
    judgment-invariant ``-1.0`` used by parity tests) dooms it at the
    first pre-admission check regardless of which clock — wall-derived
    engine or model-time sim — is asking."""
    if slo is None:
        return math.inf
    spec = slo.classes.get(slo.resolve(cls or ""))
    if spec is None:
        return math.inf
    return arrival + spec.target("e2e")


def shed_pass(queue: List, *, now: float, step: int,
              rf: Optional[ReplicaFaults], slo, obs):
    """Doomed-request timeouts + pressure shedding, run identically at
    the top of both serve loops.  Returns ``(kept, timed_out, shed)``;
    emits ``timeout``/``shed`` events, ``faults.*`` counters and an
    ``inf`` e2e SLO observation (a recorded miss against any finite
    target) for every dropped request."""
    if rf is None:
        return queue, [], []
    timed: List = []
    kept: List = []
    if rf.deadlines:
        for t in queue:
            if now > deadline_of(t.r, _task_cls(t), slo):
                timed.append(t)
            else:
                kept.append(t)
    else:
        kept = list(queue)
    shed: List = []
    pol = rf.shed
    if pol is not None and len(kept) > pol.queue_depth:
        over = len(kept) - pol.queue_depth
        victims: List = []
        if pol.bulk_classes:
            victims = [t for t in kept
                       if _task_cls(t) in pol.bulk_classes][:over]
        if len(victims) < over:
            vict_ids = {id(t) for t in victims}
            miss = [t for t in kept
                    if id(t) not in vict_ids
                    and now + t.u * pol.eta_s >
                    deadline_of(t.r, _task_cls(t), slo)]
            miss.sort(key=lambda t: (-t.u, _task_id(t)))
            victims += miss[:over - len(victims)]
        vict_ids = {id(t) for t in victims}
        shed = victims
        kept = [t for t in kept if id(t) not in vict_ids]
    if obs is not None:
        for t in timed:
            cls = _task_cls(t)
            obs.event("timeout", now, _task_id(t), step,
                      **({"cls": cls} if cls else {}))
            obs.inc("faults.timed_out")
            obs.slo_observe("e2e", cls or "", now, math.inf)
        for t in shed:
            cls = _task_cls(t)
            reason = "bulk" if cls in (pol.bulk_classes or ()) else "miss"
            obs.event("shed", now, _task_id(t), step, reason=reason,
                      **({"cls": cls} if cls else {}))
            obs.inc("faults.shed")
            obs.slo_observe("e2e", cls or "", now, math.inf)
    return kept, timed, shed


# ---------------------------------------------------------------------------
# circuit breaker


class CircuitBreaker:
    """Per-replica closed/open/half-open routing health, driven by the
    deterministic pool placement counter (never wall time)."""

    def __init__(self, R: int, *, failure_threshold: int = 3,
                 cooldown_placements: int = 4):
        self.R = R
        self.failure_threshold = failure_threshold
        self.cooldown_placements = cooldown_placements
        self.state: List[str] = ["closed"] * R
        self._consecutive = [0] * R
        self._opened_at = [0] * R

    def mark_down(self, r: int, placements: int) -> None:
        self.state[r] = "open"
        self._opened_at[r] = placements

    def record_failure(self, r: int, placements: int) -> None:
        self._consecutive[r] += 1
        if self._consecutive[r] >= self.failure_threshold:
            self.mark_down(r, placements)

    def record_success(self, r: int) -> None:
        self._consecutive[r] = 0

    def close(self, r: int) -> None:
        self.state[r] = "closed"
        self._consecutive[r] = 0

    def health(self, r: int, placements: int) -> str:
        if self.state[r] != "open":
            return "closed"
        if placements - self._opened_at[r] >= self.cooldown_placements:
            return "half_open"
        return "open"


# ---------------------------------------------------------------------------
# the shared coordinator


@dataclasses.dataclass(frozen=True)
class _Survivor:
    """Side-agnostic descriptor for an unfinished request collected off
    a crashed replica; ``payload`` is the side's native object (SimTask
    or Request) handed back to the driver for delivery."""
    task_id: object
    u: float
    cls: Optional[str]
    arrival: float
    need: int
    payload: object


class FaultCoordinator:
    """The pool-level failure state machine, instantiated fresh per run
    and driven through the SAME call sequence by ``ReplicatedEngine``
    and ``simulate_replicated`` — placement gating, transient faults,
    half-open probes, crash bookkeeping, retry/backoff/failover and
    dead-lettering all live here so the two sides cannot drift."""

    def __init__(self, plan: FaultPlan, R: int, router: Router, obs, *,
                 kv_num_blocks: int = 0):
        plan.validate(R)
        self.plan = plan
        self.R = R
        self.router = router
        self.obs = obs
        self.kv_num_blocks = kv_num_blocks
        self.breaker = CircuitBreaker(
            R, failure_threshold=plan.failure_threshold,
            cooldown_placements=plan.cooldown_placements)
        self.placements = 0
        self.attempts: Dict[object, int] = {}
        self.retries = 0
        self.failovers = 0
        self.dead_lettered = 0
        self.dead_letter_ids: List = []
        self.failover_placements: List[Tuple] = []
        self.placed_count = [0] * R
        self.u_sum = [0.0] * R
        self.crashed = [False] * R
        self._crash_placement = [0] * R
        self._transients_fired: Set[int] = set()

    # -- health / functional state -------------------------------------

    def health(self, r: int) -> str:
        if not self.plan.health_gating:
            return "closed"
        return self.breaker.health(r, self.placements)

    def functional(self, r: int) -> bool:
        if not self.crashed[r]:
            return True
        c = self.plan.crash_for(r)
        if c is None or c.recover_after_placements is None:
            return False
        return (self.placements - self._crash_placement[r]
                >= c.recover_after_placements)

    def should_crash(self, r: int, step: int) -> bool:
        c = self.plan.crash_for(r)
        return (c is not None and not self.crashed[r]
                and step >= c.at_step)

    def note_crash(self, r: int) -> None:
        self.crashed[r] = True
        self._crash_placement[r] = self.placements
        self.breaker.mark_down(r, self.placements)

    # -- placement -----------------------------------------------------

    def ledger_views(self) -> List[ReplicaView]:
        """Deterministic assignment-ledger views (counts of requests
        ever assigned, full KV pool) — the same bookkeeping the engine
        front-end places with, used by BOTH sides for failover
        re-dispatch so the decision is temporally well-defined."""
        return [ReplicaView(
            replica=r, queued=self.placed_count[r], active=0,
            free_blocks=self.kv_num_blocks,
            num_blocks=self.kv_num_blocks, u_load=self.u_sum[r],
            is_bulk=r in self.router.bulk_replicas)
            for r in range(self.R)]

    def place(self, views: Sequence[ReplicaView], *, task_id, u: float,
              cls: Optional[str], arrival: float,
              need: int) -> Optional[int]:
        """Health-gated placement with transient faults and half-open
        probes.  Emits the ``route`` event itself; returns the target
        replica or ``None`` when the request dead-letters (already
        counted + emitted)."""
        excluded: Set[int] = set()
        while True:
            hviews = []
            for v in views:
                h = ("open" if v.replica in excluded
                     else self.health(v.replica))
                if h != v.health:
                    v = dataclasses.replace(v, health=h)
                hviews.append(v)
            try:
                d = self.router.place(hviews, u=u, cls=cls, need=need)
            except NoEligibleReplica:
                self._dead_letter(task_id, cls, arrival,
                                  reason="no_replica")
                return None
            r = d.replica
            if self._transient_fires(r):
                self.breaker.record_failure(r, self.placements)
                if not self._note_retry(task_id, cls, arrival,
                                        reason="transient"):
                    return None
                excluded.add(r)
                continue
            if not self.functional(r):
                # dead probe (gating on) or dispatch to a dead replica
                # (gating off): the breaker (re)opens and the request
                # retries against the remaining replicas
                self.breaker.mark_down(r, self.placements)
                if not self._note_retry(task_id, cls, arrival,
                                        reason="down"):
                    return None
                excluded.add(r)
                continue
            if self.breaker.state[r] == "open":
                # functional again: the half-open probe succeeded
                self.breaker.close(r)
                if self.obs is not None:
                    self.obs.event("replica_up", arrival, None, None,
                                   replica=r)
            self.breaker.record_success(r)
            if self.obs is not None:
                self.obs.event("route", arrival, task_id, None,
                               replica=r, score=d.score, policy=d.policy)
            self.placements += 1
            self.placed_count[r] += 1
            self.u_sum[r] += u
            return r

    def _transient_fires(self, r: int) -> bool:
        for i, tf in enumerate(self.plan.transients):
            if (i not in self._transients_fired
                    and tf.at_placement == self.placements
                    and (tf.replica is None or tf.replica == r)):
                self._transients_fired.add(i)
                return True
        return False

    # -- retry / failover / dead-letter --------------------------------

    def _note_retry(self, task_id, cls, arrival, *, reason: str) -> bool:
        a = self.attempts.get(task_id, 0) + 1
        if not self.plan.failover or a > self.plan.retry.budget:
            self._dead_letter(task_id, cls, arrival, reason=reason)
            return False
        self.attempts[task_id] = a
        self.retries += 1
        if self.obs is not None:
            self.obs.event(
                "retry", arrival, task_id, None, attempt=a,
                reason=reason,
                backoff_s=self.plan.retry.backoff_s(task_id, a))
            self.obs.inc("faults.retries")
        return True

    def _dead_letter(self, task_id, cls, arrival, *,
                     reason: str) -> None:
        self.dead_lettered += 1
        self.dead_letter_ids.append(task_id)
        if self.obs is not None:
            self.obs.event("dead_letter", arrival, task_id, None,
                           reason=reason, **({"cls": cls} if cls else {}))
            self.obs.inc("faults.dead_lettered")
            self.obs.slo_observe("e2e", cls or "", arrival, math.inf)

    def redispatch(self, survivors: Sequence[_Survivor], *,
                   from_replica: int) -> List[Tuple[object, int]]:
        """Retry/backoff + failover for the unfinished requests of a
        crashed replica.  Returns ``[(payload, target_replica), ...]``
        in deterministic (arrival, task_id) order for the driver to
        deliver; budget-exhausted or all-down requests dead-letter."""
        for s in survivors:
            self.placed_count[from_replica] -= 1
            self.u_sum[from_replica] -= s.u
        out: List[Tuple[object, int]] = []
        for s in sorted(survivors, key=lambda s: (s.arrival,
                                                  str(s.task_id))):
            if not self._note_retry(s.task_id, s.cls, s.arrival,
                                    reason="crash"):
                continue
            tgt = self.place(self.ledger_views(), task_id=s.task_id,
                             u=s.u, cls=s.cls, arrival=s.arrival,
                             need=s.need)
            if tgt is None:
                continue
            self.failovers += 1
            self.failover_placements.append(
                (s.task_id, from_replica, tgt))
            if self.obs is not None:
                self.obs.event("failover", s.arrival, s.task_id, None,
                               src=from_replica, dst=tgt,
                               attempt=self.attempts[s.task_id])
                self.obs.inc("faults.failovers")
            out.append((s.payload, tgt))
        return out

    def survivor(self, *, task_id, u, cls, arrival, need,
                 payload) -> _Survivor:
        return _Survivor(task_id=task_id, u=u, cls=cls, arrival=arrival,
                         need=need, payload=payload)

    def counters(self) -> Dict[str, int]:
        return {"retries": self.retries, "failovers": self.failovers,
                "dead_lettered": self.dead_lettered}
