"""LLaVA-NeXT (Mistral-7B backbone) — anyres VLM
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

Assignment row: [vlm] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000.  The ViT/SigLIP frontend is a STUB per the assignment
carve-out: input_specs() provides precomputed anyres patch embeddings
(num_patch_tokens=2880, the anyres maximum) which the trainable
mlp2x_gelu projector maps into the LM embedding space.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    vocab_size=32000,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    mlp_act="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision",
    num_patch_tokens=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (LLaVA-NeXT)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b-smoke", family="vlm", num_layers=2,
        d_model=256, vocab_size=2048, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, mlp_act="swiglu", frontend="vision",
        num_patch_tokens=16, source=CONFIG.source)
