"""Router-hardening battery (ISSUE 9).

Acceptance properties:

  * per-policy placement — ``round_robin`` cycles each eligibility
    group independently, ``least_queue`` picks the shallowest queue
    with ties to the lowest replica id, and the ``rtlm`` score is
    monotone increasing in predicted uncertainty and decreasing in
    KV-pool headroom;
  * bulk-slice isolation — over a 500-request flash-crowd trace,
    interactive requests NEVER land on a bulk replica and bulk-class
    requests never leave the slice;
  * engine-vs-sim parity — ``ReplicatedEngine`` and
    ``simulate_replicated`` drive identically-configured ``Router``
    instances over the same workload and produce bit-identical
    placements, route-event streams, per-replica parity event streams,
    metrics counters and SLO parity counters at R in {1, 2, 4} for
    both the fifo and rt-lm scheduling policies;
  * R=1 reduction — the replicated path at R=1 is byte-identical to
    the single-engine / ``simulate_continuous`` stream (no ``route``
    events, no ``replica`` fields, no ``rN.*`` counter mirrors);
  * conservation — every request is placed on exactly one replica
    within its eligibility set, and ``least_queue`` over an all-at-t0
    trace balances placements to within one request (the deterministic
    mirrors of the hypothesis properties in tests/test_properties.py).
"""

import dataclasses
import types

import numpy as np
import pytest

import jax

from repro import configs
from repro.core import datagen, personas, priority as prio
from repro.core import scheduler as sched, simulator, workload
from repro.launch.mesh import replica_groups
from repro.obs import Observability
from repro.obs.slo import SLOSpec
from repro.serving.engine import Request, ServingEngine
from repro.serving.replica import ReplicatedEngine
from repro.serving.router import (ROUTER_POLICIES, ReplicaView,
                                  RouteDecision, Router)

SLOTS = 3
MAX_NEW = 6
BUCKET = 8
BS = 4
BLOCKS = 64                       # per-replica pool (generous: no rejects)
CAPS = [2, 6, 1, 4, 6, 2, 3, 5, 1, 6, 2, 4]
CLS = ["interactive", "batch"] * (len(CAPS) // 2)
# judgment-invariant targets: an empty spec always attains, -1.0 never —
# so slo.* parity counters are deterministic regardless of wall clocks
TARGETS = {"interactive": SLOSpec(),
           "batch": SLOSpec(ttft_s=-1.0, itl_s=-1.0, e2e_s=-1.0,
                            queue_wait_s=-1.0)}


# ---------------------------------------------------------------------------
# pure router unit tests (no jax, no model)
# ---------------------------------------------------------------------------


def _views(*queued, free=32, num=32, u_loads=None, bulk=()):
    return [ReplicaView(replica=r, queued=q, free_blocks=free,
                        num_blocks=num,
                        u_load=(u_loads[r] if u_loads else 0.0),
                        is_bulk=r in bulk)
            for r, q in enumerate(queued)]


def test_round_robin_cycles():
    router = Router(3, "round_robin")
    picks = [router.place(_views(0, 0, 0)).replica for _ in range(7)]
    assert picks == [0, 1, 2, 0, 1, 2, 0]


def test_round_robin_bulk_slice_has_independent_cursor():
    router = Router(4, "round_robin", bulk_replicas=(2, 3),
                    bulk_classes=("batch",))
    v = _views(0, 0, 0, 0, bulk=(2, 3))
    inter = [router.place(v, cls="interactive").replica for _ in range(4)]
    bulk = [router.place(v, cls="batch").replica for _ in range(4)]
    assert inter == [0, 1, 0, 1]
    assert bulk == [2, 3, 2, 3]


def test_least_queue_picks_min_ties_to_lowest_id():
    router = Router(3, "least_queue")
    assert router.place(_views(2, 1, 3)).replica == 1
    d = router.place(_views(2, 1, 1))
    assert d.replica == 1 and d.score == 1.0
    assert router.place(_views(0, 0, 0)).replica == 0   # all-tie -> id 0


def test_rtlm_score_monotone_in_u():
    router = Router(2, "rtlm")
    v = ReplicaView(replica=0, queued=2, free_blocks=8, num_blocks=32,
                    u_load=4.0)
    scores = [router.score(v, u=u, need=3) for u in (0.0, 1.0, 4.0, 16.0)]
    assert scores == sorted(scores)
    assert scores[0] < scores[-1]


def test_rtlm_score_monotone_in_free_blocks():
    router = Router(2, "rtlm")
    scores = [router.score(
        ReplicaView(replica=0, queued=2, free_blocks=f, num_blocks=32,
                    u_load=4.0), u=2.0, need=6)
        for f in (32, 8, 2, 1)]
    assert scores == sorted(scores)           # less headroom, higher cost
    assert scores[0] < scores[-1]


def test_rtlm_steers_away_from_loaded_replica():
    router = Router(2, "rtlm")
    # equal queues, replica 0 carries far more predicted work
    v = _views(2, 2, u_loads=[40.0, 2.0])
    assert router.place(v, u=8.0, need=2).replica == 1
    # equal u_load, replica 1 is memory-tight
    v = [ReplicaView(replica=0, queued=2, free_blocks=30, num_blocks=32),
         ReplicaView(replica=1, queued=2, free_blocks=1, num_blocks=32)]
    assert router.place(v, u=8.0, need=8).replica == 0


def test_rtlm_ties_to_lowest_id():
    router = Router(3, "rtlm")
    assert router.place(_views(1, 1, 1), u=2.0, need=2).replica == 0


def test_admissibility_gate_excludes_undersized_pools():
    router = Router(2, "least_queue")
    v = [ReplicaView(replica=0, queued=0, free_blocks=4, num_blocks=4),
         ReplicaView(replica=1, queued=5, free_blocks=64, num_blocks=64)]
    # need=10 can never fit replica 0's pool -> 1 despite deeper queue
    assert router.place(v, need=10).replica == 1
    # num_blocks == 0 marks an unpaged replica: gate inapplicable
    v[0] = ReplicaView(replica=0, queued=0, free_blocks=0, num_blocks=0)
    assert router.place(v, need=10).replica == 0


def test_place_raises_when_no_replica_is_eligible():
    router = Router(2, "least_queue")
    v = [ReplicaView(replica=0, queued=0, free_blocks=4, num_blocks=4),
         ReplicaView(replica=1, queued=0, free_blocks=4, num_blocks=4)]
    with pytest.raises(ValueError, match="no eligible replica"):
        router.place(v, need=10)


def test_eligibility_sets():
    router = Router(4, "round_robin", bulk_replicas=(3,),
                    bulk_classes=("batch",))
    assert router.eligible("interactive") == [0, 1, 2]
    assert router.eligible("") == [0, 1, 2]
    assert router.eligible("batch") == [3]
    assert Router(4, "round_robin").eligible("batch") == [0, 1, 2, 3]
    assert router.is_bulk(3) and not router.is_bulk(0)


def test_router_validation():
    with pytest.raises(ValueError, match="R must be"):
        Router(0)
    with pytest.raises(ValueError, match="unknown router policy"):
        Router(2, "nope")
    with pytest.raises(ValueError, match="out of range"):
        Router(2, bulk_replicas=(5,))
    with pytest.raises(ValueError, match="covers every replica"):
        Router(2, bulk_replicas=(0, 1))
    with pytest.raises(ValueError, match="u_scale"):
        Router(2, u_scale=0.0)
    with pytest.raises(ValueError, match="expected 3 views"):
        Router(3).place(_views(0, 0))
    assert "rtlm" in ROUTER_POLICIES
    d = RouteDecision(replica=0, score=1.0, policy="rtlm")
    assert d.replica == 0


# ---------------------------------------------------------------------------
# simulator-level: bulk isolation, R=1 reduction, conservation mirrors
# ---------------------------------------------------------------------------

PERSONA = dataclasses.replace(personas.get_persona("bart"),
                              batch_size=SLOTS)
PCFG = sched.PolicyConfig(u_scale=30.0, tau=1e18)
SIM_KW = dict(xi=0.5, per_task_overhead_s=0.01, num_slots=SLOTS,
              kv_block_size=BS, kv_num_blocks=BLOCKS, prompt_len=BUCKET)


def _mk_tasks(n, classes=None, arrivals=None, seed=0):
    rng = np.random.default_rng(seed)
    us = rng.uniform(0.5, 12.0, size=n)
    if arrivals is None:
        arrivals = [0.0] * n
    out = []
    for i in range(n):
        cls = classes[i] if classes else ""
        task = types.SimpleNamespace(task_id=i, traffic_class=cls)
        out.append(prio.SimTask(task=task, u=float(us[i]),
                                r=float(arrivals[i]), d=1e9,
                                input_len=float(BUCKET),
                                true_out_len=1 + int(us[i]) % MAX_NEW))
    return out


def test_bulk_isolation_over_flash_crowd_trace():
    n = 500
    classes_decl = workload.make_traffic_classes({
        "interactive": {"weight": 3.0},
        "batch": {"weight": 1.0, "bulk": True},
    })
    assert workload.bulk_class_names(classes_decl) == ["batch"]
    cls = workload.assign_classes(n, classes_decl, seed=1)
    arrivals = workload.flash_crowd_trace(n, seed=1)
    tasks = _mk_tasks(n, classes=cls, arrivals=arrivals, seed=1)
    router = Router(4, "rtlm", bulk_replicas=(3,),
                    bulk_classes=tuple(workload.bulk_class_names(
                        classes_decl)))
    res = simulator.simulate_replicated(
        tasks, sched.POLICIES["rt-lm"](PERSONA, PCFG), R=4,
        router=router, **SIM_KW)
    assert res.n_tasks == n
    assert len(res.placements) == n
    assert sum(res.placement_counts()) == n
    assert sum(len(r.tasks) for r in res.replicas) == n   # conservation
    for i in range(n):
        if cls[i] == "batch":
            assert res.placements[i] == 3
        else:
            assert res.placements[i] != 3
    # the interactive slice actually spreads (no degenerate pile-up)
    inter_counts = res.placement_counts()[:3]
    assert all(c > 0 for c in inter_counts)


def test_replicated_r1_reduces_to_simulate_continuous():
    policy = sched.POLICIES["rt-lm"](PERSONA, PCFG)
    arrivals = workload.constant_rate_trace(40, 120.0, seed=3)
    single_obs, rep_obs = Observability(), Observability()
    single = simulator.simulate_continuous(
        _mk_tasks(40, arrivals=arrivals, seed=3), policy,
        obs=single_obs, **SIM_KW)
    rep = simulator.simulate_replicated(
        _mk_tasks(40, arrivals=arrivals, seed=3), policy, R=1,
        router=Router(1, "rtlm"), obs=rep_obs, **SIM_KW)
    assert rep.placements == [0] * 40
    assert single.summary() == rep.replicas[0].summary()
    # byte-identical streams: no route events, no replica fields
    se = single_obs.trace.parity_events()
    re_ = rep_obs.trace.parity_events()
    assert se == re_
    assert not any(e[0] == "route" for e in re_)
    assert not any("replica" in dict(e[3]) for e in re_)
    assert single_obs.metrics.counters() == rep_obs.metrics.counters()
    assert not any(k.startswith("r0.")
                   for k in rep_obs.metrics.counters())


def test_least_queue_work_conservation_deterministic():
    """Deterministic mirror of the hypothesis property: all-at-t0
    arrivals under least_queue balance placements to within one, place
    each task exactly once, and every task completes."""
    for n, R in ((17, 4), (24, 3), (5, 2)):
        tasks = _mk_tasks(n, seed=n)
        res = simulator.simulate_replicated(
            tasks, sched.POLICIES["fifo"](PERSONA, PCFG), R=R,
            router=Router(R, "least_queue"), **SIM_KW)
        counts = res.placement_counts()
        assert sum(counts) == n
        assert max(counts) - min(counts) <= 1
        done_ids = sorted(t.task.task_id for r in res.replicas
                          for t in r.tasks)
        assert done_ids == list(range(n))


def test_replicated_rejects_bad_config():
    tasks = _mk_tasks(4)
    policy = sched.POLICIES["fifo"](PERSONA, PCFG)
    with pytest.raises(ValueError, match="R must be"):
        simulator.simulate_replicated(tasks, policy, R=0, **SIM_KW)
    with pytest.raises(ValueError, match="router expects"):
        simulator.simulate_replicated(tasks, policy, R=2,
                                      router=Router(3), **SIM_KW)


def test_replica_groups_cpu_and_sliced():
    # this host: replicas wrap round-robin onto the available devices
    groups = replica_groups(4)
    assert len(groups) == 4
    assert all(len(g) == 1 for g in groups) \
        or all(len(g) >= 1 for g in groups)
    # explicit device lists: contiguous equal slices, leftovers unused
    devs = [f"d{i}" for i in range(8)]
    assert replica_groups(2, devices=devs) == [devs[:4], devs[4:]]
    assert replica_groups(3, devices=devs) == [["d0", "d1"],
                                               ["d2", "d3"],
                                               ["d4", "d5"]]
    assert replica_groups(4, devices=["d0"]) == [["d0"]] * 4
    with pytest.raises(ValueError, match="R must be"):
        replica_groups(0)
    with pytest.raises(RuntimeError, match="no devices"):
        replica_groups(1, devices=[])


# ---------------------------------------------------------------------------
# engine-vs-sim parity at R in {1, 2, 4} x {fifo, rt-lm}
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("starcoder2-3b")
    from repro.models import model as model_lib
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 64, seed=0)
    train, test = datagen.train_test_split(corpus, train_frac=0.5)
    persona = dataclasses.replace(personas.get_persona("bart"),
                                  batch_size=SLOTS)
    profile = sched.offline_profile(train, persona, epochs=15)
    texts = [test[i % 4].text for i in range(len(CAPS))]
    return cfg, params, persona, profile, texts


def _requests(texts):
    return [Request(text=t, arrival=0.0, task_id=i, max_new_tokens=c,
                    traffic_class=CLS[i])
            for i, (t, c) in enumerate(zip(texts, CAPS))]


def _sim_tasks(texts, profile, persona, xi=2.0):
    out = []
    for i, (t, c) in enumerate(zip(texts, CAPS)):
        u = profile.predictor.score(t)
        d = prio.priority_point(0.0, len(t.split()), persona.phi,
                                None, xi=xi)
        out.append(prio.SimTask(
            task=Request(text=t, arrival=0.0, task_id=i,
                         traffic_class=CLS[i]),
            u=float(max(u, 0.0)), r=0.0, d=d,
            input_len=float(len(t.split())), true_out_len=int(c)))
    return out


def _make_obs():
    return Observability(slo=dict(TARGETS))


def _router(R):
    """Identically-configured Router per side — rtlm placement so the
    float scores in the route events are parity-compared too."""
    kw = dict(bulk_replicas=(R - 1,), bulk_classes=("batch",)) \
        if R > 1 else {}
    return Router(R, "rtlm", **kw)


@pytest.fixture(scope="module")
def replicated_run(setup):
    """Memoized replicated serve: (R, policy) -> (engine, result, obs),
    keeping the module's device time bounded."""
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    cache = {}

    def _run(R, policy_name):
        key = (R, policy_name)
        if key not in cache:
            obs = _make_obs()
            eng = ReplicatedEngine(
                params, cfg, sched.POLICIES[policy_name](persona, pcfg),
                profile, replicas=R, router=_router(R), obs=obs,
                input_bucket=BUCKET, max_new_tokens=MAX_NEW,
                mode="continuous", eos_id=-1, kv="paged",
                kv_block_size=BS, num_slots=SLOTS, kv_num_blocks=BLOCKS)
            cache[key] = (eng, eng.serve(_requests(texts)), obs)
        return cache[key]

    return _run


@pytest.mark.parametrize("R", [1, 2, 4])
@pytest.mark.parametrize("policy_name", ["fifo", "rt-lm"])
def test_engine_vs_sim_replicated_parity(setup, replicated_run, R,
                                         policy_name):
    """The tentpole acceptance: engine pool and simulator pool drive
    identically-configured routers over the same workload and produce
    bit-identical placements, route events, per-replica event streams
    and counters."""
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng, res, eobs = replicated_run(R, policy_name)
    sobs = _make_obs()
    sim = simulator.simulate_replicated(
        _sim_tasks(texts, profile, persona),
        sched.POLICIES[policy_name](persona, pcfg), R=R,
        router=_router(R), obs=sobs,
        num_slots=SLOTS, kv_block_size=BS, kv_num_blocks=BLOCKS,
        prompt_len=BUCKET)

    # placements and their counts
    assert res["placements"] == sim.placements
    assert res["placement_counts"] == sim.placement_counts()
    # bulk isolation on the engine side too
    if R > 1:
        for i, cls in enumerate(CLS):
            if cls == "batch":
                assert res["placements"][i] == R - 1
            else:
                assert res["placements"][i] != R - 1

    # route-event subsequences (global order = arrival order, both
    # sides; scores are floats and must match bitwise)
    eroutes = [e for e in eobs.trace.parity_events() if e[0] == "route"]
    sroutes = [e for e in sobs.trace.parity_events() if e[0] == "route"]
    assert eroutes == sroutes
    assert len(eroutes) == (len(CAPS) if R > 1 else 0)

    # per-replica lifecycle streams and completion orders
    for r in range(R):
        assert eobs.trace.parity_events(replica=r) \
            == sobs.trace.parity_events(replica=r), f"replica {r}"
        assert res["completion_orders"][r] \
            == [t.task.task_id for t in sim.replicas[r].tasks]

    # counters (includes the rN.* per-replica mirrors) and SLO splits
    assert eobs.metrics.counters() == sobs.metrics.counters()
    assert eobs.slo.parity_counters() == sobs.slo.parity_counters()
    assert res["rejected_for_memory"] == sum(
        r.kv_rejected for r in sim.replicas)


def test_r1_replicated_byte_identical_to_single_engine(setup,
                                                       replicated_run):
    """R=1 is not 'almost' the single-engine stream — it IS the
    single-engine stream: same events, same counters, no route events,
    no replica fields, no rN.* mirrors."""
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    _, rep_res, rep_obs = replicated_run(1, "rt-lm")
    obs = _make_obs()
    eng = ServingEngine(
        params, cfg, sched.POLICIES["rt-lm"](persona, pcfg), profile,
        input_bucket=BUCKET, max_new_tokens=MAX_NEW, mode="continuous",
        eos_id=-1, kv="paged", kv_block_size=BS, num_slots=SLOTS,
        kv_num_blocks=BLOCKS, obs=obs)
    res = eng.serve(_requests(texts))
    ee = obs.trace.parity_events()
    re_ = rep_obs.trace.parity_events()
    assert ee == re_
    assert not any(e[0] == "route" for e in re_)
    assert not any("replica" in dict(e[3]) for e in re_)
    assert obs.metrics.counters() == rep_obs.metrics.counters()
    assert not any(k.startswith("r0.")
                   for k in rep_obs.metrics.counters())
    assert res["completion_order"] == rep_res["completion_orders"][0]


def test_replicated_engine_validation(setup):
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    policy = sched.POLICIES["fifo"](persona, pcfg)
    with pytest.raises(ValueError, match="replicas must be"):
        ReplicatedEngine(params, cfg, policy, profile, replicas=0)
    with pytest.raises(ValueError, match="router expects"):
        ReplicatedEngine(params, cfg, policy, profile, replicas=2,
                         router=Router(3))
