"""Real serving engine: RT-LM scheduling over the actual JAX model.

This is the end-to-end integration of the paper's ecosystem with the
model substrate: requests (text + arrival time) flow through RULEGEN ->
m_theta -> the UASCHED policy, and the formed batches run REAL batched
prefill/greedy-decode on the JAX engine (tiny configs on CPU; the same
code path jit-lowers for the production mesh).

Adaptation note (DESIGN.md §2): a CPU-only container has no heterogeneous
co-processor, so the "CPU lane" is a *bulk lane* — a second execution
queue drained only when the main lane is idle, emulating resource
isolation of high-uncertainty tasks.  On a TPU pod the same lane maps to
a dedicated low-priority replica slice.

Batches are padded to (C, input_bucket) so the jitted prefill/decode
executables are reused across batches.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import priority as prio
from repro.core import scheduler as sched_lib
from repro.core.personas import Persona
from repro.models import model as model_lib

from . import generate

EOS_ID = 1


def hash_tokenize(text: str, vocab_size: int, max_len: int) -> List[int]:
    """Toy deterministic tokenizer: word -> stable hash id (2..V-1)."""
    toks = []
    for w in text.lower().split()[:max_len]:
        h = 2166136261
        for c in w.encode():
            h = ((h ^ c) * 16777619) & 0xFFFFFFFF
        toks.append(2 + (h % (vocab_size - 2)))
    return toks or [2]


@dataclasses.dataclass
class Request:
    text: str
    arrival: float
    task_id: int
    # filled at completion:
    start: float = -1.0
    finish: float = -1.0
    lane: str = ""
    out_len: int = 0

    @property
    def response_time(self) -> float:
        return self.finish - self.arrival


class ServingEngine:
    """Single-node engine with a pluggable batch-forming policy."""

    def __init__(self, params, cfg, policy: sched_lib.Policy,
                 profile: sched_lib.OfflineProfile, *,
                 input_bucket: int = 32, max_new_tokens: int = 32,
                 xi: float = 2.0):
        self.params = params
        self.cfg = cfg
        self.policy = policy
        self.profile = profile
        self.persona = policy.persona
        self.input_bucket = input_bucket
        self.max_new_tokens = max_new_tokens
        self.xi = xi
        max_len = input_bucket + max_new_tokens + 8
        self._prefill = generate.make_prefill_fn(cfg, max_len)
        self._decode = generate.make_decode_fn(cfg)
        self.scheduler_overhead_s = 0.0

    # ------------------------------------------------------------------
    def _to_sim_task(self, req: Request) -> prio.SimTask:
        t0 = time.perf_counter()
        u = self.profile.predictor.score(req.text)
        d = prio.priority_point(req.arrival, len(req.text.split()),
                                self.persona.phi, None, xi=self.xi)
        self.scheduler_overhead_s += time.perf_counter() - t0
        st = prio.SimTask(task=req, u=float(max(u, 0.0)), r=req.arrival,
                          d=d, input_len=float(len(req.text.split())),
                          true_out_len=0)
        return st

    def _run_batch(self, batch: Sequence[prio.SimTask], lane: str,
                   now: float) -> float:
        """Execute a batch on the JAX engine; returns finish time."""
        C = self.persona.batch_size
        toks = [hash_tokenize(t.task.text, self.cfg.vocab_size,
                              self.input_bucket) for t in batch]
        S = self.input_bucket
        arr = np.zeros((C, S), np.int32)
        for i, seq in enumerate(toks):
            arr[i, S - len(seq):] = seq          # left-pad
        tokens = jnp.asarray(arr)
        t0 = time.perf_counter()
        out_tokens, lengths = generate.generate(
            self.params, self.cfg, {"tokens": tokens},
            max_new_tokens=self.max_new_tokens, eos_id=EOS_ID,
            prefill_fn=self._prefill, decode_fn=self._decode)
        jax.block_until_ready(out_tokens)
        dur = time.perf_counter() - t0
        if lane == "cpu":
            dur *= self.persona.cpu_slowdown   # bulk-lane emulation
        finish = now + dur
        for i, t in enumerate(batch):
            t.start, t.finish, t.lane = now, finish, lane
            t.task.start, t.task.finish, t.task.lane = now, finish, lane
            t.task.out_len = int(lengths[i]) if i < len(lengths) else 0
        return finish

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence[Request]) -> Dict:
        """Run a full trace (virtual-time arrivals, real execution)."""
        pending = sorted(requests, key=lambda r: r.arrival)
        sim_tasks = [self._to_sim_task(r) for r in pending]
        queue: List[prio.SimTask] = []
        bulk: List[prio.SimTask] = []
        done: List[prio.SimTask] = []
        now = 0.0
        i = 0
        n = len(sim_tasks)
        C = self.persona.batch_size
        while len(done) < n:
            while i < n and sim_tasks[i].r <= now + 1e-9:
                queue.append(sim_tasks[i])
                i += 1
            if queue and (len(queue) >= C
                          or now - min(t.r for t in queue) >= self.xi
                          or i >= n):
                t0 = time.perf_counter()
                gpu_b, cpu_b, rest = self.policy.select(list(queue), now)
                self.scheduler_overhead_s += time.perf_counter() - t0
                queue = list(rest)
                bulk.extend(cpu_b)
                if gpu_b:
                    now = self._run_batch(gpu_b[:C], "gpu", now)
                    done.extend(gpu_b[:C])
                    queue.extend(gpu_b[C:])
                    continue
            if bulk and not queue and i >= n:
                batch, bulk = bulk[:C], bulk[C:]
                now = self._run_batch(batch, "cpu", now)
                done.extend(batch)
                continue
            if bulk and not queue:
                batch, bulk = bulk[:C], bulk[C:]
                now = self._run_batch(batch, "cpu", now)
                done.extend(batch)
                continue
            # idle: advance to next arrival / window expiry
            cand = []
            if i < n:
                cand.append(sim_tasks[i].r)
            if queue:
                cand.append(min(t.r for t in queue) + self.xi)
            future = [c for c in cand if c > now]
            if future:
                now = min(future)
            else:
                now += self.xi
        rts = np.array([t.response_time for t in done])
        return {
            "mean_response_s": float(rts.mean()),
            "max_response_s": float(rts.max()),
            "throughput_per_min": 60.0 * n / max(
                max(t.finish for t in done) - min(t.r for t in done), 1e-9),
            "scheduler_overhead_s": self.scheduler_overhead_s,
            "n_tasks": n,
            "tasks": done,
        }
