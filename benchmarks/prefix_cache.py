"""Prefix caching: shared persona/system-prompt workload on the paged
continuous engine, cache on vs off.

The measured pathology: high-traffic serving repeats prompt PREFIXES —
every request carries one of a few persona preambles, and a fraction
of requests are exact repeats — so the uncached engine re-prefills the
same KV blocks once per admission.  ``prefix_cache=True``
(repro.kvcache.prefix) maps matched prefix blocks to the blocks a
previous request already wrote (refcounted, copy-on-write on full
matches) and prefills only the uncached suffix, so admission cost —
and therefore TTFT — scales with the NOVEL tokens of each prompt.

Two measurements of the same shared-prefix workload, both engines
producing token-for-token identical output (asserted in-benchmark and
in tests/test_prefix_cache.py):

  * ``sim``    — persona latency model, deterministic (the acceptance
    numbers: cached TTFT p50/p99 strictly below uncached at equal
    completion throughput, prefill tokens computed cut by the hit
    rate);
  * ``engine`` — the REAL JAX engine (tiny config on CPU), wall-clock.
    The prefill-tokens-computed and hit/CoW counters are exact on both
    substrates; engine wall-clock ratios are noisy on a dispatch-bound
    CPU host (a short-suffix chunk call costs nearly as much as a full
    prefill call — see docs/BENCHMARKS.md), so the sim column carries
    the latency-bound acceptance numbers.

Results land in experiments/bench/prefix_cache.json.

    PYTHONPATH=src python -m benchmarks.prefix_cache [--seed N]
"""

from __future__ import annotations

import argparse
import gc
import time

import numpy as np

from repro.core import datagen, priority as prio
from repro.core import scheduler as sched, simulator

from . import common
from .continuous_vs_batch import persona_for_bench as _shared_persona

N_REQUESTS = 96
N_ENGINE = 32
SHORT, LONG = 6, 24
LONG_FRAC = 0.25
SLOTS = 8
INPUT_BUCKET = 64
KV_BLOCK = 16
N_PERSONAS = 4
PREFIX_WORDS = 48        # persona preamble: 3 of 4 blocks of the bucket
DUP_FRAC = 0.25          # exact repeats -> full matches -> CoW path
SEED = 0


def build_workload(n=N_REQUESTS, seed=SEED):
    """Shared-prefix texts: ``persona preamble + novel query``, padded
    to exactly INPUT_BUCKET words so identical preambles land on
    identical block-aligned token positions (the engine left-pads to
    the bucket; equal-length prompts keep prefixes aligned).  A
    DUP_FRAC fraction repeats an earlier request's text verbatim."""
    rng = np.random.default_rng(seed)
    personas = [" ".join(f"persona{p}tok{j}" for j in range(PREFIX_WORDS))
                for p in range(N_PERSONAS)]
    texts = []
    for i in range(n):
        if texts and rng.random() < DUP_FRAC:
            texts.append(texts[rng.integers(len(texts))])
            continue
        p = int(rng.integers(N_PERSONAS))
        query = " ".join(f"q{i}w{j}"
                         for j in range(INPUT_BUCKET - PREFIX_WORDS))
        texts.append(personas[p] + " " + query)
    caps = np.where(rng.random(n) < LONG_FRAC, LONG, SHORT).astype(int)
    arrivals = np.sort(rng.uniform(0.0, 0.25, size=n))
    # train corpus for the predictor profile (the scheduler side of the
    # engine; the cache is policy-agnostic)
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 64, seed=seed)
    train, _ = datagen.train_test_split(corpus, train_frac=0.8)
    return train, texts, caps.tolist(), arrivals.tolist()


def persona_for_bench():
    return _shared_persona(batch_size=SLOTS)


def _sim_tasks(texts, caps, arrivals, profile, persona, xi=2.0):
    out = []
    for i, (t, c, r) in enumerate(zip(texts, caps, arrivals)):
        from repro.serving.engine import Request
        u = profile.predictor.score(t)
        d = prio.priority_point(r, len(t.split()), persona.phi, None,
                                xi=xi)
        out.append(prio.SimTask(
            task=Request(text=t, arrival=float(r), task_id=i),
            u=float(max(u, 0.0)), r=float(r), d=d,
            input_len=float(len(t.split())), true_out_len=int(c)))
    return out


def _prompt_tokens_fn(vocab_size, bucket=INPUT_BUCKET):
    from repro.serving.engine import tokenize_padded

    def fn(task):
        return tokenize_padded(task.task.text, vocab_size, bucket)
    return fn


def _summary(res, n) -> dict:
    total_prompt = n * INPUT_BUCKET
    if isinstance(res, dict):
        out = {k: res[k] for k in
               ("mean_response_s", "throughput_per_min",
                "ttft_p50", "ttft_p99", "itl_p50", "itl_p99",
                "prefix_hit_rate", "cached_tokens_reused",
                "cow_copies", "prefix_evictions")}
    else:
        out = dict(res.summary(),
                   prefix_hit_rate=res.prefix_hit_rate,
                   cached_tokens_reused=res.cached_tokens_reused,
                   cow_copies=res.cow_copies,
                   prefix_evictions=res.prefix_evictions)
    out["prefill_tokens_computed"] = \
        total_prompt - out["cached_tokens_reused"]
    return out


def run_sim(policy_name="fifo", seed=SEED):
    """Deterministic persona-model column (the acceptance gate)."""
    from repro import configs

    persona = persona_for_bench()
    train, texts, caps, arrivals = build_workload(seed=seed)
    profile = sched.offline_profile(train, persona, epochs=20, seed=seed)
    pcfg = profile.policy_config()
    cfg = configs.get_smoke_config("starcoder2-3b")
    kv_blocks = SLOTS * (
        (INPUT_BUCKET + LONG + 8 + KV_BLOCK - 1) // KV_BLOCK)
    out = {}
    for name, kw in (("uncached", {}),
                     ("cached", dict(
                         prefix_cache=True,
                         prompt_tokens=_prompt_tokens_fn(cfg.vocab_size)))):
        tasks = _sim_tasks(texts, caps, arrivals, profile, persona)
        res = simulator.simulate_continuous(
            tasks, sched.POLICIES[policy_name](persona, pcfg),
            num_slots=SLOTS, kv_block_size=KV_BLOCK,
            kv_num_blocks=kv_blocks, prompt_len=INPUT_BUCKET, **kw)
        out[name] = _summary(res, len(texts))
    out["ttft_p50_ratio"] = (out["cached"]["ttft_p50"]
                             / max(out["uncached"]["ttft_p50"], 1e-12))
    out["ttft_p99_ratio"] = (out["cached"]["ttft_p99"]
                             / max(out["uncached"]["ttft_p99"], 1e-12))
    out["prefill_tokens_ratio"] = (
        out["cached"]["prefill_tokens_computed"]
        / max(out["uncached"]["prefill_tokens_computed"], 1))
    out["throughput_ratio"] = (out["cached"]["throughput_per_min"]
                               / out["uncached"]["throughput_per_min"])
    return out


def run_engine(policy_name="fifo", n=N_ENGINE, seed=SEED, reps=5):
    """Same comparison on the real JAX engine (tiny config,
    wall-clock); output is token-for-token identical between cached
    and uncached, which run_engine also verifies.  Medians over
    ``reps`` interleaved repetitions (CPU wall-clock is noisy); the
    hit/CoW/tokens counters are deterministic and identical across
    repetitions."""
    import statistics

    import jax
    from repro import configs
    from repro.models import model as model_lib
    from repro.serving.engine import Request, ServingEngine

    persona = persona_for_bench()
    train, texts, caps, arrivals = build_workload(n=n, seed=seed)
    profile = sched.offline_profile(train, persona, epochs=20, seed=seed)
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
    engines = {}
    for name, kw in (("uncached", {}),
                     ("cached", dict(prefix_cache=True))):
        policy = sched.POLICIES[policy_name](persona,
                                             profile.policy_config())
        eng = ServingEngine(params, cfg, policy, profile,
                            input_bucket=INPUT_BUCKET, max_new_tokens=LONG,
                            mode="continuous", eos_id=-1, kv="paged",
                            kv_block_size=KV_BLOCK, **kw)
        # untimed warmup: compile every executable (full prefill, the
        # suffix-chunk shapes, CoW copy, decode)
        eng.serve([Request(text=t, arrival=0.0, task_id=i,
                           max_new_tokens=3)
                   for i, t in enumerate(texts[:SLOTS + 1])])
        engines[name] = eng
    out = {}
    tokens = {}
    rep_rows = {"uncached": [], "cached": []}
    for _ in range(reps):
        for name, eng in engines.items():
            reqs = [Request(text=t, arrival=a, task_id=i,
                            max_new_tokens=c)
                    for i, (t, c, a) in enumerate(zip(texts, caps,
                                                      arrivals))]
            gc.disable()
            try:
                res = eng.serve(reqs)
            finally:
                gc.enable()
            if eng.prefix_cache is not None:
                eng.prefix_cache.clear()
            eng.allocator.check_no_leaks()
            rep_rows[name].append(_summary(res, n))
            tokens.setdefault(name, {t.task.task_id: t.task.out_tokens
                                     for t in res["tasks"]})
    for name, rows in rep_rows.items():
        out[name] = {k: statistics.median(r[k] for r in rows)
                     for k in rows[0]}
        out[name]["reps"] = rows
    assert tokens["uncached"] == tokens["cached"], \
        "prefix caching changed the greedy output"
    out["token_parity"] = True
    assert out["cached"]["prefix_hit_rate"] > 0
    assert out["cached"]["cow_copies"] > 0        # duplicates -> CoW
    out["ttft_p50_ratio"] = (out["cached"]["ttft_p50"]
                             / max(out["uncached"]["ttft_p50"], 1e-12))
    out["ttft_p99_ratio"] = (out["cached"]["ttft_p99"]
                             / max(out["uncached"]["ttft_p99"], 1e-12))
    out["prefill_tokens_ratio"] = (
        out["cached"]["prefill_tokens_computed"]
        / max(out["uncached"]["prefill_tokens_computed"], 1))
    out["throughput_ratio"] = (out["cached"]["throughput_per_min"]
                               / out["uncached"]["throughput_per_min"])
    return out


def main(seed=SEED):
    t0 = time.time()
    sim = run_sim("fifo", seed=seed)
    eng = run_engine("fifo", seed=seed)
    payload = {
        "seed": seed,
        "input_bucket": INPUT_BUCKET,
        "kv_block_size": KV_BLOCK,
        "num_slots": SLOTS,
        "n_personas": N_PERSONAS,
        "prefix_words": PREFIX_WORDS,
        "dup_frac": DUP_FRAC,
        "sim": sim,
        "engine": eng,
    }
    common.save("prefix_cache", payload)
    common.emit(
        "prefix_cache", time.time() - t0,
        f"sim_ttft_p50_x={sim['ttft_p50_ratio']:.2f},"
        f"sim_ttft_p99_x={sim['ttft_p99_ratio']:.2f},"
        f"sim_prefill_tokens_x={sim['prefill_tokens_ratio']:.2f},"
        f"engine_prefill_tokens_x={eng['prefill_tokens_ratio']:.2f},"
        f"engine_hit_rate={eng['cached']['prefix_hit_rate']:.2f}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=SEED)
    main(seed=ap.parse_args().seed)
