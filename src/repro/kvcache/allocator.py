"""Host-side block allocator: free list + per-sequence block tables
+ per-block reference counts.

The allocator is deliberately dumb and exact — a list of free physical
block ids, a ``seq_id -> [block ids]`` table map and a ``block ->
refcount`` map.  All policy (reservation-based admission, lazy
boundary-crossing allocation, prefix matching) lives in the serving
engine / simulator / ``kvcache.prefix``; the allocator only enforces
the hard invariants the property tests pin down:

  * a live block is never handed out twice: ``allocate`` only pops
    blocks no one references;
  * reference counts balance: every ``share``/``add_ref`` is undone by
    exactly one ``free_sequence`` entry / ``drop_ref``, and a block
    returns to the free list exactly when its count reaches zero — so
    no block shared by a prefix cache or a sibling sequence is ever
    freed while someone still reads it;
  * ``free_sequence`` drops one reference per table entry (no leaks —
    after a full ``serve()`` plus a cache ``clear()`` the pool is
    whole again).

Copy-on-write lives here as ``cow_block``: replacing one SHARED entry
of a sequence's table with a fresh private block (the caller copies the
device-side page contents).  The sharing machinery is only engaged by
``kvcache.prefix.PrefixCache``; plain paged serving keeps every block
at refcount 1 and behaves exactly as before.

Under allocator pressure an optional ``reclaim`` hook (installed by the
prefix cache) is consulted: it must release at least one block back to
the free list per call (LRU eviction of cached, otherwise-unreferenced
blocks) or return False, at which point ``OutOfBlocksError`` is raised.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple


def blocks_for_tokens(num_tokens: int, block_size: int) -> int:
    """Memory formula: blocks needed to hold ``num_tokens`` KV entries.

    Shared by the engine's admission gate and the simulator's
    block-budget model — both must compute reservations identically or
    engine-vs-sim parity breaks.
    """
    if num_tokens <= 0:
        return 0
    return -(-num_tokens // block_size)


def window_target_tokens(prompt_len: int, produced: int, cap: int,
                         steps: int) -> int:
    """Tokens a slot's block table must cover before an N-step decode
    window (the multi-step launch of the async host pipeline).

    A slot that has ``produced`` tokens sits at write position
    ``prompt + produced - 1``; window step j (1-based) writes position
    ``prompt + produced + j - 2`` and emits token ``produced + j``.
    Readback — and therefore EOS/cap detection and eviction — happens
    only at window END (in arrears), so a sequence may be stepped up
    to ``steps - 1`` times past its logical end.  The LAST useful write
    is the one emitting token ``cap`` (position ``prompt + cap - 2``),
    which is why the target clamps at ``prompt + cap - 1``: overhang
    writes past the cap fall off the sequence's table onto the trash
    page (the scatter primitives clamp the block index to the table
    width), and post-EOS writes before the cap land in the slot's own
    still-held private blocks, freed untouched at window end.

    The clamp is the eviction-lag invariant: the target never exceeds
    the admission reservation ``blocks_for(prompt + cap - 1)``, so
    admission/rejection decisions are identical for every ``steps`` —
    the engine and the simulator both allocate against this formula.
    ``steps=1`` reduces exactly to the synchronous per-step rule
    ``prompt + produced`` (the pre-window state of the original loop).
    """
    return prompt_len + min(produced + steps, cap) - 1


class OutOfBlocksError(RuntimeError):
    """Raised when an allocation is requested from an empty free list.

    With reservation-based admission this is a bug, not backpressure:
    the engine reserves a sequence's worst case up front (and cached
    refcount-0 blocks are reclaimable on demand), so a boundary
    crossing must never find the pool empty.
    """


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` physical KV blocks."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # popped from the end so blocks hand out in ascending id order
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}
        self._refs: Dict[int, int] = {}
        # optional pressure valve (kvcache.prefix installs LRU eviction
        # of cached blocks here); must free >= 1 block or return False
        self.reclaim: Optional[Callable[[], bool]] = None

    # -- accounting ----------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def live_sequences(self) -> int:
        return len(self._tables)

    def utilization(self) -> float:
        return self.num_used / self.num_blocks

    def blocks_for(self, num_tokens: int) -> int:
        return blocks_for_tokens(num_tokens, self.block_size)

    def refcount(self, block: int) -> int:
        """References held on ``block`` (0 = free)."""
        return self._refs.get(block, 0)

    # -- alloc / share / free ------------------------------------------
    def _ensure_free(self, n: int) -> None:
        """Make sure ``n`` blocks are on the free list, reclaiming
        cached blocks through the ``reclaim`` hook if one is installed."""
        while len(self._free) < n:
            if self.reclaim is None or not self.reclaim():
                raise OutOfBlocksError(
                    f"need {n} free KV blocks, have {len(self._free)} "
                    f"(of {self.num_blocks}) and nothing to reclaim")

    def allocate(self, seq_id: int) -> int:
        """Append one fresh (refcount-1) block to ``seq_id``'s table."""
        self._ensure_free(1)
        blk = self._free.pop()
        assert blk not in self._refs, f"block {blk} double-allocated"
        self._refs[blk] = 1
        self._tables.setdefault(seq_id, []).append(blk)
        return blk

    def allocate_n(self, seq_id: int, n: int) -> List[int]:
        self._ensure_free(n)
        return [self.allocate(seq_id) for _ in range(n)]

    def share(self, seq_id: int, block: int) -> None:
        """Append an already-live block to ``seq_id``'s table, taking
        one more reference (prefix-cache hit: the sequence READS the
        block; it must copy-on-write before any divergent write)."""
        assert block in self._refs, f"cannot share free block {block}"
        self._refs[block] += 1
        self._tables.setdefault(seq_id, []).append(block)

    def add_ref(self, block: int) -> None:
        """Take a table-less reference (the prefix cache pinning a
        block it indexes)."""
        assert block in self._refs, f"cannot reference free block {block}"
        self._refs[block] += 1

    def drop_ref(self, block: int) -> bool:
        """Release one reference; returns True when the block was freed
        (count reached zero and it went back on the free list)."""
        n = self._refs[block] - 1
        assert n >= 0
        if n == 0:
            del self._refs[block]
            self._free.append(block)
            return True
        self._refs[block] = n
        return False

    def cow_block(self, seq_id: int, index: int) -> Tuple[int, int]:
        """Copy-on-write: replace ``seq_id``'s SHARED table entry
        ``index`` with a fresh private block.  Returns ``(src, dst)``
        physical ids — the caller copies the device-side page contents
        of ``src`` into ``dst`` before writing.  The shared block keeps
        its remaining references (cache / sibling sequences), so a CoW
        never mutates a block someone else still reads.
        """
        table = self._tables[seq_id]
        src = table[index]
        assert self._refs[src] >= 2, (
            f"block {src} is private (refcount {self._refs[src]}); "
            "write in place instead of CoW")
        self._ensure_free(1)
        dst = self._free.pop()
        assert dst not in self._refs, f"block {dst} double-allocated"
        self._refs[dst] = 1
        table[index] = dst
        self.drop_ref(src)
        return src, dst

    def table(self, seq_id: int) -> List[int]:
        """The sequence's block table (copy), empty if unknown."""
        return list(self._tables.get(seq_id, ()))

    def free_sequence(self, seq_id: int) -> int:
        """Drop one reference per table entry of ``seq_id``; returns the
        number of entries released.  Blocks return to the pool only when
        their LAST reference drops — shared prefix blocks survive as
        long as the cache or a sibling sequence still holds them.

        Idempotent: freeing an unknown (or already-freed) sequence is a
        no-op — eviction paths need not track whether a sequence ever
        received blocks.
        """
        blocks = self._tables.pop(seq_id, None)
        if not blocks:
            return 0
        for blk in blocks:
            self.drop_ref(blk)
        return len(blocks)

    def free_all(self) -> int:
        """Crash-time bulk free: drop every live sequence in ascending
        seq-id order (deterministic free-list order on both sides of a
        parity run); returns the number of table entries released.
        Table-less references (prefix-cache pins) are untouched — the
        cache outlives a replica crash exactly like it outlives normal
        eviction."""
        released = 0
        for seq_id in sorted(self._tables):
            released += self.free_sequence(seq_id)
        return released

    def check_no_leaks(self) -> None:
        """Assert the pool is whole (used by tests after a full serve;
        prefix-cache engines ``clear()`` the cache's references first)."""
        assert not self._tables and not self._refs, (
            f"leaked {self.num_used} blocks across "
            f"{self.live_sequences} sequences "
            f"({len(self._refs)} referenced)")
        assert sorted(self._free) == list(range(self.num_blocks))
