"""Discrete-event simulator of the serving node (GPU lane + CPU lane).

Execution-time model, calibrated to the paper's published coefficients
(personas.py) and cross-checked against the real JAX engine on tiny
configs (tests/test_engine_vs_sim.py):

    t_batch(GPU) = setup_f + eta_f * max(out_len in batch)
    t_batch(CPU) = cpu_slowdown_f * t_batch(GPU-model)

Batched autoregressive decoding runs until its *longest* member finishes
— this is precisely the head-of-line effect RT-LM's consolidation
exploits: batches with homogeneous output lengths waste no decode steps.

The simulator owns the clock; the policy is consulted whenever the GPU
lane is free and the dispatch condition holds (>= C queued, or the oldest
task has waited the xi batching window).  The CPU lane drains offloaded
tasks independently.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from . import scheduler as sched_lib
from .personas import Persona
from .priority import SimTask


@dataclasses.dataclass
class SimResult:
    tasks: List[SimTask]
    makespan: float
    overhead_s: float = 0.0

    # ---- paper metrics ------------------------------------------------
    @property
    def response_times(self) -> np.ndarray:
        return np.array([t.response_time for t in self.tasks])

    @property
    def mean_response(self) -> float:
        return float(self.response_times.mean())

    @property
    def max_response(self) -> float:
        return float(self.response_times.max())

    @property
    def throughput_per_min(self) -> float:
        return 60.0 * len(self.tasks) / max(self.makespan, 1e-9)

    @property
    def miss_rate(self) -> float:
        return float(np.mean([t.missed for t in self.tasks]))

    def summary(self) -> Dict[str, float]:
        return {
            "mean_response_s": self.mean_response,
            "max_response_s": self.max_response,
            "p95_response_s": float(np.quantile(self.response_times, 0.95)),
            "throughput_per_min": self.throughput_per_min,
            "miss_rate": self.miss_rate,
            "n_tasks": len(self.tasks),
        }


class Lane:
    def __init__(self, slowdown: float = 1.0):
        self.free_at = 0.0
        self.slowdown = slowdown
        self.busy_time = 0.0

    def run_batch(self, batch: List[SimTask], now: float,
                  persona: Persona, lane_name: str) -> float:
        start = max(now, self.free_at)
        dur = persona.batch_latency(
            [t.true_out_len for t in batch]) * self.slowdown
        finish = start + dur
        for t in batch:
            t.start, t.finish, t.lane = start, finish, lane_name
        self.free_at = finish
        self.busy_time += dur
        return finish


def simulate(tasks: Sequence[SimTask], policy: sched_lib.Policy, *,
             xi: float = 2.0, per_task_overhead_s: float = 0.0) -> SimResult:
    """Run the full trace through the node under ``policy``.

    per_task_overhead_s models the scheduler's own latency (Table VII);
    it is added to the dispatch instant of every formed batch.
    """
    persona = policy.persona
    pending = sorted(tasks, key=lambda t: t.r)
    n_total = len(pending)
    queue: List[SimTask] = []
    cpu_queue: List[SimTask] = []
    done: List[SimTask] = []
    gpu = Lane(1.0)
    cpu = Lane(persona.cpu_slowdown)
    now = 0.0
    overhead_total = 0.0
    i = 0
    C = persona.batch_size

    def dispatch_ready(now: float) -> bool:
        if not queue:
            return False
        if len(queue) >= C:
            return True
        oldest = min(t.r for t in queue)
        if now - oldest >= xi:
            return True
        # nothing else will ever arrive -> flush
        return i >= n_total

    while len(done) < n_total:
        # admit arrivals up to `now`
        while i < n_total and pending[i].r <= now + 1e-12:
            queue.append(pending[i])
            i += 1

        progressed = False
        if gpu.free_at <= now + 1e-12 and dispatch_ready(now):
            gpu_batch, off_batch, rest = policy.select(list(queue), now)
            queue = list(rest)
            cpu_queue.extend(off_batch)
            if gpu_batch:
                oh = per_task_overhead_s * len(gpu_batch)
                overhead_total += oh
                gpu.run_batch(gpu_batch, now + oh, persona, "gpu")
                done.extend(gpu_batch)
                progressed = True
        if cpu.free_at <= now + 1e-12 and cpu_queue:
            batch, cpu_queue = cpu_queue[:C], cpu_queue[C:]
            cpu.run_batch(batch, now, persona, "cpu")
            done.extend(batch)
            progressed = True

        if progressed:
            continue
        # advance the clock to the next *future* event
        candidates = []
        if i < n_total:
            candidates.append(pending[i].r)
        if queue:
            candidates.append(min(t.r for t in queue) + xi)
            candidates.append(gpu.free_at)
        if cpu_queue:
            candidates.append(cpu.free_at)
        future = [c for c in candidates if c > now + 1e-12]
        now = min(future) if future else now + xi

    makespan = max(t.finish for t in done) - min(t.r for t in done)
    return SimResult(tasks=done, makespan=makespan,
                     overhead_s=overhead_total)


# ---------------------------------------------------------------------------
# one-call experiment helper
# ---------------------------------------------------------------------------


def run_policy(tasks: Sequence[SimTask], policy_name: str,
               persona: Persona, pcfg: sched_lib.PolicyConfig, *,
               xi: float = 2.0, per_task_overhead_s: float = 0.0
               ) -> SimResult:
    import copy
    policy = sched_lib.POLICIES[policy_name](persona, pcfg)
    tasks = [copy.copy(t) for t in tasks]    # fresh timing fields
    return simulate(tasks, policy, xi=xi,
                    per_task_overhead_s=per_task_overhead_s)
