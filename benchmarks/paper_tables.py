"""Reproductions of the paper's tables and figures (simulator-backed).

Each function returns (payload, derived_summary) and corresponds to one
artifact of the paper:

  table3  — max response time, 5 LMs x {small, normal, large} variance
  table4  — average throughput, same grid
  fig9    — response-time distributions (quantiles per policy)
  fig10   — ablation: FIFO/HPF vs UP vs UP+C vs RT-LM
  fig13a  — alpha sweep;  fig13b — b sweep
  fig14   — malicious-task ratio 0..100%
  table6  — offline profiling overhead (LW training time / memory)
  table7  — online scheduling overhead per task
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core import personas

from . import common

LMS = personas.PERSONA_NAMES


def table3():
    rows: Dict[str, Dict] = {}
    for lm in LMS:
        rows[lm] = {}
        for var in common.VARIANCES:
            for pol in common.POLICIES:
                res = common.run(var, lm, pol)
                rows[lm].setdefault(var, {})[pol] = round(
                    res.max_response, 3)
    # headline: best improvement of rt-lm over FIFO on max response
    imps = []
    for lm in LMS:
        for var in common.VARIANCES:
            f, r = rows[lm][var]["fifo"], rows[lm][var]["rt-lm"]
            imps.append((f - r) / f)
    derived = (f"max_resp_improvement_best={max(imps)*100:.0f}%"
               f";median={np.median(imps)*100:.0f}%")
    return {"rows": rows, "improvements": imps}, derived


def table4():
    rows: Dict[str, Dict] = {}
    for lm in LMS:
        rows[lm] = {}
        for var in common.VARIANCES:
            for pol in common.POLICIES:
                res = common.run(var, lm, pol)
                rows[lm].setdefault(var, {})[pol] = round(
                    res.throughput_per_min, 2)
    imps = []
    for lm in LMS:
        for var in common.VARIANCES:
            f, r = rows[lm][var]["fifo"], rows[lm][var]["rt-lm"]
            imps.append((r - f) / f)
    derived = (f"throughput_improvement_best={max(imps)*100:.0f}%"
               f";median={np.median(imps)*100:.0f}%")
    return {"rows": rows, "improvements": imps}, derived


def fig9():
    out = {}
    for var in common.VARIANCES:
        out[var] = {}
        for pol in common.POLICIES:
            res = common.run(var, "dialogpt", pol)
            rts = res.response_times
            out[var][pol] = {
                "mean": float(rts.mean()),
                "p50": float(np.quantile(rts, .5)),
                "p90": float(np.quantile(rts, .9)),
                "p99": float(np.quantile(rts, .99)),
                "max": float(rts.max()),
            }
    d = out["large"]
    derived = (f"large_var_mean_fifo={d['fifo']['mean']:.2f}s"
               f";rtlm={d['rt-lm']['mean']:.2f}s")
    return out, derived


def fig10():
    out = {}
    gaps = []
    for lm in LMS:
        out[lm] = {}
        for pol in common.ABLATION:
            res = common.run("large", lm, pol)
            out[lm][pol] = round(res.mean_response, 3)
        gaps.append(out[lm]["fifo"] - out[lm]["rt-lm"])
    derived = (f"ablation_mean_resp_gap_fifo_to_rtlm="
               f"{min(gaps):.2f}..{max(gaps):.2f}s")
    return out, derived


def fig13a():
    out = {}
    for lm in LMS:
        out[lm] = {}
        for alpha in [round(0.1 * i, 1) for i in range(0, 21, 2)]:
            res = common.run("large", lm, "rt-lm", alpha=alpha)
            out[lm][str(alpha)] = round(res.mean_response, 3)
    spans = [max(v.values()) - min(v.values()) for v in out.values()]
    derived = f"alpha_sensitivity_max_span={max(spans):.2f}s"
    return out, derived


def fig13b():
    out = {}
    for lm in LMS:
        out[lm] = {}
        for b in [1.0, 1.2, 1.4, 1.6, 1.8, 2.0, 2.4, 2.8, 3.0]:
            res = common.run("large", lm, "rt-lm", b=b)
            out[lm][str(b)] = round(res.mean_response, 3)
    spans = [max(v.values()) - min(v.values()) for v in out.values()]
    derived = f"b_sensitivity_max_span={max(spans):.2f}s"
    return out, derived


def fig14():
    out = {}
    for pct in range(0, 101, 10):
        row = {}
        for pol in ("fifo", "rt-lm"):
            res = common.run("normal", "dialogpt", pol, malicious_pct=pct)
            row[pol] = round(res.mean_response, 3)
        out[str(pct)] = row
    derived = (f"mal50_fifo={out['50']['fifo']:.2f}s"
               f";rtlm={out['50']['rt-lm']:.2f}s")
    return out, derived


def table6():
    """Offline profiling overhead: LW training wall time vs the total LM
    inference time of the training corpus (paper reports 3~4%)."""
    out = {}
    for lm in LMS:
        prof = common.profile("normal", lm)
        train, _ = common.corpus("normal")
        persona = personas.get_persona(lm)
        lm_inference_s = sum(
            persona.output_latency(t.out_lens[lm]) for t in train)
        out[lm] = {
            "lw_train_s": round(prof.train_wall_s, 2),
            "lm_inference_s": round(lm_inference_s, 1),
            "ratio_pct": round(100 * prof.train_wall_s / lm_inference_s, 2),
        }
    worst = max(v["ratio_pct"] for v in out.values())
    return out, f"offline_overhead_worst={worst:.1f}%"


def table7():
    """Online scheduling overhead per task: wall-time the three stages."""
    out = {}
    for lm in LMS:
        tasks, prof = common.sim_tasks("normal", lm)
        persona = personas.get_persona(lm)
        pcfg = prof.policy_config()
        # prioritization = predictor scoring + priority computation
        t0 = time.perf_counter()
        _ = prof.predictor.score_batch([t.task.text for t in tasks[:512]])
        prior_ms = (time.perf_counter() - t0) / 512 * 1e3
        # consolidation+offload = one select() pass over a full queue
        from repro.core import scheduler as sched
        pol = sched.POLICIES["rt-lm"](persona, pcfg)
        queue = list(tasks[:256])
        t0 = time.perf_counter()
        reps = 20
        for _ in range(reps):
            pol.select(queue, 0.0)
        sel_ms = (time.perf_counter() - t0) / (reps * len(queue)) * 1e3
        lm_ms = persona.output_latency(
            np.mean([t.true_out_len for t in tasks])) * 1e3
        out[lm] = {
            "prioritization_ms": round(prior_ms, 3),
            "consolidate_offload_ms": round(sel_ms, 4),
            "per_task_total_ms": round(prior_ms + sel_ms, 3),
            "lm_inference_ms": round(lm_ms, 1),
            "ratio_pct": round(100 * (prior_ms + sel_ms) / lm_ms, 2),
        }
    worst = max(v["ratio_pct"] for v in out.values())
    return out, f"online_overhead_worst={worst:.1f}%"


def fig11_xavier():
    """§V-E on-device evaluation: the same grids on the AGX Xavier
    platform (6x slower executor, narrower GPU:CPU gap)."""
    out = {}
    for lm in LMS:
        out[lm] = {}
        for pol in common.POLICIES:
            res = common.run("large", lm, pol, platform="agx_xavier")
            out[lm][pol] = round(res.mean_response, 3)
    # paper: faster devices show SMALLER relative disparity across methods
    rel_gap_xavier = np.mean([
        (out[lm]["fifo"] - out[lm]["rt-lm"]) / out[lm]["fifo"]
        for lm in LMS])
    derived = f"xavier_rel_gap_fifo_to_rtlm={rel_gap_xavier*100:.0f}%"
    return out, derived


def fig12_xavier_ablation():
    out = {}
    for lm in LMS:
        out[lm] = {}
        for pol in common.ABLATION:
            res = common.run("large", lm, pol, platform="agx_xavier")
            out[lm][pol] = round(res.mean_response, 3)
    gaps = [out[lm]["fifo"] - out[lm]["rt-lm"] for lm in LMS]
    return out, f"xavier_ablation_gap={min(gaps):.2f}..{max(gaps):.2f}s"


def beyond_rtlmq():
    """Beyond-paper: tail-aware consolidation (P90 pinball predictor) vs
    vanilla RT-LM — batched decode latency is set by the batch MAX, so
    consolidating on the predicted tail should cut max response."""
    out = {}
    for lm in ("dialogpt", "godel", "bart"):
        row = {}
        for pol in ("rt-lm", "rt-lm-q"):
            res = common.run("large", lm, pol, tail_quantile=0.9)
            row[pol] = {"mean": round(res.mean_response, 3),
                        "max": round(res.max_response, 3),
                        "p95": round(float(np.quantile(
                            res.response_times, 0.95)), 3)}
        out[lm] = row
    imp = np.mean([
        (out[lm]["rt-lm"]["max"] - out[lm]["rt-lm-q"]["max"])
        / out[lm]["rt-lm"]["max"] for lm in out])
    return out, f"rtlmq_max_resp_improvement={imp*100:.0f}%"


ALL = {
    "table3_max_response": table3,
    "table4_throughput": table4,
    "fig9_distributions": fig9,
    "fig10_ablation": fig10,
    "fig13a_alpha_sweep": fig13a,
    "fig13b_b_sweep": fig13b,
    "fig14_malicious": fig14,
    "fig11_xavier": fig11_xavier,
    "fig12_xavier_ablation": fig12_xavier_ablation,
    "table6_offline_overhead": table6,
    "table7_online_overhead": table7,
    "beyond_rtlmq": beyond_rtlmq,
}
