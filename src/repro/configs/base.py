"""Model / run configuration dataclasses shared by every architecture.

Every assigned architecture gets one module in ``repro.configs`` exporting
``CONFIG`` (the full, paper-exact configuration) and ``smoke_config()``
(a reduced variant of the same family: 2 layers, d_model<=512, <=4 experts)
used by the CPU smoke tests.  The full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A single architecture description (one per assigned arch)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    vocab_size: int
    # --- attention ---
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    window: Optional[int] = None      # sliding-window size (tokens); None = full
    rope_theta: float = 10_000.0
    # --- mlp ---
    d_ff: int = 0
    mlp_act: str = "swiglu"           # swiglu | gelu | relu
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    num_dense_layers: int = 0         # leading dense layers before MoE stack
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1
    # --- hybrid (RecurrentGemma / Griffin) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec","rec","attn")
    lru_width: int = 0
    local_window: int = 2048
    # --- encoder-decoder ---
    num_encoder_layers: int = 0
    encoder_seq_len: int = 4096       # fixed encoder memory length for decode shapes
    # --- multimodal stubs ---
    frontend: str = ""                # "" | vision | audio
    num_patch_tokens: int = 0         # VLM: patch embeddings prepended to prompt
    # --- numerics ---
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    tie_embeddings: bool = True
    # --- citation for the assignment table ---
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded for shardability across <=16-way model parallelism.

        Padding the embedding/vocab axis to a multiple of 2048 makes every
        assigned vocab divisible by the model axis (16) and by 2*16 for the
        multi-pod mesh.  Logit positions >= vocab_size are masked to -inf
        in the loss / sampler.
        """
        return _round_up(self.vocab_size, 2048)

    @property
    def attn_groups(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Whether decode state is bounded => long_500k eligible."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True
        if self.window is not None:
            return True
        return False

    # -------------------------- parameter counting --------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6*N*D model-FLOPs roofline)."""
        D, V = self.d_model, self.padded_vocab
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += V * D
        per_attn = D * self.num_heads * self.head_dim * 2 + \
            D * self.num_kv_heads * self.head_dim * 2
        if self.family == "ssm":
            di, nheads, ns = self.ssm_d_inner, self.ssm_heads, self.ssm_state
            cw, g = self.ssm_conv_width, self.ssm_groups
            per_layer = (D * (2 * di + 2 * g * ns + nheads)      # in_proj
                         + (di + 2 * g * ns) * cw                 # conv
                         + nheads * 2                             # A_log, D
                         + di                                     # gated norm
                         + di * D)                                # out_proj
            n += self.num_layers * per_layer + D
            return n
        if self.family == "hybrid":
            lw = self.lru_width or D
            rec_layer = D * lw * 2 + lw * self.ssm_conv_width + lw * 4 + lw * D
            attn_layer = per_attn
            mlp = 3 * D * self.d_ff
            pat = self.block_pattern or ("rec",)
            n_attn = sum(1 for i in range(self.num_layers)
                         if pat[i % len(pat)] == "attn")
            n_rec = self.num_layers - n_attn
            n += n_rec * (rec_layer + mlp + 2 * D) + \
                n_attn * (attn_layer + mlp + 2 * D) + D
            return n
        mlp_mult = 3 if self.mlp_act == "swiglu" else 2
        dense_mlp = mlp_mult * D * self.d_ff
        if self.family == "moe":
            expert_mlp = mlp_mult * D * self.moe_d_ff
            moe_layer = (per_attn + self.num_experts * expert_mlp
                         + self.num_shared_experts * expert_mlp
                         + D * self.num_experts + 2 * D)
            dense_layer = per_attn + dense_mlp + 2 * D
            n += (self.num_dense_layers * dense_layer
                  + (self.num_layers - self.num_dense_layers) * moe_layer + D)
            return n
        per_layer = per_attn + dense_mlp + 2 * D
        n += self.num_layers * per_layer + D
        if self.family == "encdec":
            # encoder layers + decoder cross-attention
            enc_layer = per_attn + dense_mlp + 2 * D
            n += self.num_encoder_layers * enc_layer + D
            n += self.num_layers * (per_attn + D)  # cross attn
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        mlp_mult = 3 if self.mlp_act == "swiglu" else 2
        expert_mlp = mlp_mult * self.d_model * self.moe_d_ff
        inactive = (self.num_layers - self.num_dense_layers) * \
            (self.num_experts - self.experts_per_token) * expert_mlp
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is a live dry-run combination.

    Returns (ok, reason-if-skipped).  Mirrors DESIGN.md §4.
    """
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("full quadratic attention; 512k decode KV state is "
                       "unbounded — skipped per spec (no SWA/block-sparse "
                       "variant for this arch)")
    return True, ""
