"""Paged KV-cache subsystem: allocator invariants and page gather /
scatter round-trips (deterministic; always runs).

The hypothesis fuzzed forms of these invariants live in
tests/test_properties.py behind its ``importorskip`` guard; the
engine-level no-leak-after-serve and token-parity properties live in
tests/test_paged_engine.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kvcache import BlockAllocator, blocks_for_tokens
from repro.kvcache.allocator import OutOfBlocksError
from repro.kvcache.paged import (gather_tokens, scatter_prefill,
                                 scatter_token)


# ---------------------------------------------------------------------------
# deterministic allocator coverage
# ---------------------------------------------------------------------------


def test_blocks_for_tokens():
    assert blocks_for_tokens(0, 16) == 0
    assert blocks_for_tokens(1, 16) == 1
    assert blocks_for_tokens(16, 16) == 1
    assert blocks_for_tokens(17, 16) == 2
    assert blocks_for_tokens(-3, 16) == 0


def test_allocator_basics():
    a = BlockAllocator(4, 16)
    b0 = a.allocate(seq_id=7)
    b1, b2 = a.allocate_n(seq_id=9, n=2)
    assert len({b0, b1, b2}) == 3            # all distinct
    assert a.num_used == 3 and a.num_free == 1
    assert a.table(9) == [b1, b2]
    assert a.free_sequence(9) == 2
    assert a.num_free == 3
    assert a.free_sequence(9) == 0           # idempotent
    assert a.free_sequence(7) == 1
    a.check_no_leaks()


def test_allocator_exhaustion():
    a = BlockAllocator(2, 16)
    a.allocate_n(seq_id=0, n=2)
    with pytest.raises(OutOfBlocksError):
        a.allocate(seq_id=1)
    with pytest.raises(OutOfBlocksError):
        a.allocate_n(seq_id=1, n=1)
    # a failed allocate_n must not leak partial grabs
    a.free_sequence(0)
    with pytest.raises(OutOfBlocksError):
        a.allocate_n(seq_id=1, n=3)
    assert a.table(1) == []
    assert a.num_free == 2


def test_freed_blocks_are_reusable():
    a = BlockAllocator(2, 8)
    first = set(a.allocate_n(seq_id=0, n=2))
    a.free_sequence(0)
    second = set(a.allocate_n(seq_id=1, n=2))
    assert first == second


# ---------------------------------------------------------------------------
# deterministic page round-trip
# ---------------------------------------------------------------------------


def test_page_roundtrip_prefill_then_tokens():
    """scatter_prefill + per-token scatter_token reproduce the logical
    sequence exactly under gather_tokens (the contiguous-layout
    equivalence the token-parity engine test relies on)."""
    bs, nb, N = 4, 3, 8
    feat = (2, 5)
    key = jax.random.PRNGKey(0)
    seq = jax.random.normal(key, (nb * bs,) + feat)
    pages = jnp.zeros((N, bs) + feat)
    table = jnp.asarray([5, 1, 6], jnp.int32)
    S = 6
    pages = scatter_prefill(pages, seq[:S], table, S)
    for pos in range(S, nb * bs):
        pages = scatter_token(pages, seq[pos][None],
                              table[None, :], jnp.asarray([pos]))
    got = gather_tokens(pages, table[None, :])[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(seq))


def test_roundtrip_with_permuted_table_and_stale_pages():
    """Gather is exact even when physical order != logical order and
    spare pages hold garbage (the stale-content case after eviction)."""
    bs, nb, N = 3, 4, 9
    rng = np.random.default_rng(1)
    table = jnp.asarray([7, 0, 3, 5], jnp.int32)
    pages = jnp.asarray(rng.normal(size=(N, bs, 2)).astype(np.float32))
    S = 10
    seq = jnp.asarray(rng.normal(size=(S, 2)).astype(np.float32))
    pages = scatter_prefill(pages, seq[:4], table, 4)
    for pos in range(4, S):
        pages = scatter_token(pages, seq[pos][None], table[None, :],
                              jnp.asarray([pos]))
    got = gather_tokens(pages, table[None, :])[0, :S]
    np.testing.assert_allclose(np.asarray(got), np.asarray(seq),
                               atol=0, rtol=0)
