"""Poisson workload generation (paper §V-A Workload setup).

Inter-arrival times are sampled from an exponential distribution whose
rate evolves minute-by-minute through beta = 10..150 queries/min (the
paper iterates integer beta values, one minute each, light load to
high-traffic peak).  A wait-time interval xi (=2 s) groups arrivals for
batch processing — the simulator implements xi as its dispatch window.

Traffic classes (PR 8): a workload spec may declare named classes with
per-class SLO targets (``slo={"ttft_s": ..., "itl_s": ...}``) that the
windowed SLO monitor (``repro.obs.slo``) judges attainment against.
``SLOSpec`` lives in ``repro.obs.slo`` (obs must stay importable
without ``repro.core``; this import direction is the safe one).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.obs.slo import SLOSpec


def poisson_trace(n_tasks: int, *, beta_min: int = 10, beta_max: int = 150,
                  seed: int = 0,
                  betas: Optional[Sequence[int]] = None) -> List[float]:
    """Arrival times (s) for n_tasks, beta evolving one minute per value."""
    rng = np.random.default_rng(seed)
    if betas is None:
        betas = list(range(beta_min, beta_max + 1, 10))
    arrivals: List[float] = []
    t = 0.0
    minute_end = 60.0
    bi = 0
    while len(arrivals) < n_tasks:
        beta = betas[min(bi, len(betas) - 1)]
        mu = 60.0 / beta                       # mean inter-arrival (s)
        t = t + rng.exponential(mu)
        while t >= minute_end:
            minute_end += 60.0
            bi += 1
        arrivals.append(t)
    return arrivals


def constant_rate_trace(n_tasks: int, beta: float, seed: int = 0
                        ) -> List[float]:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(60.0 / beta, size=n_tasks)
    return list(np.cumsum(gaps))


def flash_crowd_trace(n_tasks: int, *, base_beta: float = 30.0,
                      peak_beta: float = 300.0,
                      peak_frac: float = 0.25,
                      seed: int = 0) -> List[float]:
    """Arrival times (s) with a flash crowd in the middle of the trace:
    a baseline Poisson stream at ``base_beta`` queries/min whose middle
    ``peak_frac`` of requests arrive at ``peak_beta`` instead — the
    sudden burst that separates placement policies (a load-oblivious
    router keeps hashing the burst uniformly; a load/uncertainty-aware
    one drains it around the backlog).  Deterministic per seed."""
    if not 0.0 <= peak_frac <= 1.0:
        raise ValueError(f"peak_frac must be in [0, 1], got {peak_frac}")
    rng = np.random.default_rng(seed)
    n_peak = int(n_tasks * peak_frac)
    n_base = n_tasks - n_peak
    lead = n_base // 2
    rates = ([base_beta] * lead + [peak_beta] * n_peak
             + [base_beta] * (n_tasks - lead - n_peak))
    gaps = [rng.exponential(60.0 / rates[i]) for i in range(n_tasks)]
    return list(np.cumsum(gaps))


# ---------------------------------------------------------------------------
# traffic classes with per-class SLO targets
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One named slice of the workload with its latency SLO.

    ``weight`` is the relative arrival share used by
    ``assign_classes``; ``max_new_tokens`` optionally caps generation
    for the class (interactive traffic tends to be short); ``bulk``
    marks the class as low-priority batch traffic that the
    multi-replica router confines to its bulk replica slice
    (``repro.serving.router.Router(bulk_classes=...)`` — see
    ``bulk_class_names``).
    """

    name: str
    slo: SLOSpec = SLOSpec()
    weight: float = 1.0
    max_new_tokens: Optional[int] = None
    bulk: bool = False


def make_traffic_classes(spec: Mapping[str, Mapping]
                         ) -> List[TrafficClass]:
    """Build classes from the declaration form the ISSUE/workload spec
    uses::

        make_traffic_classes({
            "interactive": {"slo": {"ttft_s": 0.5, "itl_s": 0.1},
                            "weight": 3.0},
            "batch": {"slo": {"e2e_s": 60.0}},
        })

    A bare mapping of target names is also accepted as the ``slo``
    shorthand (``{"interactive": {"ttft_s": 0.5}}``).
    """
    classes: List[TrafficClass] = []
    for name, cfg in spec.items():
        cfg = dict(cfg)
        slo = cfg.pop("slo", None)
        if slo is None:
            # shorthand: the cfg itself is the target mapping
            slo = {k: cfg.pop(k) for k in list(cfg)
                   if k.endswith("_s")}
        if not isinstance(slo, SLOSpec):
            slo = SLOSpec.from_json(dict(slo))
        classes.append(TrafficClass(name=name, slo=slo, **cfg))
    return classes


def assign_classes(n_tasks: int, classes: Sequence[TrafficClass],
                   seed: int = 0) -> List[str]:
    """Deterministic weighted class assignment for ``n_tasks``."""
    if not classes:
        return [""] * n_tasks
    rng = np.random.default_rng(seed)
    weights = np.asarray([max(c.weight, 0.0) for c in classes],
                         dtype=np.float64)
    if weights.sum() <= 0.0:
        weights = np.ones(len(classes))
    probs = weights / weights.sum()
    names = [c.name for c in classes]
    idx = rng.choice(len(names), size=n_tasks, p=probs)
    return [names[i] for i in idx]


def slo_targets(classes: Sequence[TrafficClass]) -> Dict[str, SLOSpec]:
    """The ``{name: SLOSpec}`` mapping ``SLOMonitor`` consumes."""
    return {c.name: c.slo for c in classes}


def bulk_class_names(classes: Sequence[TrafficClass]) -> List[str]:
    """Names of the ``bulk=True`` classes — the ``bulk_classes``
    argument of ``repro.serving.router.Router``."""
    return [c.name for c in classes if c.bulk]


def request_deadline(arrival: float, cls: str,
                     targets: Dict[str, SLOSpec]) -> float:
    """Absolute deadline of one request: arrival + its class's e2e SLO
    target.  ``inf`` (class unknown or no e2e target) = never times
    out; the failure-aware serving path (``serving.faults``) sheds
    queued requests past this point before admission."""
    spec = targets.get(cls)
    if spec is None:
        return float("inf")
    return arrival + spec.target("e2e")
