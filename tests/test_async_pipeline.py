"""Async host pipeline coverage (ISSUE 6).

Acceptance properties:

  * window math — ``kvcache.window_target_tokens`` reduces to the old
    per-step rule at ``steps=1`` and clamps at the admission
    reservation, so rejection decisions are independent of N;
  * multi-step launch — ``model.decode_steps`` (one scanned launch)
    is bit-identical to N sequential ``decode_step`` calls, tokens and
    cache alike;
  * token identity — the engine's greedy output is identical at
    N ∈ {1, 2, 4} for stall and chunked prefill, including sequences
    finishing mid-window (caps not divisible by N) and with EOS
    enabled;
  * eviction lag — a slot decoding up to N-1 steps past its end never
    double-frees or corrupts still-referenced blocks: tight-pool and
    prefix-cache serves at N=4 end with a whole pool
    (``check_no_leaks``);
  * engine-vs-sim parity — completion order, rejection counts,
    utilization traces and the decode/prefill dispatch counters stay
    bit-for-bit at N ∈ {1, 2, 4} for fifo and rt-lm;
  * host-path bug sweep — the stall prefix-suffix rides the fused
    ragged executable (shape-key counters, engine == sim), the factory
    memo is bounded and weak, the jnp-fallback warning re-arms per
    serve, AOT warmup populates the executables it claims to and never
    changes tokens, and prefix cache + pool persist across serves
    behind the opt-in flag (warm hit rate, engine == sim via
    ``PrefixState``).
"""

import dataclasses
import gc
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import datagen, personas, priority as prio
from repro.core import scheduler as sched, simulator
from repro.kvcache import blocks_for_tokens, window_target_tokens
from repro.serving import generate
from repro.serving.engine import Request, ServingEngine, tokenize_padded
from repro.serving.pipeline import CompletionWorker

SLOTS = 3
MAX_NEW = 6
BUCKET = 8
BS = 4
CAPS = [2, 6, 1, 4, 6, 2, 3, 5, 1, 6, 2, 4]


def _persona(batch_size=SLOTS):
    return dataclasses.replace(personas.get_persona("bart"),
                               batch_size=batch_size)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("starcoder2-3b")
    from repro.models import model as model_lib
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 64, seed=0)
    train, test = datagen.train_test_split(corpus, train_frac=0.5)
    persona = _persona()
    profile = sched.offline_profile(train, persona, epochs=15)
    # cycle a few distinct texts so identical padded buckets repeat —
    # gives the prefix-cache tests full matches while staying harmless
    # for everything else
    texts = [test[i % 4].text for i in range(len(CAPS))]
    return cfg, params, persona, profile, texts


def _requests(texts, caps):
    return [Request(text=t, arrival=0.0, task_id=i, max_new_tokens=c)
            for i, (t, c) in enumerate(zip(texts, caps))]


def _sim_tasks(texts, caps, profile, persona, xi=2.0):
    out = []
    for i, (t, c) in enumerate(zip(texts, caps)):
        u = profile.predictor.score(t)
        d = prio.priority_point(0.0, len(t.split()), persona.phi,
                                None, xi=xi)
        out.append(prio.SimTask(
            task=Request(text=t, arrival=0.0, task_id=i),
            u=float(max(u, 0.0)), r=0.0, d=d,
            input_len=float(len(t.split())), true_out_len=int(c)))
    return out


def _prompt_tokens_fn(cfg, bucket=BUCKET):
    def fn(task):
        return tokenize_padded(task.task.text, cfg.vocab_size, bucket)
    return fn


def _make_engine(setup, policy_name="fifo", **kw):
    cfg, params, persona, profile, _ = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    return ServingEngine(
        params, cfg, sched.POLICIES[policy_name](persona, pcfg), profile,
        input_bucket=BUCKET, max_new_tokens=MAX_NEW, mode="continuous",
        eos_id=-1, kv="paged", kv_block_size=BS, **kw)


@pytest.fixture(scope="module")
def run(setup):
    """Memoized serve runner: identical (policy, kwargs) share one
    serve, keeping the module's device time bounded."""
    _, _, _, _, texts = setup
    cache = {}

    def _run(policy_name="fifo", **kw):
        key = (policy_name, tuple(sorted(kw.items())))
        if key not in cache:
            eng = _make_engine(setup, policy_name, **kw)
            res = eng.serve(_requests(texts, CAPS))
            cache[key] = (eng, res)
        return cache[key]

    return _run


def _toks(res):
    return {t.task.task_id: list(t.task.out_tokens) for t in res["tasks"]}


# ---------------------------------------------------------------------------
# window math + validation (host-side, no device work)
# ---------------------------------------------------------------------------


def test_window_target_tokens_formula():
    # steps=1 is the old per-step rule while the slot is live
    # (produced < cap): cover exactly through the next write position
    for produced in range(1, 6):
        assert window_target_tokens(8, produced, 6, 1) == 8 + produced
    # the clamp: never past the admission reservation prompt + cap - 1,
    # however deep the window runs past the sequence's end
    assert window_target_tokens(8, 5, 6, 4) == 8 + 6 - 1
    assert window_target_tokens(8, 1, 6, 99) == 8 + 6 - 1
    # monotone in steps up to the clamp — deeper windows never need
    # FEWER blocks, so the reservation gate is independent of N
    prev = 0
    for steps in range(1, 10):
        t = window_target_tokens(8, 2, 6, steps)
        assert prev <= t <= 8 + 6 - 1
        prev = t
    # a window never needs more blocks than the reservation holds back
    assert (blocks_for_tokens(window_target_tokens(8, 1, 6, 8), BS)
            <= blocks_for_tokens(8 + 6 - 1, BS))


def test_decode_steps_validation():
    cfg = configs.get_smoke_config("starcoder2-3b")
    persona = _persona()
    policy = sched.POLICIES["fifo"](persona, sched.PolicyConfig())
    with pytest.raises(ValueError, match="decode_steps"):
        ServingEngine(None, cfg, policy, None, mode="continuous",
                      decode_steps=0)
    with pytest.raises(ValueError, match="continuous"):
        ServingEngine(None, cfg, policy, None, mode="batch",
                      decode_steps=2)
    with pytest.raises(ValueError, match="slack"):
        ServingEngine(None, cfg, policy, None, mode="continuous",
                      decode_steps=32)
    with pytest.raises(ValueError, match="prefix_cache"):
        ServingEngine(None, cfg, policy, None, mode="continuous",
                      kv="paged", persist_prefix_cache=True)
    with pytest.raises(ValueError, match="decode_steps"):
        simulator.simulate_continuous([], policy, decode_steps=0)
    with pytest.raises(ValueError, match="prefix_cache"):
        simulator.simulate_continuous(
            [], policy, prefix_state=simulator.make_prefix_state(8, 4))


# ---------------------------------------------------------------------------
# completion worker
# ---------------------------------------------------------------------------


def test_completion_worker_fifo_and_error_propagation():
    with CompletionWorker() as w:
        w.submit(jnp.arange(3), time.perf_counter())
        w.submit({"a": jnp.ones((2,))}, time.perf_counter())
        host, dt = w.collect()                     # strictly FIFO
        np.testing.assert_array_equal(host, np.arange(3))
        assert dt >= 0.0
        host2, _ = w.collect()
        assert isinstance(host2["a"], np.ndarray)

    class _Boom:
        def __array__(self, *a, **k):
            raise RuntimeError("boom")

    w = CompletionWorker()
    try:
        w.submit(_Boom(), time.perf_counter())
        with pytest.raises(RuntimeError, match="boom"):
            w.collect()
    finally:
        w.close()


# ---------------------------------------------------------------------------
# host-path bug sweep: factory memo + fallback warning
# ---------------------------------------------------------------------------


def test_factory_memo_bounded_and_weak():
    test_keys = [("_test_memo", i) for i in range(generate._FN_LRU_CAP + 4)]
    try:
        handles = [generate._memoized(k, lambda: (lambda x: x))
                   for k in test_keys]
        # same key -> same executable while any strong ref lives
        assert generate._memoized(test_keys[-1], lambda: None) \
            is handles[-1]
        # the strong LRU is bounded however many keys flow through
        assert len(generate._fn_lru) <= generate._FN_LRU_CAP
        # weak memo: dropping every strong ref drops the entry
        weak_key = ("_test_memo_weak",)
        fn = generate._memoized(weak_key, lambda: (lambda x: x))
        assert generate._fn_memo.get(weak_key) is fn
        generate._fn_lru.pop(weak_key, None)
        del fn
        gc.collect()
        assert generate._fn_memo.get(weak_key) is None
        # unhashable key: memo skipped, fresh executable per call
        a = generate._memoized((["u"],), lambda: (lambda x: x))
        b = generate._memoized((["u"],), lambda: (lambda x: x))
        assert isinstance(a, generate.JitExecutable) and a is not b
    finally:
        for k in test_keys:
            generate._fn_lru.pop(k, None)


def test_fallback_warning_rearms(caplog):
    if jax.default_backend() == "tpu":
        pytest.skip("no jnp fallback on TPU")
    logger_name = "repro.serving.generate"
    generate.reset_fallback_warning()
    with caplog.at_level(logging.WARNING, logger=logger_name):
        generate.resolve_use_pallas(None)
        assert any("auto-detection" in r.message for r in caplog.records)
        caplog.clear()
        generate.resolve_use_pallas(None)          # consumed: silent
        assert not caplog.records
        generate.reset_fallback_warning()          # per-serve re-arm
        generate.resolve_use_pallas(None)
        assert any("auto-detection" in r.message for r in caplog.records)
    generate.reset_fallback_warning()


# ---------------------------------------------------------------------------
# multi-step decode launch
# ---------------------------------------------------------------------------


def test_decode_steps_scan_matches_sequential(setup):
    """One scanned N-step launch == N sequential decode launches, bit
    for bit — window tokens AND final cache."""
    cfg, params, *_ = setup
    toks = np.zeros((2, BUCKET), np.int32)
    toks[0, 2:] = np.arange(2, BUCKET) % (cfg.vocab_size - 2) + 2
    toks[1, 4:] = np.arange(4, BUCKET) % (cfg.vocab_size - 2) + 2
    prefill = generate.make_prefill_fn(cfg, BUCKET + 8)
    cache, last = prefill(params, {"tokens": jnp.asarray(toks)})
    tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    dec = generate.make_decode_fn(cfg)
    ds = generate.make_decode_steps_fn(cfg)
    window, cache_n = ds(params, cache, tok, num_steps=4)
    c, t, cols = cache, tok, []
    for _ in range(4):
        t, _, c = dec(params, c, t)
        cols.append(np.asarray(t)[:, 0])
    np.testing.assert_array_equal(np.asarray(window), np.stack(cols, 1))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), cache_n, c)
    # num_steps=1 is the single step exactly
    w1, _ = ds(params, cache, tok, num_steps=1)
    t1, _, _ = dec(params, cache, tok)
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(t1))


# ---------------------------------------------------------------------------
# token identity + eviction lag
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 4])
def test_token_identity_stall(run, n):
    """Caps of 1..6 with N ∈ {2, 4} finish all over the window
    interior — identity here is the eviction-lag invariant at work."""
    _, base = run(num_slots=SLOTS)
    eng, res = run(num_slots=SLOTS, decode_steps=n)
    assert _toks(res) == _toks(base)
    assert res["decode_steps_executed"] == n * res["decode_dispatches"]
    assert res["decode_dispatch_trace"] == (
        [n] * res["decode_dispatches"])
    assert res["decode_dispatches"] < base["decode_dispatches"]
    eng.allocator.check_no_leaks()


@pytest.mark.parametrize("n", [4])
def test_token_identity_chunked(run, n):
    _, base = run(num_slots=SLOTS, prefill="chunked", chunk_size=3,
                  token_budget=8)
    eng, res = run(num_slots=SLOTS, prefill="chunked", chunk_size=3,
                   token_budget=8, decode_steps=n)
    assert _toks(res) == _toks(base)
    assert res["decode_steps_executed"] == n * res["decode_dispatches"]
    # trace aligned with budget_trace: every entry is 0 or n
    assert set(res["decode_dispatch_trace"]) <= {0, n}
    assert len(res["decode_dispatch_trace"]) == len(res["budget_trace"])
    eng.allocator.check_no_leaks()


def test_token_identity_with_eos_enabled(setup):
    """EOS mid-window exercises the same finished-slot column discard
    as a cap; tokens must not depend on N with real EOS either."""
    _, _, _, _, texts = setup
    out = {}
    for n in (1, 4):
        eng = _make_engine(setup, num_slots=SLOTS, decode_steps=n)
        eng.eos_id = 1                      # the real EOS id
        out[n] = eng.serve(_requests(texts, CAPS))
        eng.allocator.check_no_leaks()
    assert _toks(out[1]) == _toks(out[4])


def test_eviction_lag_tight_pool_prefix(run):
    """The hard case: N=4, tight pool, prefix sharing — a finished
    slot holds blocks for up to 3 dead steps while OTHER sequences'
    admissions compete for the pool.  No double-free, no write into a
    freed block (identity), pool whole afterwards."""
    _, base = run(num_slots=4, kv_num_blocks=7)
    eng, res = run(num_slots=4, kv_num_blocks=7, decode_steps=4)
    assert _toks(res) == _toks(base)
    assert res["rejected_for_memory"] > 0        # pool actually binds
    eng.allocator.check_no_leaks()
    engp, resp = run(num_slots=SLOTS, prefix_cache=True, decode_steps=4)
    _, basep = run(num_slots=SLOTS, prefix_cache=True)
    assert _toks(resp) == _toks(basep) == _toks(run(num_slots=SLOTS)[1])
    engp.prefix_cache.clear()
    engp.allocator.check_no_leaks()


# ---------------------------------------------------------------------------
# engine-vs-sim parity at N > 1
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy_name", ["fifo", "rt-lm"])
@pytest.mark.parametrize("n", [1, 2, 4])
def test_engine_vs_sim_parity_async(setup, run, policy_name, n):
    """Tight budget (rejections bind): completion order, rejection
    count, utilization trace and BOTH dispatch counter families stay
    bit-for-bit at every window depth."""
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng, res = run(policy_name, num_slots=4, kv_num_blocks=7,
                   decode_steps=n)
    eng.allocator.check_no_leaks()
    sim = simulator.simulate_continuous(
        _sim_tasks(texts, CAPS, profile, persona),
        sched.POLICIES[policy_name](persona, pcfg),
        num_slots=4, kv_block_size=BS, kv_num_blocks=7,
        prompt_len=BUCKET, decode_steps=n)
    assert res["completion_order"] == [t.task.task_id for t in sim.tasks]
    assert res["rejected_for_memory"] == sim.kv_rejected
    np.testing.assert_allclose(res["kv_util_peak"], sim.kv_util_peak)
    np.testing.assert_allclose(res["kv_util_mean"], sim.kv_util_mean)
    assert res["decode_dispatches"] == sim.decode_dispatches
    assert res["decode_steps_executed"] == sim.decode_steps_executed
    assert res["decode_dispatch_trace"] == sim.decode_dispatch_trace
    assert res["prefill_dispatches"] == sim.prefill_dispatches
    assert res["prefill_dispatch_trace"] == sim.prefill_dispatch_trace


def test_engine_vs_sim_parity_async_chunked(setup, run):
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng, res = run(num_slots=SLOTS, prefill="chunked", chunk_size=3,
                   token_budget=8, decode_steps=4)
    sim = simulator.simulate_continuous(
        _sim_tasks(texts, CAPS, profile, persona),
        sched.POLICIES["fifo"](persona, pcfg),
        num_slots=SLOTS, kv_block_size=BS,
        kv_num_blocks=eng.kv_num_blocks, prompt_len=BUCKET,
        prefill="chunked", chunk_size=3, token_budget=8, decode_steps=4)
    assert res["completion_order"] == [t.task.task_id for t in sim.tasks]
    assert res["budget_trace"] == sim.budget_trace
    assert res["decode_dispatch_trace"] == sim.decode_dispatch_trace
    assert res["decode_dispatches"] == sim.decode_dispatches
    assert res["decode_steps_executed"] == sim.decode_steps_executed
    assert res["exec_cache_hits"] == sim.exec_cache_hits
    assert res["exec_cache_misses"] == sim.exec_cache_misses


# ---------------------------------------------------------------------------
# stall prefix-suffix rides the fused ragged executable
# ---------------------------------------------------------------------------


def test_stall_prefix_suffix_ragged_counters(setup, run):
    """Partial prefix hits route their uncached suffix through the
    fused ragged executable — the shape-key counters light up in stall
    mode now, and the simulator mirrors them exactly."""
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng, res = run(num_slots=SLOTS, prefix_cache=True)
    # repeats of the 4 cycled texts are full-prompt matches -> the
    # L=1 recompute suffix rides the ragged path
    assert res["prefix_hit_rate"] > 0
    assert res["exec_cache_misses"] >= 1
    sim = simulator.simulate_continuous(
        _sim_tasks(texts, CAPS, profile, persona),
        sched.POLICIES["fifo"](persona, pcfg),
        num_slots=SLOTS, kv_block_size=BS,
        kv_num_blocks=eng.kv_num_blocks, prompt_len=BUCKET,
        prefix_cache=True, prompt_tokens=_prompt_tokens_fn(cfg))
    assert res["exec_cache_hits"] == sim.exec_cache_hits
    assert res["exec_cache_misses"] == sim.exec_cache_misses
    assert res["prefix_hit_rate"] == sim.prefix_hit_rate
    assert res["cow_copies"] == sim.cow_copies
    # cache off: no prefix admissions, counters stay dark in stall mode
    _, plain = run(num_slots=SLOTS)
    assert plain["exec_cache_hits"] == plain["exec_cache_misses"] == 0


# ---------------------------------------------------------------------------
# prefix-cache persistence across serves
# ---------------------------------------------------------------------------


def test_prefix_persistence_engine_and_sim(setup):
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng = _make_engine(setup, num_slots=SLOTS, prefix_cache=True,
                       persist_prefix_cache=True)
    ra = eng.serve(_requests(texts, CAPS))
    pool_a = eng.paged_cache
    rb = eng.serve(_requests(texts, CAPS))
    assert eng.paged_cache is pool_a             # pool survived
    assert _toks(ra) == _toks(rb)
    assert rb["prefix_hit_rate"] > ra["prefix_hit_rate"]  # warm start
    assert rb["pipeline"]["persist_prefix_cache"] is True
    # tokens identical to a cold non-persistent serve
    engc = _make_engine(setup, num_slots=SLOTS, prefix_cache=True)
    rc = engc.serve(_requests(texts, CAPS))
    assert _toks(rc) == _toks(ra)
    # the simulator's PrefixState mirrors both serves' hit counters
    state = simulator.make_prefix_state(eng.kv_num_blocks, BS)
    sims = []
    for _ in range(2):
        sims.append(simulator.simulate_continuous(
            _sim_tasks(texts, CAPS, profile, persona),
            sched.POLICIES["fifo"](persona, pcfg),
            num_slots=SLOTS, kv_block_size=BS,
            kv_num_blocks=eng.kv_num_blocks, prompt_len=BUCKET,
            prefix_cache=True, prompt_tokens=_prompt_tokens_fn(cfg),
            prefix_state=state))
    for r, s in zip((ra, rb), sims):
        assert r["completion_order"] == [t.task.task_id for t in s.tasks]
        assert r["prefix_hit_rate"] == s.prefix_hit_rate
        assert r["cached_tokens_reused"] == s.cached_tokens_reused
        assert r["cow_copies"] == s.cow_copies
    # cleanup leaves the persistent pool whole
    eng.prefix_cache.clear()
    eng.allocator.check_no_leaks()


# ---------------------------------------------------------------------------
# AOT warmup
# ---------------------------------------------------------------------------


def test_aot_warmup_populates_and_preserves_tokens(setup, run):
    eng, res = run(num_slots=SLOTS, decode_steps=4)
    # the decode window executable was compiled ahead of time and the
    # serve dispatched through it
    assert eng._window_key in eng._paged_decode_steps.aot
    assert eng._admit_key in eng._paged_prefill.aot
    engc = _make_engine(setup, num_slots=SLOTS, decode_steps=4,
                        aot_warmup=False)
    rc = engc.serve(_requests(setup[4], CAPS))
    assert _toks(rc) == _toks(res)
    assert rc["pipeline"]["aot_warmup"] is False
    assert res["pipeline"]["aot_warmup"] is True
    assert res["pipeline"]["decode_steps"] == 4
