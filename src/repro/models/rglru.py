"""RG-LRU recurrent blocks (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t) is a
first-order linear recurrence; training/prefill uses
jax.lax.associative_scan (log-depth on TPU), decode is an O(1) state update.
Combined with 2048-window local attention (1 attn per 2 recurrent blocks),
decode state is bounded — long_500k eligible.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import layers

Array = jax.Array

_C = 8.0  # Griffin's fixed exponent scale


def init_rglru_block(key: Array, cfg, dtype) -> dict:
    D = cfg.d_model
    lw = cfg.lru_width or D
    cw = cfg.ssm_conv_width
    ks = jax.random.split(key, 7)
    # Lambda init so that a = sigmoid(L)^c is in [0.9, 0.999]
    u = jax.random.uniform(ks[5], (lw,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / _C) / (1.0 - u ** (1.0 / _C)))
    return {
        "w_x": layers.dense_init(ks[0], (D, lw), dtype),
        "w_gate_branch": layers.dense_init(ks[1], (D, lw), dtype),
        "conv_w": layers.dense_init(ks[2], (cw, lw), dtype, scale=0.1),
        "conv_b": jnp.zeros((lw,), dtype),
        "w_a": layers.dense_init(ks[3], (lw, lw), dtype),
        "b_a": jnp.zeros((lw,), jnp.float32),
        "w_i": layers.dense_init(ks[4], (lw, lw), dtype),
        "b_i": jnp.zeros((lw,), jnp.float32),
        "Lambda": lam,
        "w_out": layers.dense_init(ks[6], (lw, D), dtype),
    }


def _rglru_coeffs(params, x):
    """x: (..., lw) -> (a, gated_in) both f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32) + params["b_i"])
    log_a = -_C * r * jax.nn.softplus(-params["Lambda"])  # log sigmoid(L)^(c r)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, gated


def rglru_scan(a: Array, b: Array, h0: Optional[Array] = None):
    """Linear recurrence h_t = a_t h_{t-1} + b_t over axis=1.

    a, b: (B, S, lw) f32.  Returns (h: (B,S,lw), final_state (B,lw)).
    """
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    ah, bh = lax.associative_scan(combine, (a, b), axis=1)
    return bh, bh[:, -1]


def apply_recurrent_block(params: dict, x: Array, cfg,
                          state: Optional[dict] = None):
    """Griffin recurrent block. x: (B, S, D) -> (out, new_state).

    state = {"conv": (B, W-1, lw), "h": (B, lw)}.
    """
    branch = x @ params["w_x"]
    gate = jax.nn.gelu((x @ params["w_gate_branch"]).astype(jnp.float32))
    conv_in_state = None if state is None else state["conv"]
    # reuse the causal depthwise conv from ssm (silu act there; Griffin
    # uses no activation after conv -> use linear variant here)
    W = params["conv_w"].shape[0]
    if conv_in_state is None:
        conv_in_state = jnp.zeros((x.shape[0], W - 1, branch.shape[-1]),
                                  branch.dtype)
    xp = jnp.concatenate([conv_in_state, branch], axis=1)
    conv = sum(xp[:, i:i + branch.shape[1]] * params["conv_w"][i]
               for i in range(W)) + params["conv_b"]
    new_conv = xp[:, -(W - 1):]
    a, bterm = _rglru_coeffs(params, conv)
    h0 = None if state is None else state["h"]
    h, h_final = rglru_scan(a, bterm, h0)
    y = (h.astype(gate.dtype) * gate).astype(x.dtype)
    out = y @ params["w_out"]
    return out, {"conv": new_conv, "h": h_final}


def decode_recurrent_block(params: dict, x: Array, cfg, state: dict):
    """O(1) step. x: (B, 1, D)."""
    branch = x[:, 0] @ params["w_x"]                        # (B, lw)
    gate = jax.nn.gelu((x[:, 0] @ params["w_gate_branch"])
                       .astype(jnp.float32))
    conv_state = state["conv"]
    xp = jnp.concatenate([conv_state, branch[:, None]], axis=1)  # (B,W,lw)
    conv = jnp.einsum("bwc,wc->bc", xp, params["conv_w"]) + params["conv_b"]
    new_conv = xp[:, 1:]
    a, bterm = _rglru_coeffs(params, conv)
    h = state["h"] * a + bterm
    y = (h.astype(gate.dtype) * gate).astype(x.dtype)
    out = (y @ params["w_out"])[:, None]
    return out, {"conv": new_conv, "h": h}
