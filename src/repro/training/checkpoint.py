"""Checkpointing: flattened-pytree npz shards with metadata.

Large leaves are split across multiple ``.npz`` shard files so a single
file never exceeds ``shard_bytes`` (host-memory friendly); restore
reassembles and validates structure against a reference pytree.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp

_KEY_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(prefix + [str(k)], v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(prefix + [str(i)], v)
        else:
            flat[_KEY_SEP.join(prefix)] = node

    walk([], tree)
    return flat


def save(path: str, tree: Any, *, step: int = 0,
         shard_bytes: int = 1 << 30) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "keys": {}}
    shard, shard_idx, shard_sz = {}, 0, 0

    def flush():
        nonlocal shard, shard_idx, shard_sz
        if shard:
            np.savez(os.path.join(path, f"shard{shard_idx:05d}.npz"),
                     **shard)
            shard, shard_sz = {}, 0
            shard_idx += 1

    for key, leaf in sorted(flat.items()):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            manifest["keys"][key] = {"shard": shard_idx, "dtype": "bfloat16"}
        else:
            manifest["keys"][key] = {"shard": shard_idx,
                                     "dtype": str(arr.dtype)}
        safe = re.sub(r"[^\w/.-]", "_", key)
        shard[safe] = arr
        manifest["keys"][key]["name"] = safe
        shard_sz += arr.nbytes
        if shard_sz >= shard_bytes:
            flush()
    flush()
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like: Any) -> tuple:
    """Returns (tree, step). ``like`` supplies structure and dtypes."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    shards = {}

    def load_shard(i):
        if i not in shards:
            shards[i] = np.load(os.path.join(path, f"shard{i:05d}.npz"))
        return shards[i]

    flat_like = _flatten(like)
    missing = set(flat_like) - set(manifest["keys"])
    extra = set(manifest["keys"]) - set(flat_like)
    if missing or extra:
        raise ValueError(
            f"checkpoint mismatch: missing={sorted(missing)[:5]} "
            f"extra={sorted(extra)[:5]}")
    flat_new = {}
    for key, meta in manifest["keys"].items():
        arr = load_shard(meta["shard"])[meta["name"]]
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        flat_new[key] = jnp.asarray(arr)

    def rebuild(prefix, node):
        if isinstance(node, dict):
            return {k: rebuild(prefix + [str(k)], v)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(rebuild(prefix + [str(i)], v)
                              for i, v in enumerate(node))
        return flat_new[_KEY_SEP.join(prefix)]

    return rebuild([], like), manifest["step"]
