"""Request-lifecycle event/span recorder with Perfetto export.

Typed per-request lifecycle events flow through one ``TraceRecorder``:

    enqueue -> admit | reject | offload
            -> prefix_hit? -> prefill_chunk* -> first_token
            -> (decode_window / token)* -> complete -> evict

Each event is stamped with the engine's virtual clock (``ts``), the
iteration index (``step`` — decode steps executed so far, the shared
engine/sim iteration coordinate), and structured fields (slot,
uncertainty score, KV blocks held, dispatch shape key, ...).  The real
engine additionally records per-iteration SPANS (wall-clock
launch→readback durations of the prefill and decode-window dispatches)
and counter samples (KV-pool utilization) for the Perfetto timeline.

Parity discipline: ``ServingEngine`` and ``simulate_continuous`` emit
the SAME event stream from the same decision points, so
``parity_events()`` — every event minus its wall-clock fields (``ts``
and the per-token ``times``) — compares with ``==`` between engine and
simulator whenever their scheduling decisions agree
(tests/test_obs.py::test_engine_vs_sim_event_parity*).  Spans and
counter samples are wall-clock-only by construction and excluded.

Exports (zero dependencies beyond the stdlib):

  * ``to_jsonl`` / ``load_jsonl`` — one JSON object per line, lossless
    round-trip, the capture format ``scripts/trace_report.py`` reads;
  * ``to_perfetto`` — Chrome ``trace_event`` JSON (open in
    ``ui.perfetto.dev`` or ``chrome://tracing``): one track per
    request (derived queued/prefill/decode phase spans + instants),
    one engine track (iteration spans), counter tracks.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

#: wall-clock field names excluded from the engine-vs-sim parity view
#: (``attainment`` aggregates wall latencies; ``wall`` is the
#: engine-only extras dict on ``snapshot`` events)
WALL_FIELDS = frozenset({"ts", "dur", "times", "attainment", "wall"})

#: the typed event vocabulary (trace_report validates against it);
#: the fault/failure kinds (serving.faults) only appear when a run is
#: given a FaultPlan — unfaulted streams stay byte-identical to pre-
#: fault traces
EVENT_KINDS = frozenset({
    "enqueue", "admit", "reject", "offload", "prefix_hit", "exec_cache",
    "prefill_chunk", "first_token", "decode_window", "token", "evict",
    "complete", "bulk_batch", "snapshot", "route",
    "timeout", "shed", "retry", "failover", "replica_down", "replica_up",
    "dead_letter",
})


@dataclasses.dataclass
class Event:
    """One lifecycle event.  ``fields`` holds the structured payload;
    wall-clock members of it (``WALL_FIELDS``) are excluded from
    parity comparison alongside ``ts``."""

    kind: str
    ts: float
    task_id: Optional[int] = None
    step: Optional[int] = None
    fields: Dict = dataclasses.field(default_factory=dict)

    def parity_key(self) -> Tuple:
        payload = tuple(sorted(
            (k, _freeze(v)) for k, v in self.fields.items()
            if k not in WALL_FIELDS))
        return (self.kind, self.task_id, self.step, payload)

    def to_json(self) -> Dict:
        return {"type": "event", "kind": self.kind, "ts": self.ts,
                "task_id": self.task_id, "step": self.step,
                **self.fields}


@dataclasses.dataclass
class Span:
    """One wall-clock span on an engine-side track (iteration phases:
    prefill launch, decode window, bulk batch)."""

    name: str
    ts: float                       # span start (engine clock, seconds)
    dur: float                      # duration (seconds)
    track: str = "engine"
    fields: Dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict:
        return {"type": "span", "name": self.name, "ts": self.ts,
                "dur": self.dur, "track": self.track, **self.fields}


def _freeze(v):
    """Hashable, order-stable view of a field value for parity keys."""
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    return v


class TraceRecorder:
    """Append-only recorder with a bounded-memory guard.

    ``max_events`` caps retained events (spans and counter samples ride
    the same budget); past the cap, recording drops and counts — the
    guard that keeps tracing safe to leave on for million-request
    simulations.  ``dropped`` > 0 means the trace is a prefix, not a
    sample: exports stay valid, percentile tables note the truncation.
    """

    def __init__(self, max_events: int = 1_000_000):
        self.max_events = max_events
        self.events: List[Event] = []
        self.spans: List[Span] = []
        self.counters: List[Tuple[str, float, float]] = []  # name, ts, v
        self.dropped = 0
        #: run-level metadata (e.g. declared SLO targets) — written as
        #: a leading ``{"type": "meta", ...}`` JSONL line when nonempty
        self.meta: Dict = {}

    # ------------------------------------------------------------------
    def _budget(self) -> bool:
        if (len(self.events) + len(self.spans) + len(self.counters)
                >= self.max_events):
            self.dropped += 1
            return False
        return True

    def event(self, kind: str, ts: float, task_id: Optional[int] = None,
              step: Optional[int] = None, **fields) -> None:
        if self._budget():
            self.events.append(Event(kind=kind, ts=float(ts),
                                     task_id=task_id, step=step,
                                     fields=fields))

    def span(self, name: str, ts: float, dur: float,
             track: str = "engine", **fields) -> None:
        if self._budget():
            self.spans.append(Span(name=name, ts=float(ts),
                                   dur=float(dur), track=track,
                                   fields=fields))

    def counter(self, name: str, ts: float, value: float) -> None:
        if self._budget():
            self.counters.append((name, float(ts), float(value)))

    # ------------------------------------------------------------------
    def parity_events(self, replica=None) -> List[Tuple]:
        """The event stream minus wall-clock fields — the engine-vs-sim
        comparison view (spans/counters are wall-only and excluded).

        ``replica`` — restrict to one replica's substream of a
        multi-replica run (events whose ``replica`` field matches),
        excluding front-end ``route`` events: the router emits those
        before the replica does any work, so they belong to the pool
        view (compare them as ``[e for e in parity_events() if
        e[0] == "route"]``), not to any one replica's causal order.
        """
        if replica is None:
            return [e.parity_key() for e in self.events]
        return [e.parity_key() for e in self.events
                if e.kind != "route"
                and e.fields.get("replica") == replica]

    def task_ids(self) -> List[int]:
        seen: Dict[int, None] = {}
        for e in self.events:
            if e.task_id is not None:
                seen.setdefault(e.task_id)
        return list(seen)

    # ------------------------------------------------------------------
    # JSONL sink / source
    # ------------------------------------------------------------------
    def to_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            if self.meta:
                f.write(json.dumps({"type": "meta", **self.meta}) + "\n")
            for e in self.events:
                f.write(json.dumps(e.to_json()) + "\n")
            for s in self.spans:
                f.write(json.dumps(s.to_json()) + "\n")
            for name, ts, v in self.counters:
                f.write(json.dumps({"type": "counter", "name": name,
                                    "ts": ts, "value": v}) + "\n")
        return path

    @classmethod
    def load_jsonl(cls, path: str) -> "TraceRecorder":
        rec = cls(max_events=1 << 62)
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                obj = json.loads(line)
                typ = obj.pop("type", "event")
                if typ == "meta":
                    rec.meta.update(obj)
                elif typ == "span":
                    rec.span(obj.pop("name"), obj.pop("ts"),
                             obj.pop("dur"), obj.pop("track", "engine"),
                             **obj)
                elif typ == "counter":
                    rec.counter(obj["name"], obj["ts"], obj["value"])
                else:
                    rec.event(obj.pop("kind"), obj.pop("ts"),
                              obj.pop("task_id", None),
                              obj.pop("step", None), **obj)
        return rec

    # ------------------------------------------------------------------
    # Chrome/Perfetto trace_event export
    # ------------------------------------------------------------------
    _PID_REQUESTS = 1
    _PID_ENGINE = 2

    def to_perfetto(self) -> Dict:
        """Chrome ``trace_event`` JSON object (dump with ``json.dump``
        or via ``export_perfetto``).  Timestamps are microseconds.

        Per-request tracks (pid 1, tid = task id) carry derived phase
        spans — ``queued`` (enqueue→admit), ``prefill`` (admit→first
        token), ``decode`` (first token→complete) — plus instants for
        chunk launches, prefix hits, rejections and eviction.  The
        engine track (pid 2) carries the recorded wall-clock iteration
        spans; counter samples become ``C`` events.
        """
        us = 1e6
        out: List[Dict] = [
            {"ph": "M", "name": "process_name", "pid": self._PID_REQUESTS,
             "args": {"name": "requests"}},
            {"ph": "M", "name": "process_name", "pid": self._PID_ENGINE,
             "args": {"name": "engine"}},
        ]
        by_task: Dict[int, Dict[str, Event]] = {}
        for e in self.events:
            if e.task_id is None:
                continue
            slots = by_task.setdefault(e.task_id, {})
            # first occurrence wins for phase anchors
            slots.setdefault(e.kind, e)
        for tid, anchors in by_task.items():
            out.append({"ph": "M", "name": "thread_name",
                        "pid": self._PID_REQUESTS, "tid": tid,
                        "args": {"name": f"req {tid}"}})
            enq = anchors.get("enqueue")
            admit = anchors.get("admit") or anchors.get("offload")
            first = anchors.get("first_token")
            comp = anchors.get("complete")
            phases = [("queued", enq, admit), ("prefill", admit, first),
                      ("decode", first, comp)]
            for name, a, b in phases:
                if a is None or b is None:
                    continue
                out.append({"name": name, "ph": "X",
                            "pid": self._PID_REQUESTS, "tid": tid,
                            "ts": a.ts * us,
                            "dur": max(b.ts - a.ts, 0.0) * us,
                            "args": {**a.fields}})
        instant_kinds = {"prefill_chunk", "prefix_hit", "reject",
                         "evict", "exec_cache", "first_token"}
        for e in self.events:
            if e.kind not in instant_kinds or e.task_id is None:
                continue
            out.append({"name": e.kind, "ph": "i", "s": "t",
                        "pid": self._PID_REQUESTS, "tid": e.task_id,
                        "ts": e.ts * us,
                        "args": {"step": e.step,
                                 **{k: v for k, v in e.fields.items()
                                    if k not in WALL_FIELDS}}})
        for s in self.spans:
            out.append({"name": s.name, "ph": "X",
                        "pid": self._PID_ENGINE, "tid": 0,
                        "ts": s.ts * us, "dur": s.dur * us,
                        "args": dict(s.fields)})
        for name, ts, v in self.counters:
            out.append({"name": name, "ph": "C",
                        "pid": self._PID_ENGINE, "ts": ts * us,
                        "args": {"value": v}})
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export_perfetto(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_perfetto(), f)
        return path


# ---------------------------------------------------------------------------
# trace-derived request timelines (trace_report + the acceptance test)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RequestTimeline:
    """Per-request reconstruction from a trace's event stream."""

    task_id: int
    arrival: float = -1.0
    admit_ts: float = -1.0
    first_token_ts: float = -1.0
    complete_ts: float = -1.0
    token_times: List[float] = dataclasses.field(default_factory=list)
    chunks: int = 0
    rejected: int = 0
    cls: str = ""                   # traffic class (enqueue ``cls``)
    u: float = -1.0                 # predicted length (admit ``u``)
    out_len: int = -1               # realized length (complete)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_ts < 0 or self.arrival < 0:
            return None
        return self.first_token_ts - self.arrival

    @property
    def e2e(self) -> Optional[float]:
        if self.complete_ts < 0 or self.arrival < 0:
            return None
        return self.complete_ts - self.arrival

    @property
    def queue_wait(self) -> Optional[float]:
        if self.admit_ts < 0 or self.arrival < 0:
            return None
        return self.admit_ts - self.arrival

    @property
    def itls(self) -> List[float]:
        times = self.token_times
        if self.first_token_ts >= 0:
            times = [self.first_token_ts] + times
        return [b - a for a, b in zip(times, times[1:])]


def timelines(rec: TraceRecorder) -> Dict[int, RequestTimeline]:
    """Fold a recorder's event stream into per-request timelines —
    exactly the data ``_result`` computes TTFT/ITL from, so the
    trace-reconstructed percentiles match the serve results."""
    out: Dict[int, RequestTimeline] = {}

    def tl(tid: int) -> RequestTimeline:
        t = out.get(tid)
        if t is None:
            t = out[tid] = RequestTimeline(task_id=tid)
        return t

    for e in rec.events:
        tid = e.task_id
        if tid is None:
            continue
        t = tl(tid)
        if e.kind == "enqueue":
            t.arrival = e.ts
            t.cls = e.fields.get("cls", t.cls)
        elif e.kind == "admit" and t.admit_ts < 0:
            t.admit_ts = e.ts
            t.u = float(e.fields.get("u", t.u))
        elif e.kind == "first_token":
            t.first_token_ts = e.ts
        elif e.kind == "token":
            t.token_times.append(e.ts)
        elif e.kind == "complete":
            t.complete_ts = e.ts
            t.out_len = int(e.fields.get("out_len", t.out_len))
        elif e.kind == "prefill_chunk":
            t.chunks += 1
        elif e.kind == "reject":
            t.rejected += 1
    return out
