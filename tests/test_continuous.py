"""Continuous-batching coverage: engine-vs-sim parity (both execution
modes), slot recycling on the real JAX engine, and the no-regression
property vs run-to-completion FIFO on homogeneous outputs.

The parity tests use a saturated trace (every request arrives at t=0)
and EOS disabled with exact per-request output lengths, so scheduling
decisions depend only on task attributes — identical between the
wall-clock engine and the persona-latency simulator — and the completion
ORDER must match exactly even though the clocks differ.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import datagen, personas, priority as prio
from repro.core import scheduler as sched, simulator
from repro.models import model as model_lib, transformer
from repro.serving import generate
from repro.serving.engine import Request, ServingEngine, hash_tokenize

SLOTS = 3
MAX_NEW = 6
CAPS = [2, 6, 1, 4, 6, 2, 3, 5, 1]      # heterogeneous output lengths


def _persona(batch_size=SLOTS):
    return dataclasses.replace(personas.get_persona("bart"),
                               batch_size=batch_size)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 64, seed=0)
    train, test = datagen.train_test_split(corpus, train_frac=0.5)
    persona = _persona()
    profile = sched.offline_profile(train, persona, epochs=15)
    return cfg, params, persona, profile, test


def _requests(test, caps):
    return [Request(text=t.text, arrival=0.0, task_id=i,
                    max_new_tokens=c)
            for i, (t, c) in enumerate(zip(test, caps))]


def _sim_tasks(test, caps, profile, persona, xi=2.0):
    """Mirror ServingEngine._to_sim_task, with the true output length
    the engine will realise (EOS disabled, cap = exact length)."""
    out = []
    for i, (t, c) in enumerate(zip(test, caps)):
        u = profile.predictor.score(t.text)
        d = prio.priority_point(0.0, len(t.text.split()), persona.phi,
                                None, xi=xi)
        out.append(prio.SimTask(
            task=Request(text=t.text, arrival=0.0, task_id=i),
            u=float(max(u, 0.0)), r=0.0, d=d,
            input_len=float(len(t.text.split())), true_out_len=int(c)))
    return out


@pytest.mark.parametrize("mode", ["batch", "continuous"])
@pytest.mark.parametrize("policy_name", ["fifo", "rt-lm"])
def test_engine_vs_sim_completion_order(setup, mode, policy_name):
    """Same arrivals -> same completion order, engine vs simulator, in
    both execution modes (the deterministic saturated-trace setup)."""
    cfg, params, persona, profile, test = setup
    # tau=inf: no CPU offload — the engine's bulk lane is serialized
    # while the sim's CPU lane runs concurrently, so cross-lane
    # interleaving is the one place order parity legitimately differs.
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)

    engine = ServingEngine(
        params, cfg, sched.POLICIES[policy_name](persona, pcfg), profile,
        input_bucket=8, max_new_tokens=MAX_NEW, mode=mode, eos_id=-1)
    res = engine.serve(_requests(test, CAPS))

    sim_fn = (simulator.simulate_continuous if mode == "continuous"
              else simulator.simulate)
    sim_res = sim_fn(_sim_tasks(test, CAPS, profile, persona),
                     sched.POLICIES[policy_name](persona, pcfg))
    sim_order = [t.task.task_id for t in sim_res.tasks]

    assert res["n_tasks"] == len(CAPS) == len(sim_res.tasks)
    assert res["completion_order"] == sim_order
    if mode == "continuous":
        # EOS disabled: the engine realised exactly the sim's lengths
        by_id = {t.task.task_id: t for t in res["tasks"]}
        for i, c in enumerate(CAPS):
            assert by_id[i].task.out_len == c


def test_slot_recycling_on_engine(setup):
    """A slot evicted at decode step k is re-admitted at step k (before
    the next decode step), and every request realises its exact length."""
    cfg, params, persona, profile, test = setup
    persona2 = _persona(batch_size=2)
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    engine = ServingEngine(
        params, cfg, sched.POLICIES["fifo"](persona2, pcfg), profile,
        input_bucket=8, max_new_tokens=MAX_NEW, mode="continuous",
        eos_id=-1)
    caps = [2, 6, 2, 4, 3]
    res = engine.serve(_requests(test, caps))
    assert res["n_tasks"] == len(caps)

    log = engine.admission_log
    assert len(log) == len(caps)                 # every request admitted
    assert {e["slot"] for e in log} == {0, 1}    # both slots used
    # recycling latency: an occupant admitted at step s with cap L
    # leaves at step s + (L - 1) (prefill emits token 1); the next
    # admission into that slot happens at exactly that step — i.e. the
    # freed slot is refilled before the following decode step.
    last_free = {}
    for e in log:
        cap = max(1, caps[e["task_id"]])
        if e["slot"] in last_free:
            assert e["step"] == last_free[e["slot"]]
        last_free[e["slot"]] = e["step"] + (cap - 1)
    # the per-slot cache from the serve is exposed and per-slot shaped
    assert engine.slot_cache is not None
    assert engine.slot_cache["pos"].shape == (2,)


def _slot_rows(cache: dict, slot: int) -> dict:
    """Extract slot ``slot``'s rows, mirroring write_slot's axis rule."""
    out = {}
    for key, big in cache.items():
        if key in ("pos", "slot_pos"):
            out[key] = np.asarray(big[slot])
        else:
            ax = 1 if key.startswith("scan") else 0
            out[key] = jax.tree.map(
                lambda b: np.asarray(jnp.take(b, slot, axis=ax)), big)
    return out


def _assert_tree_equal(a, b):
    la = jax.tree.leaves(a)
    lb = jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_write_slot_resets_evicted_kv(setup):
    """Re-admitting into a recycled slot fully replaces the evicted
    sequence's KV/recurrent state (bit-identical to a fresh cache) and
    leaves the neighbouring slot untouched."""
    cfg, params, _, _, test = setup
    max_len = 24
    S = 8

    def tok_batch(text):
        arr = np.zeros((1, S), np.int32)
        seq = hash_tokenize(text, cfg.vocab_size, S)
        arr[0, S - len(seq):] = seq
        return {"tokens": jnp.asarray(arr)}

    decode = generate.make_decode_fn(cfg)
    cache = transformer.init_slot_cache(cfg, 2, max_len)
    cache, _ = model_lib.prefill_into_slot(
        params, cfg, cache, tok_batch(test[0].text), 0, max_len)
    cache, _ = model_lib.prefill_into_slot(
        params, cfg, cache, tok_batch(test[1].text), 1, max_len)
    tok = jnp.full((2, 1), 5, jnp.int32)
    for _ in range(3):                       # advance both sequences
        tok, _, cache = decode(params, cache, tok)
    neighbour_before = _slot_rows(cache, 1)

    recycled, _ = model_lib.prefill_into_slot(
        params, cfg, cache, tok_batch(test[2].text), 0, max_len)
    fresh = transformer.init_slot_cache(cfg, 2, max_len)
    fresh, _ = model_lib.prefill_into_slot(
        params, cfg, fresh, tok_batch(test[2].text), 0, max_len)

    _assert_tree_equal(_slot_rows(recycled, 0), _slot_rows(fresh, 0))
    _assert_tree_equal(_slot_rows(recycled, 1), neighbour_before)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
@pytest.mark.parametrize("out_len", [3, 8])
def test_continuous_no_regression_homogeneous_fifo(seed, out_len):
    """On homogeneous output lengths under FIFO, continuous batching
    never increases ANY request's response time vs run-to-completion
    (it removes head-of-line blocking and dispatch wait, and on a full
    homogeneous batch costs no more than the batch model)."""
    persona = personas.get_persona("dialogpt")
    rng = np.random.default_rng(seed)
    n = 30
    arrivals = np.cumsum(rng.exponential(0.2, n))
    tasks = [prio.SimTask(task=i, u=5.0, r=float(r), d=float(r) + 4.0,
                          input_len=5.0, true_out_len=out_len)
             for i, r in enumerate(arrivals)]
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=1e18)
    rtc = simulator.run_policy(tasks, "fifo", persona, pcfg, mode="batch")
    cont = simulator.run_policy(tasks, "fifo", persona, pcfg,
                                mode="continuous")
    rt_batch = {t.task: t.response_time for t in rtc.tasks}
    rt_cont = {t.task: t.response_time for t in cont.tasks}
    assert set(rt_batch) == set(rt_cont)
    for i in rt_batch:
        assert rt_cont[i] <= rt_batch[i] + 1e-9
