"""Mamba-2 SSD: chunked scan vs naive recurrence; decode continuation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import ssm


def naive_ssd(x, a, B_, C_):
    """Direct recurrence h_t = exp(a_t) h_{t-1} + dt-scaled outer."""
    b, s, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    rep = h // g
    Bh = jnp.repeat(B_, rep, axis=2)
    Ch = jnp.repeat(C_, rep, axis=2)
    hstate = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        decay = jnp.exp(a[:, t])[..., None, None]
        hstate = hstate * decay + jnp.einsum(
            "bhn,bhp->bhpn", Bh[:, t], x[:, t])
        ys.append(jnp.einsum("bhn,bhpn->bhp", Ch[:, t], hstate))
    return jnp.stack(ys, axis=1), hstate


@pytest.mark.parametrize("chunk", [4, 8, 64])
def test_ssd_chunked_matches_naive(chunk):
    key = jax.random.PRNGKey(0)
    b, s, h, p, g, n = 2, 24, 4, 8, 2, 6
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    a = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    B_ = jax.random.normal(ks[2], (b, s, g, n))
    C_ = jax.random.normal(ks[3], (b, s, g, n))
    y, final = ssm.ssd_chunked(x, a, B_, C_, chunk)
    y_ref, final_ref = naive_ssd(x, a, B_, C_)
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(final, final_ref, atol=1e-4, rtol=1e-4)


def test_mamba2_decode_matches_prefill():
    """Prefill of S tokens == S single-token decode steps."""
    cfg = configs.get_smoke_config("mamba2-1.3b")
    key = jax.random.PRNGKey(1)
    params = ssm.init_mamba2(key, cfg, jnp.float32)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
    y_seq, state_seq = ssm.apply_mamba2(params, x, cfg, None)

    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    state = {"conv": jnp.zeros((B, cfg.ssm_conv_width - 1, conv_dim)),
             "ssd": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim,
                               cfg.ssm_state))}
    ys = []
    for t in range(S):
        y_t, state = ssm.decode_mamba2(params, x[:, t:t + 1], cfg, state)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_dec, y_seq, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(state["ssd"], state_seq["ssd"],
                               atol=1e-3, rtol=1e-3)


def test_ssd_chunk_continuation():
    """Two chunked calls with carried state == one long call."""
    key = jax.random.PRNGKey(3)
    b, s, h, p, g, n = 1, 16, 2, 4, 1, 4
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    a = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    B_ = jax.random.normal(ks[2], (b, s, g, n))
    C_ = jax.random.normal(ks[3], (b, s, g, n))
    y_full, final_full = ssm.ssd_chunked(x, a, B_, C_, 4)
    y1, st = ssm.ssd_chunked(x[:, :8], a[:, :8], B_[:, :8], C_[:, :8], 4)
    y2, final2 = ssm.ssd_chunked(x[:, 8:], a[:, 8:], B_[:, 8:], C_[:, 8:],
                                 4, init_state=st)
    np.testing.assert_allclose(
        jnp.concatenate([y1, y2], 1), y_full, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(final2, final_full, atol=1e-4, rtol=1e-4)
