"""FlashAttention-2-style prefill attention as a Pallas TPU kernel.

TPU adaptation of the FA2 GPU algorithm (DESIGN.md §2):
  * tiles live in VMEM via explicit BlockSpecs; MXU-aligned block shapes
    (block_q x block_k = 128 x 128 by default, multiples of the 128-lane
    MXU systolic dimension);
  * the online-softmax running state (m, l, acc) sits in VMEM scratch and
    persists across the innermost sequential grid dimension (kv blocks) —
    the TPU analogue of FA2's per-SM register accumulators;
  * GQA is handled in the BlockSpec index_map (kv head = h // G), so the
    expanded K/V are never materialized in HBM;
  * causal/sliding-window masking is positional, computed on the tile.

VMEM budget per grid step (defaults, bf16 in / f32 accum):
    q (128x128x2) + k,v (2x128x128x2) + s (128x128x4) + acc (128x128x4)
    + m,l (2x128x4)  ~= 230 KiB  << 16 MiB v5e VMEM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: Optional[int],
               block_q: int, block_k: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kpos < kv_len                         # padded kv tail
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) -> (B, Sq, H, D).

    Positions are assumed aligned (prefill): q position i == kv position
    i.  Sq/Sk are padded to block multiples internally.
    """
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / (D ** 0.5)

    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    nq = (Sq + pq) // block_q
    nk = (Sk + pk) // block_k

    # (B, S, H, D) -> (B*H, S, D) without materializing per-head copies:
    # pallas indexes the transposed view lazily via BlockSpecs.
    qt = qp.transpose(0, 2, 1, 3).reshape(B * H, Sq + pq, D)
    kt = kp.transpose(0, 2, 1, 3).reshape(B * KV, Sk + pk, D)
    vt = vp.transpose(0, 2, 1, 3).reshape(B * KV, Sk + pk, D)

    def kv_index(bh, qi, ki):
        return ((bh // H) * KV + (bh % H) // G, ki, 0)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_k, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq + pq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),    # m — running max
            pltpu.VMEM((block_q,), jnp.float32),    # l — running sum
            pltpu.VMEM((block_q, D), jnp.float32),  # acc — running out
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.reshape(B, H, Sq + pq, D).transpose(0, 2, 1, 3)
    return out[:, :Sq]
