"""Uncertainty-aware request router over R serving replicas.

RT-LM's system-level scheduler (paper §5) becomes real at pod scale:
multiple engine replicas behind a placement layer.  ``Router`` is that
layer — a pure, deterministic policy object with NO engine imports, so
the exact same instance can be driven by the real front-end
(``repro.serving.replica.ReplicatedEngine``) and by the simulator
(``repro.core.simulator.simulate_replicated``); placement decisions
parity-match bit for bit because both sides feed it bitwise-identical
``ReplicaView``s.

Three policies (``ROUTER_POLICIES``):

  * ``round_robin`` — cycle through the eligible replicas (one cursor
    per eligibility group, so a bulk slice cycles independently);
  * ``least_queue`` — fewest placed-but-unfinished requests, ties to
    the lowest replica id;
  * ``rtlm``        — the headline uncertainty-aware score (lower is
    better): predicted-uncertainty-weighted queue cost plus KV-pool
    reservation pressure,

        score = (1 + (u_load + u) / u_scale) * (queued + 1)
                + need / max(free_blocks, 1)

    where ``u`` is the arriving request's predicted output length
    (the offline profile's uncertainty proxy), ``u_load`` the sum of
    predicted lengths already placed, and ``need`` the arrival's
    worst-case block reservation (``kvcache.blocks_for_tokens`` — the
    admission gate's own formula).  The score is monotone increasing
    in ``u`` and decreasing in ``free_blocks``: high-uncertainty
    requests are steered away from loaded, memory-tight replicas —
    the paper's uncertainty-aware prioritization applied to placement.

Bulk replica slice (the paper's dynamic-consolidation/offload lane):
``bulk_replicas`` designates low-priority replicas and
``bulk_classes`` the traffic classes confined to them; interactive
(non-bulk) classes are NEVER placed on a bulk replica, so batch
traffic cannot inflate the interactive tail.

Admissibility gate: an arrival whose reservation can never fit a
replica's pool (``need > num_blocks``) is ineligible there — the
router refuses placements the engine's admission gate would deadlock
on.

Health gating (``serving.faults``): ``ReplicaView.health`` carries the
circuit-breaker state (``closed``/``half_open``/``open``); every policy
skips ``open`` replicas, and a ``half_open`` replica is eligible as a
probe.  When gating (or admissibility) empties the eligible set,
``place`` raises ``NoEligibleReplica`` — a ``ValueError`` subclass so
pre-fault callers are unchanged — which the fault coordinator converts
into a counted dead-letter outcome instead of a hang.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

#: placement policies, in documentation order
ROUTER_POLICIES = ("round_robin", "least_queue", "rtlm")


class NoEligibleReplica(ValueError):
    """No replica can take this request (bulk-slice eligibility,
    admissibility and health gating left an empty set)."""


@dataclasses.dataclass(frozen=True)
class ReplicaView:
    """One replica's load as the router sees it at placement time.

    The simulator builds views from live ``_ReplicaSim`` state
    (``_ReplicaSim.load()``); the engine front-end builds them from its
    placement bookkeeping — on all-at-t0 traces (every placement before
    any engine work) the two are bitwise identical, which is what makes
    routing decisions engine-vs-sim parity-comparable.
    """

    replica: int
    queued: int = 0        # placed-but-unfinished (queue + in-flight)
    active: int = 0        # occupied decode slots
    free_blocks: int = 0   # KV-pool headroom in blocks (0 if unpaged)
    num_blocks: int = 0    # KV-pool capacity (admissibility gate;
    #                        0 = unpaged, gate inapplicable)
    u_load: float = 0.0    # summed predicted output lengths in flight
    is_bulk: bool = False  # member of the low-priority bulk slice
    health: str = "closed"  # circuit-breaker state (serving.faults):
    #                         "open" replicas are skipped by every policy


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Placement outcome: chosen replica, the policy's score for it
    (policy-specific units: rtlm cost, queue depth, or the round-robin
    cursor's pick), and the policy name — the ``route`` event payload."""

    replica: int
    score: float
    policy: str


class Router:
    """Pluggable placement policy over R replicas (see module docs).

    Stateless per decision except the round-robin cursors, so one
    instance must NOT be shared between an engine run and a sim run
    that are meant to parity-match — give each side a fresh instance
    with identical configuration.
    """

    def __init__(self, R: int, policy: str = "round_robin", *,
                 bulk_replicas: Sequence[int] = (),
                 bulk_classes: Sequence[str] = (),
                 u_scale: float = 8.0):
        if R < 1:
            raise ValueError(f"R must be >= 1, got {R}")
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"expected one of {ROUTER_POLICIES}")
        bulk = sorted({int(b) for b in bulk_replicas})
        if any(b < 0 or b >= R for b in bulk):
            raise ValueError(f"bulk_replicas {bulk} out of range for "
                             f"R={R}")
        if bulk and len(bulk) == R:
            raise ValueError("bulk_replicas covers every replica — "
                             "interactive classes would have no "
                             "placement target")
        if u_scale <= 0:
            raise ValueError(f"u_scale must be > 0, got {u_scale}")
        self.R = R
        self.policy = policy
        self.bulk_replicas: Tuple[int, ...] = tuple(bulk)
        self.bulk_classes: Tuple[str, ...] = tuple(bulk_classes)
        self.u_scale = float(u_scale)
        self._rr_cursor: Dict[Tuple[int, ...], int] = {}

    # ------------------------------------------------------------------
    def is_bulk(self, replica: int) -> bool:
        return replica in self.bulk_replicas

    def eligible(self, cls: str = "") -> List[int]:
        """Replica ids a request of traffic class ``cls`` may be placed
        on: bulk classes get the bulk slice, everything else the
        non-bulk replicas; with no slice configured, all replicas."""
        if not self.bulk_replicas:
            return list(range(self.R))
        if cls and cls in self.bulk_classes:
            return list(self.bulk_replicas)
        return [r for r in range(self.R)
                if r not in self.bulk_replicas]

    def score(self, view: ReplicaView, *, u: float = 0.0,
              need: int = 0) -> float:
        """The rtlm placement cost (lower is better) — monotone
        increasing in ``u`` and ``u_load``, decreasing in
        ``free_blocks`` (see module docs for the formula)."""
        qcost = ((1.0 + (view.u_load + u) / self.u_scale)
                 * (view.queued + 1.0))
        return qcost + need / float(max(view.free_blocks, 1))

    # ------------------------------------------------------------------
    def place(self, views: Sequence[ReplicaView], *, u: float = 0.0,
              cls: str = "", need: int = 0) -> RouteDecision:
        """Choose a replica for one arrival.

        ``views`` — one ``ReplicaView`` per replica, index-aligned;
        ``u`` — the arrival's predicted output length;
        ``cls`` — its traffic class (bulk-slice eligibility);
        ``need`` — its worst-case block reservation
        (``kvcache.blocks_for_tokens``; 0 when unpaged).
        """
        if len(views) != self.R:
            raise ValueError(f"expected {self.R} views, got "
                             f"{len(views)}")
        elig = self.eligible(cls)
        # health gate: circuit-broken replicas take no traffic
        # (half-open replicas stay eligible as probes)
        elig = [r for r in elig if views[r].health != "open"]
        if need > 0:
            # admissibility: a pool that can never hold the reservation
            # is out (num_blocks == 0 marks an unpaged replica — no gate)
            elig = [r for r in elig
                    if views[r].num_blocks <= 0
                    or need <= views[r].num_blocks]
        if not elig:
            raise NoEligibleReplica(
                f"no eligible replica for cls={cls!r} need={need} "
                f"(bulk_replicas={self.bulk_replicas}, "
                f"bulk_classes={self.bulk_classes})")
        if self.policy == "round_robin":
            group = tuple(elig)
            k = self._rr_cursor.get(group, 0)
            r = elig[k % len(elig)]
            self._rr_cursor[group] = (k + 1) % len(elig)
            return RouteDecision(replica=r, score=float(r),
                                 policy=self.policy)
        if self.policy == "least_queue":
            r = min(elig, key=lambda k: (views[k].queued, k))
            return RouteDecision(replica=r,
                                 score=float(views[r].queued),
                                 policy=self.policy)
        # rtlm: lowest uncertainty-weighted cost, ties to lowest id
        r = min(elig, key=lambda k: (self.score(views[k], u=u,
                                                need=need), k))
        return RouteDecision(replica=r,
                             score=self.score(views[r], u=u, need=need),
                             policy=self.policy)
