"""RT-LM core: the paper's contribution.

  rulegen    — six linguistic-uncertainty rules (RULEGEN)
  predictor  — lightweight MLP m_theta: rule scores -> output length
  priority   — Eq. 2 slack / Eq. 3 uncertainty-aware priorities
  scheduler  — Algorithm 1 UASCHED + FIFO/HPF/LUF/MUF baselines
  simulator  — discrete-event serving-node model (GPU + CPU lanes)
  workload   — Poisson traces (beta = 10..150 q/min, xi batching window)
  datagen    — six-type synthetic corpora + benchmark-dataset mixes
  personas   — published per-LM coefficient profiles (C_f, tau_f, eta_f,
               phi_f for DialoGPT/GODEL/BlenderBot/BART/T5)
"""

from . import (datagen, personas, predictor, priority, rulegen,  # noqa
               scheduler, simulator, workload)
