#!/usr/bin/env bash
# CI entry point (also runnable locally): docs checks first (cheapest
# signal), then the serving subsystem modules, then the fast lane,
# then the full tier-1 suite.
#
#   scripts/ci.sh          # docs + subsystem modules + fast lane + tier-1
#   CI_FAST_ONLY=1 scripts/ci.sh   # skip the full tier-1 pass
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== hygiene: no tracked bytecode =="
if git ls-files | grep -E '(\.pyc$|__pycache__/)' ; then
  echo "ERROR: compiled bytecode is tracked; git rm it" >&2
  exit 1
fi

echo "== docs: markdown links + quickstart smoke =="
python scripts/check_docs.py
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py

echo "== serving subsystems (quick signal) =="
# per-test wall-clock cap when pytest-timeout is installed (the fault
# tests exercise hang-prone failover paths; a hang should fail, not
# wedge the lane) — optional locally, installed in CI
TIMEOUT_ARGS=()
if python -c "import pytest_timeout" >/dev/null 2>&1; then
  TIMEOUT_ARGS=(--timeout 120)
fi
scripts/run_tier1.sh -m "not slow" "${TIMEOUT_ARGS[@]}" \
  tests/test_chunked_prefill.py \
  tests/test_prefix_cache.py tests/test_async_pipeline.py \
  tests/test_kernels.py tests/test_obs.py tests/test_slo.py \
  tests/test_router.py tests/test_faults.py

echo "== trace/SLO report smoke (checked-in mini trace) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/trace_report.py \
  tests/data/mini_trace.jsonl --json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/slo_report.py \
  tests/data/mini_trace.jsonl --json

echo "== fast lane (-m 'not slow') =="
scripts/run_tier1.sh -m "not slow" --ignore=tests/test_chunked_prefill.py \
  --ignore=tests/test_prefix_cache.py \
  --ignore=tests/test_async_pipeline.py --ignore=tests/test_kernels.py \
  --ignore=tests/test_obs.py --ignore=tests/test_slo.py \
  --ignore=tests/test_router.py --ignore=tests/test_faults.py

if [[ "${CI_FAST_ONLY:-0}" != "1" ]]; then
  echo "== full tier-1 =="
  scripts/run_tier1.sh
fi
