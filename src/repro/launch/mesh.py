"""Production mesh construction (TPU v5e target).

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — jax locks the device count on
first backend initialization, and only launch/dryrun.py is allowed to
set the 512-placeholder-device XLA flag before that happens.
"""

from __future__ import annotations

import math

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """Version-compat shim: jax.sharding.AxisType (and the axis_types
    kwarg of jax.make_mesh) only exist on newer jax releases.  Older
    versions behave as Auto everywhere, so omitting the kwarg is
    semantically identical."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = math.prod(shape)
    have = len(jax.devices())
    if have < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices, have {have} — run under "
            f"launch/dryrun.py (XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=512)")
    return jax.make_mesh(
        shape, axes, devices=jax.devices()[:ndev],
        **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary (test-scale) mesh over the first prod(shape) devices."""
    ndev = math.prod(shape)
    return jax.make_mesh(
        tuple(shape), tuple(axes), devices=jax.devices()[:ndev],
        **_axis_type_kwargs(len(axes)))


def replica_groups(R: int, devices=None):
    """Device groups for R serving replicas (PR 9 multi-replica pool).

    With at least R devices the replicas get contiguous equal
    data-parallel slices (leftover devices stay unused — equal pools
    keep the replicas interchangeable for the router).  With fewer
    devices than replicas the groups wrap round-robin onto single
    devices: R engine instances time-sharing one host device, the CPU
    test case ``serving.replica.ReplicatedEngine`` models.
    """
    if R < 1:
        raise ValueError(f"R must be >= 1, got {R}")
    devs = list(devices) if devices is not None else list(jax.devices())
    if not devs:
        raise RuntimeError("no devices available for replica_groups")
    if len(devs) >= R:
        per = len(devs) // R
        return [devs[r * per:(r + 1) * per] for r in range(R)]
    return [[devs[r % len(devs)]] for r in range(R)]
