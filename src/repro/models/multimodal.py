"""Modality-frontend STUBS for the [vlm] and [audio] architectures.

Per the assignment carve-out, the ViT/SigLIP vision encoder and the
mel-spectrogram + conv feature extractor are NOT implemented; instead
``input_specs()`` (launch/dryrun.py) provides precomputed patch / frame
embeddings of the right shape, and this module provides

  * the trainable projector that maps frontend embeddings into the
    language model's embedding space (the LLaVA-style ``mm_projector``),
  * helpers to synthesize random embeddings for smoke tests / examples.

The language / decoder transformer that CONSUMES these embeddings is fully
implemented in ``repro.models``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers

Array = jax.Array


def init_projector(key: Array, cfg, dtype) -> dict:
    """Two-layer MLP projector (LLaVA-1.5+ style mlp2x_gelu)."""
    D = cfg.d_model
    k1, k2 = jax.random.split(key)
    return {
        "w1": layers.dense_init(k1, (D, D), dtype),
        "b1": jnp.zeros((D,), dtype),
        "w2": layers.dense_init(k2, (D, D), dtype),
        "b2": jnp.zeros((D,), dtype),
    }


def apply_projector(params: dict, emb: Array) -> Array:
    """emb: (B, T_front, D) frontend embeddings -> LM embedding space."""
    h = jax.nn.gelu(emb @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def stub_patch_embeddings(key: Array, batch: int, cfg,
                          dtype=jnp.bfloat16) -> Array:
    """Random stand-in for ViT anyres patch embeddings (smoke/examples)."""
    return jax.random.normal(
        key, (batch, cfg.num_patch_tokens, cfg.d_model), jnp.float32
    ).astype(dtype)


def stub_frame_embeddings(key: Array, batch: int, cfg,
                          dtype=jnp.bfloat16) -> Array:
    """Random stand-in for conv-encoded audio frame embeddings."""
    return jax.random.normal(
        key, (batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32
    ).astype(dtype)
