"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

These are the *semantic* references: naive, unchunked, numerically
straightforward.  The production jnp fallback in repro.models.layers is
the chunked flash-style implementation; tests close the triangle
(pallas ~= ref, layers ~= ref).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  q_positions=None, kv_positions=None):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(k.shape[1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kv_positions[None, :] <= q_positions[:, None]
    if window is not None:
        mask &= (q_positions[:, None] - kv_positions[None, :]) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, *, mask):
    """q: (B, H, D); caches: (B, S, KV, D); mask: (B, S) or (S,) bool."""
    B, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    if G > 1:
        k_cache = jnp.repeat(k_cache, G, axis=2)
        v_cache = jnp.repeat(v_cache, G, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / (D ** 0.5)
    if mask.ndim == 1:
        mask = mask[None]
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, seq_lens):
    """q: (B, H, D); pages: (N, bs, KV, D); block_tables: (B, nb) i32;
    seq_lens: (B,) i32.  Pure-jnp fallback: materialize each sequence's
    contiguous view via the block table, then ordinary decode attention.
    """
    N, bs = k_pages.shape[:2]
    B, nb = block_tables.shape
    idx = (block_tables[:, :, None] * bs
           + jnp.arange(bs)[None, None, :]).reshape(B, nb * bs)
    k = jnp.take(k_pages.reshape((N * bs,) + k_pages.shape[2:]), idx,
                 axis=0)
    v = jnp.take(v_pages.reshape((N * bs,) + v_pages.shape[2:]), idx,
                 axis=0)
    mask = jnp.arange(nb * bs)[None, :] < seq_lens[:, None]
    return decode_attention_ref(q, k, v, mask=mask)


def chunked_prefill_attention_ref(q, k_pages, v_pages, block_tables,
                                  ctx_lens):
    """q: (B, T, H, D) chunk queries; pages: (N, bs, KV, D);
    block_tables: (B, nb) i32; ctx_lens: (B,) i32 prior-context
    lengths.  Pages must already hold each row's chunk K/V at logical
    positions ``ctx_lens[b] .. ctx_lens[b] + T - 1``.

    Pure-jnp fallback: materialize each sequence's contiguous view via
    the block table, then masked attention — query ``t`` attends
    logical positions ``<= ctx_lens[b] + t`` (full over the prefix,
    causal within the chunk; ``ctx_lens == 0`` is the first-chunk
    edge).  Semantic oracle for the Pallas kernel in
    ``chunked_prefill_attention.py``.
    """
    N, bs = k_pages.shape[:2]
    B, nb = block_tables.shape
    T = q.shape[1]
    idx = (block_tables[:, :, None] * bs
           + jnp.arange(bs)[None, None, :]).reshape(B, nb * bs)
    k = jnp.take(k_pages.reshape((N * bs,) + k_pages.shape[2:]), idx,
                 axis=0)
    v = jnp.take(v_pages.reshape((N * bs,) + v_pages.shape[2:]), idx,
                 axis=0)
    KV = k.shape[2]
    G = q.shape[2] // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (q.shape[-1] ** 0.5)
    kv_pos = jnp.arange(nb * bs)
    mask = (kv_pos[None, None, :]
            <= ctx_lens[:, None, None] + jnp.arange(T)[None, :, None])
    s = jnp.where(mask[:, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ragged_chunked_prefill_ref(q, k_new, v_new, k_pages, v_pages,
                               block_tables, meta):
    """Oracle for the fused ragged chunked-prefill kernel.

    q: (C, T_pad, H, D) per-chunk padded queries; k_new/v_new:
    (C, T_pad, KV, D) each chunk's fresh K/V; pages: (N, bs, KV, D);
    block_tables: (C, nb) i32; meta: (C, 4) i32 rows
    ``[slot, ctx_len, chunk_len, q_offset]``.

    Scatters each chunk's first ``chunk_len`` K/V rows into the pages
    at logical positions ``ctx_len .. ctx_len + chunk_len - 1``
    (padding rows dropped, never written), then runs the standard
    chunked-prefill mask over the gathered view — so for query rows
    ``t < chunk_len`` the output equals the per-chunk
    ``chunked_prefill_attention_ref`` after a separate scatter pass;
    rows ``t >= chunk_len`` are undefined padding.  Returns
    (out (C, T_pad, H, D), new_k_pages, new_v_pages).
    """
    C, T = q.shape[:2]
    N, bs = k_pages.shape[:2]
    nb = block_tables.shape[1]
    ctx = meta[:, 1]
    lens = meta[:, 2]
    pos = ctx[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]  # (C, T)
    blk = jnp.take_along_axis(block_tables,
                              jnp.minimum(pos // bs, nb - 1), axis=1)
    flat = blk * bs + pos % bs
    valid = jnp.arange(T)[None, :] < lens[:, None]
    flat = jnp.where(valid, flat, N * bs)          # out of bounds -> drop
    feat = k_pages.shape[2:]
    new_k = (k_pages.reshape((N * bs,) + feat)
             .at[flat.reshape(-1)]
             .set(k_new.reshape((C * T,) + feat).astype(k_pages.dtype),
                  mode="drop").reshape(k_pages.shape))
    new_v = (v_pages.reshape((N * bs,) + feat)
             .at[flat.reshape(-1)]
             .set(v_new.reshape((C * T,) + feat).astype(v_pages.dtype),
                  mode="drop").reshape(v_pages.shape))
    out = chunked_prefill_attention_ref(q, new_k, new_v, block_tables, ctx)
    return out, new_k, new_v


def rms_norm_ref(x, weight, eps: float = 1e-6):
    """x: (..., D); weight: (D,) — matches models.layers.rms_norm."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)
