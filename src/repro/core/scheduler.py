"""UASCHED (Algorithm 1) and the four baseline policies.

A *policy* is driven through two interfaces; the discrete-event
simulator (core/simulator.py) and the real serving engine
(serving/engine.py) support both:

  * ``select(queue, now)`` — batch-former: at a dispatch instant return
    (gpu_batch, cpu_batch, remaining_queue) and run the gpu batch to
    completion (the paper's execution model).
  * ``admit(queue, now, running)`` — incremental admission for
    continuous (iteration-level) batching: choose ONE task for a decode
    slot freed this step, given the tasks currently occupying the other
    slots.  Uncertainty-aware policies consolidate against the RUNNING
    batch (admit the candidate whose predicted length is homogeneous
    with it) and keep Alg. 1's tau offload as a congestion relief valve.

  FIFO  — arrival order, fixed batch size, uncertainty-oblivious.
  HPF   — earliest priority point first (deadline-monotonic analogue).
  LUF   — least uncertainty first.
  MUF   — most uncertainty first.
  RT-LM — Alg. 1: UP priority order (Eq. 3), accumulate b*C, re-sort by
          ascending u, lambda-segmentation, u > tau offloaded to CPU.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import datagen, predictor as predictor_lib, priority as prio
from .personas import Persona

Batch = List[prio.SimTask]


@dataclasses.dataclass
class PolicyConfig:
    alpha: float = 1.0       # Eq. 3 uncertainty weight (paper Fig. 13a)
    lam: float = 1.5         # lambda — max u ratio within a batch
    b: float = 1.8           # batch-accumulation multiplier
    k: float = 0.9           # offload quantile
    u_scale: float = 30.0    # Eq. 3 normalization (set from train set)
    tau: float = 1e18        # malicious threshold (set from train set)


class Policy:
    """Base: uncertainty-oblivious FIFO."""

    name = "fifo"
    uses_uncertainty = False

    def __init__(self, persona: Persona, pcfg: Optional[PolicyConfig] = None):
        self.persona = persona
        self.pcfg = pcfg or PolicyConfig()

    # ------------------------------------------------------------------
    def assign_priority(self, t: prio.SimTask) -> float:
        return -t.r  # FIFO: earlier arrival = higher priority

    def select(self, queue: Batch, now: float
               ) -> Tuple[Batch, Batch, Batch]:
        order = sorted(queue, key=self.assign_priority, reverse=True)
        C = self.persona.batch_size
        return order[:C], [], order[C:]

    # ------------------------------------------------------------------
    def max_batch(self) -> int:
        """Largest GPU batch ``select`` can return — the row count the
        engine preallocates its batch-mode executables with.
        Consolidating policies extend past C_f up to b * C_f (Alg. 1)."""
        return self.persona.batch_size

    # ------------------------------------------------------------------
    def admit(self, queue: Batch, now: float,
              running: Sequence[prio.SimTask] = ()
              ) -> Tuple[Optional[prio.SimTask], str, Batch]:
        """Incremental admission (continuous batching): pick ONE task for
        a freed decode slot.  Returns (task | None, lane, rest) where
        lane is "gpu" (admit into the slot) or "cpu" (offload)."""
        if not queue:
            return None, "gpu", []
        order = sorted(queue, key=self.assign_priority, reverse=True)
        return order[0], "gpu", order[1:]


class HPF(Policy):
    """Highest Priority-point First [48] — earliest d_J first."""

    name = "hpf"

    def assign_priority(self, t: prio.SimTask) -> float:
        return -t.d


class LUF(Policy):
    name = "luf"
    uses_uncertainty = True

    def assign_priority(self, t: prio.SimTask) -> float:
        return -t.u


class MUF(Policy):
    name = "muf"
    uses_uncertainty = True

    def assign_priority(self, t: prio.SimTask) -> float:
        return t.u


class SlackEq2(Policy):
    """Pure slack-based priority (paper Eq. 2) — the 'straightforward'
    variant the paper contrasts UP against."""

    name = "slack-eq2"
    uses_uncertainty = True

    def assign_priority(self, t: prio.SimTask) -> float:
        return prio.eq2_priority(t.d, t.r, t.u, self.persona.eta)


class UP(Policy):
    """Uncertainty-aware Prioritization only (ablation arm: no
    consolidation, no offloading) — Eq. 3 order, fixed batch size."""

    name = "up"
    uses_uncertainty = True

    def assign_priority(self, t: prio.SimTask) -> float:
        return prio.eq3_priority(t.d, t.r, t.u, self.persona.eta,
                                 self.pcfg.alpha, self.pcfg.u_scale)


class UPC(UP):
    """UP + dynamic consolidation (ablation arm: no offloading)."""

    name = "up+c"
    offload = False

    def select(self, queue: Batch, now: float
               ) -> Tuple[Batch, Batch, Batch]:
        pcfg, C = self.pcfg, self.persona.batch_size
        for t in queue:
            t.p = self.assign_priority(t)
        order = sorted(queue, key=lambda t: t.p, reverse=True)

        cpu_batch: Batch = []
        tmp: Batch = []
        rest: Batch = []
        target = int(math.floor(pcfg.b * C))
        # §III-C frames offloading as a relief valve "under overloaded
        # situations or ... computation-demanding workloads": engage the
        # CPU lane only when the GPU has a backlog (queue beyond one
        # batch) — otherwise the slow lane only inflates tail latency.
        congested = len(order) > target
        for t in order:
            if self.offload and congested and \
                    self._consolidation_u(t) > pcfg.tau:
                cpu_batch.append(t)           # Alg. 1 line 15-16
            elif len(tmp) < target:
                tmp.append(t)                 # line 18
            else:
                rest.append(t)
        # lines 19-25: re-sort by ascending uncertainty, lambda-segment.
        # NB Alg. 1 line 22 is a disjunction: `while u <= lam*u_prev OR
        # count < C_f` — the batch always reaches C when enough tasks are
        # queued, and dynamic consolidation may *extend* it (up to b*C)
        # while uncertainty stays homogeneous; the lambda cut never
        # starves the executor below C.
        tmp.sort(key=self._consolidation_u)
        count = 0
        u_prev = self._consolidation_u(tmp[0]) if tmp else 0.0
        while count < len(tmp) and (
                count < C
                or self._consolidation_u(tmp[count])
                <= pcfg.lam * max(u_prev, 1e-9)):
            u_prev = self._consolidation_u(tmp[count])
            count += 1
        gpu_batch = tmp[:count]
        rest = tmp[count:] + rest
        # keep the executor busy: if nothing made the GPU cut, ship the
        # CPU batch; if both empty, fall back to the front of the queue.
        if not gpu_batch and not cpu_batch and rest:
            gpu_batch, rest = rest[:C], rest[C:]
        return gpu_batch, cpu_batch, rest

    def max_batch(self) -> int:
        C = self.persona.batch_size
        return max(C, int(math.floor(self.pcfg.b * C)))

    # ------------------------------------------------------------------
    def _consolidation_u(self, t: prio.SimTask) -> float:
        """The uncertainty key consolidation/offload decisions use (the
        tail-aware variant overrides this with the P90 prediction)."""
        return t.u

    def admit(self, queue: Batch, now: float,
              running: Sequence[prio.SimTask] = ()
              ) -> Tuple[Optional[prio.SimTask], str, Batch]:
        """Continuous-batching Alg. 1 analogue.  Priority (Eq. 3) ranks
        the queue; the slot goes to whichever of the top-⌈b⌉ candidates
        is most length-homogeneous with the RUNNING batch (dynamic
        consolidation against live slots instead of a formed batch).
        Under congestion, a predicted-malicious (u > tau) front-runner is
        offloaded to the CPU lane exactly as in batch mode."""
        if not queue:
            return None, "gpu", []
        pcfg, C = self.pcfg, self.persona.batch_size
        for t in queue:
            t.p = self.assign_priority(t)
        order = sorted(queue, key=lambda t: t.p, reverse=True)
        congested = len(order) > int(math.floor(pcfg.b * C))
        if self.offload and congested and \
                self._consolidation_u(order[0]) > pcfg.tau:
            return order[0], "cpu", order[1:]
        window = order[:max(1, int(math.ceil(pcfg.b)))]
        if running:
            anchor = (sum(self._consolidation_u(t) for t in running)
                      / len(running))
            pick = min(window,
                       key=lambda t: abs(self._consolidation_u(t) - anchor))
        else:
            # empty engine: seed the batch with the least-uncertain of
            # the candidates (Alg. 1's ascending-u re-sort analogue)
            pick = min(window, key=self._consolidation_u)
        return pick, "gpu", [t for t in order if t is not pick]


class RTLM(UPC):
    """The full UASCHED: UP + consolidation + strategic CPU offloading."""

    name = "rt-lm"
    offload = True


class RTLMQ(RTLM):
    """Beyond-paper: RT-LM with tail-aware consolidation — batched decode
    runs until its LONGEST member, so batches are consolidated and
    offloaded on the predicted P90 output length (pinball-loss predictor)
    while priorities keep using the mean prediction."""

    name = "rt-lm-q"

    def _consolidation_u(self, t):
        # consolidation/offload on tail u; priorities (assign_priority)
        # keep using the mean prediction t.u
        return t.u_hi


POLICIES = {p.name: p for p in (Policy, HPF, LUF, MUF, SlackEq2,
                               UP, UPC, RTLM, RTLMQ)}


# ---------------------------------------------------------------------------
# offline profiling (Alg. 1 lines 2-9)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class OfflineProfile:
    predictor: predictor_lib.Predictor
    tau: float
    u_scale: float
    persona_name: str
    # beyond-paper: optional tail predictor (pinball-trained, e.g. P90)
    predictor_hi: Optional[predictor_lib.Predictor] = None

    def policy_config(self, alpha=1.0, lam=1.5, b=1.8) -> PolicyConfig:
        return PolicyConfig(alpha=alpha, lam=lam, b=b,
                            u_scale=self.u_scale, tau=self.tau)


def offline_profile(train_tasks: Sequence[datagen.Task], persona: Persona,
                    *, k: float = 0.9, epochs: int = 100,
                    seed: int = 0,
                    tail_quantile: Optional[float] = None
                    ) -> OfflineProfile:
    """Train m_theta on D_train, derive tau = quantile_k of train scores.

    C_f comes from the persona (the paper reads it off GPU-utilization
    profiling, Fig. 8a — those published values are baked into the
    persona table).  tail_quantile additionally trains a pinball-loss
    tail predictor (beyond-paper, see RTLMQ).
    """
    pred = predictor_lib.train_predictor(
        train_tasks, persona.name, epochs=epochs, seed=seed)
    scores = pred.score_batch([t.text for t in train_tasks])
    tau = float(np.quantile(scores, k))
    u_scale = float(np.quantile(scores, 0.95))
    pred_hi = None
    if tail_quantile is not None:
        pred_hi = predictor_lib.train_predictor(
            train_tasks, persona.name, epochs=epochs, seed=seed + 1,
            quantile=tail_quantile)
    return OfflineProfile(predictor=pred, tau=tau, u_scale=u_scale,
                          persona_name=persona.name, predictor_hi=pred_hi)


def make_sim_tasks(tasks: Sequence[datagen.Task], profile: OfflineProfile,
                   persona: Persona, arrivals: Sequence[float],
                   xi: float = 2.0) -> List[prio.SimTask]:
    """Attach predictions + priority points to a trace of tasks."""
    scores = profile.predictor.score_batch([t.text for t in tasks])
    if profile.predictor_hi is not None:
        scores_hi = profile.predictor_hi.score_batch(
            [t.text for t in tasks])
    else:
        scores_hi = scores
    out = []
    for t, u, uh, r in zip(tasks, scores, scores_hi, arrivals):
        ilen = float(len(t.text.split()))
        d = prio.priority_point(r, ilen, persona.phi, t.deadline, xi=xi)
        out.append(prio.SimTask(
            task=t, u=float(max(u, 0.0)), u_hi=float(max(uh, u, 0.0)),
            r=float(r), d=d, input_len=ilen,
            true_out_len=t.out_lens[persona.name]))
    return out
