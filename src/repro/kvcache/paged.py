"""Device-side paged K/V store: gather/scatter primitives + container.

Pages are arrays of shape ``(num_blocks, block_size, *feat)`` (feat =
``(kv_heads, head_dim)`` for attention caches).  A sequence's tokens
live at logical position ``p`` inside physical block ``table[p // bs]``
at offset ``p % bs`` — exactly the vLLM block-table layout, so the
gathered view of a sequence is bit-identical to what a contiguous
(absolute-position) cache would hold.  That bit-exactness is what the
token-for-token paged-vs-contiguous engine parity test leans on: masked
positions contribute exp(-inf) == 0.0 exactly, so layout padding never
perturbs the softmax.

The primitives are pure jnp (jit/vmap-safe, traced table operands) and
are the semantic reference for the Pallas kernel in
``repro.kernels.paged_decode_attention``; the model's paged decode path
(models/transformer.py) composes them with the existing
``layers.decode_attention``.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .allocator import blocks_for_tokens

Array = jax.Array


# ---------------------------------------------------------------------------
# gather / scatter primitives
# ---------------------------------------------------------------------------


def _flat(pages: Array) -> Array:
    """(N, bs, *feat) -> (N*bs, *feat) token-major view."""
    N, bs = pages.shape[:2]
    return pages.reshape((N * bs,) + pages.shape[2:])


def gather_tokens(pages: Array, tables: Array) -> Array:
    """Gather each sequence's tokens in logical order.

    pages: (N, bs, *feat); tables: (B, nb) i32 physical block ids.
    Returns (B, nb*bs, *feat) — row b's logical positions 0..nb*bs-1.
    Entries past a sequence's written length are whatever the page
    holds (zeros or stale data); callers mask by valid length.
    """
    bs = pages.shape[1]
    B, nb = tables.shape
    idx = (tables[:, :, None] * bs
           + jnp.arange(bs, dtype=tables.dtype)[None, None, :])
    return jnp.take(_flat(pages), idx.reshape(B, nb * bs), axis=0)


def scatter_token(pages: Array, values: Array, tables: Array,
                  pos: Array) -> Array:
    """Write one token per sequence at its current logical position.

    pages: (N, bs, *feat); values: (B, *feat); tables: (B, nb) i32;
    pos: (B,) i32 logical positions.  Distinct sequences own distinct
    blocks (allocator invariant), so rows never collide.  The table
    lookup clamps ``pos // bs`` to the table width: evicted (dead) decode
    rows keep stepping with a stale, ever-growing ``pos``, and their
    table rows point at the reserved trash page — the clamp makes every
    dead-row write land there instead of indexing out of bounds.
    """
    bs = pages.shape[1]
    blk_idx = jnp.minimum(pos[:, None] // bs, tables.shape[1] - 1)
    blk = jnp.take_along_axis(tables, blk_idx, axis=1)[:, 0]
    flat_idx = blk * bs + pos % bs
    out = _flat(pages).at[flat_idx].set(values.astype(pages.dtype))
    return out.reshape(pages.shape)


def scatter_chunk(pages: Array, seq: Array, table_row: Array,
                  start: Array) -> Array:
    """Write one sequence's prefill CHUNK at a traced position offset.

    pages: (N, bs, *feat); seq: (T, *feat) the chunk's K/V; table_row:
    (nb,) i32; start: scalar i32 — the chunk covers logical positions
    ``start .. start + T - 1``.  Unlike ``scatter_prefill`` (static
    offset 0, unrolled dynamic-update-slices) the offset is traced, so
    one jitted executable serves every chunk of a prompt; like
    ``scatter_token`` the block lookup clamps to the table width so a
    trash-table row degrades to trash-page writes instead of indexing
    out of bounds.
    """
    bs = pages.shape[1]
    T = seq.shape[0]
    pos = start + jnp.arange(T, dtype=jnp.int32)
    blk_idx = jnp.minimum(pos // bs, table_row.shape[0] - 1)
    blk = jnp.take(table_row, blk_idx)
    flat_idx = blk * bs + pos % bs
    out = _flat(pages).at[flat_idx].set(seq.astype(pages.dtype))
    return out.reshape(pages.shape)


def scatter_packed(pages: Array, seq: Array, tables: Array,
                   token_chunk: Array, positions: Array,
                   valid: Array) -> Array:
    """Write a PACKED multi-chunk K/V stream in one pass.

    pages: (N, bs, *feat); seq: (TT, *feat) — the fused ragged-prefill
    executable's packed token stream (every scheduled chunk of one
    engine iteration back to back, plus padding); tables: (C, nb) i32
    per-chunk block tables; token_chunk: (TT,) i32 mapping each packed
    row to its chunk; positions: (TT,) i32 absolute logical positions;
    valid: (TT,) bool — False rows (padding) are DROPPED, never
    written (out-of-bounds drop-mode scatter), so the pool is
    bit-identical to what per-chunk ``scatter_chunk`` calls would
    produce.  Distinct chunks map distinct sequences (pack_plans merges
    same-job plans), so rows never collide; the block lookup clamps to
    the table width like the other scatter primitives.
    """
    bs = pages.shape[1]
    N = pages.shape[0]
    nb = tables.shape[1]
    blk_idx = jnp.minimum(positions // bs, nb - 1)
    blk = tables[token_chunk, blk_idx]
    flat_idx = jnp.where(valid, blk * bs + positions % bs, N * bs)
    out = _flat(pages).at[flat_idx].set(seq.astype(pages.dtype),
                                        mode="drop")
    return out.reshape(pages.shape)


def copy_block(pages: Array, src: Array, dst: Array) -> Array:
    """Copy one physical page: ``pages[dst] = pages[src]``.

    ``src``/``dst`` are traced scalars, so one jitted executable serves
    every copy-on-write — the prefix cache's full-match admission path
    duplicates the last shared block before the (re)computed final
    prompt position is written into it (``kvcache.prefix``).
    """
    row = lax.dynamic_slice_in_dim(pages, src, 1, axis=0)
    return lax.dynamic_update_slice_in_dim(pages, row, dst, axis=0)


def scatter_prefill(pages: Array, seq: Array, table_row: Array,
                    seq_len: int) -> Array:
    """Write a freshly prefilled sequence into its table's blocks.

    pages: (N, bs, *feat); seq: (S, *feat) with S >= seq_len (the
    prefill cache's leading ``max_len`` rows — only the first
    ``seq_len`` are written); table_row: (nb,) i32.  ``seq_len`` is
    static (the engine's input bucket), so this unrolls into
    ``ceil(seq_len / bs)`` dynamic-update-slices with traced block ids.
    """
    bs = pages.shape[1]
    zeros = (0,) * (pages.ndim - 2)
    for j in range(blocks_for_tokens(seq_len, bs)):
        chunk_len = min(bs, seq_len - j * bs)
        chunk = seq[j * bs:j * bs + chunk_len].astype(pages.dtype)[None]
        pages = lax.dynamic_update_slice(
            pages, chunk, (table_row[j], 0) + zeros)
    return pages


# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------


class PagedKVCache:
    """Paged KV store for the continuous engine.

    Owns the device-side state pytree (per-layer K/V page arrays plus
    per-slot ``pos``, built by ``transformer.init_paged_cache``) and the
    host-side ``(num_slots, max_blocks_per_seq)`` block-table array the
    jitted prefill/decode executables consume.  Memory formula:

        bytes = layers * 2 * num_blocks * block_size
                       * kv_heads * head_dim * dtype_bytes

    versus ``layers * 2 * num_slots * max_len * ...`` for the contiguous
    slot cache — paged capacity scales with *live tokens* (allocated
    blocks), not with worst-case sequence length per slot.
    """

    def __init__(self, cfg, num_slots: int, num_blocks: int,
                 block_size: int, max_len: int, dtype=jnp.bfloat16):
        from repro.models import transformer  # lazy: avoid import cycle
        self.num_slots = num_slots
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.max_len = max_len
        self.max_blocks_per_seq = blocks_for_tokens(max_len, block_size)
        # one extra physical page the allocator never hands out: the
        # decode step writes a KV entry for EVERY row, and evicted
        # (dead) rows must not scribble over blocks that may already
        # belong to a newly admitted sequence — their tables point here.
        self.trash_block = num_blocks
        self.state = transformer.init_paged_cache(
            cfg, num_slots, num_blocks + 1, block_size, dtype)
        # host-side table copy; rows are rewritten at admission and
        # extended at block-boundary crossings, then shipped to the
        # jitted executables as a (num_slots, nb_max) i32 operand.
        self.tables = np.full((num_slots, self.max_blocks_per_seq),
                              self.trash_block, np.int32)

    # -- table management (host) ---------------------------------------
    def set_table(self, slot: int, blocks) -> None:
        """Install a freshly admitted sequence's table into ``slot``."""
        row = np.full((self.max_blocks_per_seq,), self.trash_block,
                      np.int32)
        row[:len(blocks)] = blocks
        self.tables[slot] = row

    def extend_table(self, slot: int, block_index: int, block: int) -> None:
        """Record a boundary-crossing allocation for ``slot``."""
        self.tables[slot, block_index] = block

    def clear_table(self, slot: int) -> None:
        """Point an evicted slot back at the trash page."""
        self.tables[slot] = self.trash_block

    def tables_device(self) -> Array:
        return jnp.asarray(self.tables)

    def table_row(self, slot: int) -> Array:
        return jnp.asarray(self.tables[slot])


def default_num_blocks(num_slots: int, max_len: int,
                       block_size: int) -> int:
    """Block count matching a contiguous ``(num_slots, max_len)`` slot
    cache's KV-token budget — the equal-budget comparison the
    paged-vs-contiguous benchmark and capacity tests are built on."""
    return max(1, num_slots * max_len // block_size)
