"""Real serving engine: RT-LM scheduling over the actual JAX model.

This is the end-to-end integration of the paper's ecosystem with the
model substrate: requests (text + arrival time) flow through RULEGEN ->
m_theta -> the UASCHED policy, and execution happens on the REAL batched
prefill/greedy-decode JAX engine (tiny configs on CPU; the same code
path jit-lowers for the production mesh).

Two execution modes:

  * ``mode="batch"`` — the paper's run-to-completion model: the policy
    forms whole batches, each batch decodes until its LONGEST member
    finishes (head-of-line blocking on output-length variance — exactly
    the pathology RT-LM quantifies).
  * ``mode="continuous"`` — iteration-level batching: a persistent
    decode loop over C slots backed by one preallocated per-slot KV
    cache (transformer.init_slot_cache).  Finished sequences are evicted
    PER DECODE STEP and the policy's ``admit`` is consulted to fill each
    freed slot (uncertainty-aware admission instead of batch formation).
    Admission prefills the request into its slot through one jitted
    executable (bucketed (1, input_bucket) shape, traced slot index);
    the decode step reuses one jitted (C, 1) executable throughout.

Continuous mode takes a KV-cache layout, ``kv="contiguous"`` (default)
or ``kv="paged"``:

  * contiguous — each slot owns a private (max_len,) KV ring; memory is
    pinned to ``num_slots * max_len`` regardless of live tokens.
  * paged — one pool of ``kv_num_blocks`` fixed-size blocks shared by
    all slots (repro.kvcache): a sequence holds a block table, admission
    reserves its worst case ``ceil((S + cap - 1)/block_size)`` blocks
    (deadlock-free: a boundary crossing can never find the pool empty),
    physical blocks are allocated lazily when decode crosses a block
    boundary, and eviction returns every block to the free list.  A
    request whose reservation does not fit is REJECTED for memory
    (left queued; counted in the results) — the admission gate the
    simulator's block-budget model mirrors exactly.  Decode runs the
    same (C, 1) executable against gathered block-table views, so paged
    output is token-for-token identical to contiguous; with
    ``num_slots`` raised above the persona batch size at the same KV
    budget, paging admits strictly more concurrent sequences.

With ``prefix_cache=True`` (requires ``kv="paged"``), admission first
looks up the longest CACHED prefix of the padded prompt bucket in a
content-hash index over previously written blocks
(``repro.kvcache.prefix``): matched blocks are shared read-only into
the new sequence's table (per-block refcounts), prefill runs only from
the first uncached position (through the traced-offset chunk
executable), a full-prompt match copy-on-writes its last block so the
final position's logits can be recomputed, and cached blocks nobody
references are LRU-evicted only under pool pressure.  Output stays
token-for-token identical with the cache on or off; the simulator
drives the same ``PrefixCache`` host-side, so hit/CoW/eviction counts
and completion order agree bit-for-bit (tests/test_prefix_cache.py).

Adaptation note (DESIGN.md §2): a CPU-only container has no heterogeneous
co-processor, so the "CPU lane" is a *bulk lane* — a second execution
queue drained only when the main lane is idle, emulating resource
isolation of high-uncertainty tasks.  On a TPU pod the same lane maps to
a dedicated low-priority replica slice.

Batches are padded to (policy.max_batch(), input_bucket) — b * C for the
consolidating UASCHED policies, C otherwise — so a dynamically
consolidated batch executes as ONE batch (as the simulator models it)
and the jitted prefill/decode executables are reused across batches.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import priority as prio
from repro.core import scheduler as sched_lib
from repro.core.simulator import _pct as pct  # noqa: F401 - re-exported
from repro.core.personas import Persona
from repro.kvcache import (BlockAllocator, blocks_for_tokens,
                           window_target_tokens)
from repro.kvcache.paged import PagedKVCache
from repro.kvcache.prefix import PrefixCache
from repro.models import transformer
from repro.obs import Observability
from repro.obs import log as obslog
from repro.obs.metrics import Histogram
from repro.prefill import (ChunkScheduler, build_packed_arrays, pack_plans,
                           suffix_shape_key)

from . import generate
from .faults import shed_pass
from .pipeline import CompletionWorker

logger = logging.getLogger(__name__)

EOS_ID = 1
# max_len headroom past input_bucket + max_new_tokens.  It doubles as
# the multi-step decode window's OVERHANG budget: with readback in
# arrears a slot may be stepped up to decode_steps - 1 times past its
# logical end, and those dead-row writes must stay inside the slot's
# own ring (contiguous) / its table's clamp range (paged) — hence the
# constructor's ``decode_steps - 1 <= _MAX_LEN_SLACK`` validation.
_MAX_LEN_SLACK = 8


def hash_tokenize(text: str, vocab_size: int, max_len: int) -> List[int]:
    """Toy deterministic tokenizer: word -> stable hash id (2..V-1)."""
    toks = []
    for w in text.lower().split()[:max_len]:
        h = 2166136261
        for c in w.encode():
            h = ((h ^ c) * 16777619) & 0xFFFFFFFF
        toks.append(2 + (h % (vocab_size - 2)))
    return toks or [2]


def tokenize_padded(text: str, vocab_size: int, bucket: int) -> np.ndarray:
    """The engine's admission bucket: ``hash_tokenize`` then LEFT-pad
    to ``bucket``.  Module-level because the simulator's prefix-cache
    model and the benchmarks must hash the exact same token buckets
    the engine prefills (``simulate_continuous(prompt_tokens=...)``)."""
    arr = np.zeros((bucket,), np.int32)
    seq = hash_tokenize(text, vocab_size, bucket)
    arr[bucket - len(seq):] = seq                   # left-pad
    return arr


@dataclasses.dataclass
class Request:
    text: str
    arrival: float
    task_id: int
    # optional per-request decode budget (None -> engine default); with
    # EOS disabled this IS the output length — how the benchmarks build
    # deterministic heterogeneous-output-length workloads.
    max_new_tokens: Optional[int] = None
    # traffic class (repro.core.workload.TrafficClass name) the SLO
    # monitor attributes this request to; "" = unclassed (resolves to
    # the monitor's default class, and the enqueue event stays
    # bit-identical to pre-class traces)
    traffic_class: str = ""
    # filled at completion:
    start: float = -1.0
    finish: float = -1.0
    # admission instant minus arrival (engine clock): how long the
    # request sat queued before the scheduler committed resources to it
    # — bulk/batch requests are stamped at batch start
    queue_wait_s: float = -1.0
    lane: str = ""
    out_len: int = 0
    slot: int = -1               # decode slot served in (continuous mode)
    # generated token ids (greedy); the paged-vs-contiguous parity test
    # asserts these match token for token
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    # per-token emission times (engine clock): token_times[0] is the
    # first-token instant (TTFT = token_times[0] - arrival), successive
    # diffs are the inter-token latencies the percentile metrics
    # summarize.  Continuous modes record exact step times; batch mode
    # models streaming linearly across the batch's decode horizon.
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def response_time(self) -> float:
        return self.finish - self.arrival


class ServingEngine:
    """Single-node engine with a pluggable scheduling policy.

    mode="batch": policy.select forms run-to-completion batches.
    mode="continuous": policy.admit fills decode slots per step.
    """

    def __init__(self, params, cfg, policy: sched_lib.Policy,
                 profile: sched_lib.OfflineProfile, *,
                 input_bucket: int = 32, max_new_tokens: int = 32,
                 xi: float = 2.0, mode: str = "batch",
                 eos_id: int = EOS_ID, kv: str = "contiguous",
                 num_slots: Optional[int] = None,
                 kv_block_size: int = 16,
                 kv_num_blocks: Optional[int] = None,
                 prefill: str = "stall",
                 chunk_size: int = 16,
                 token_budget: Optional[int] = None,
                 use_pallas: Optional[bool] = None,
                 prefix_cache: bool = False,
                 decode_steps: int = 1,
                 aot_warmup: bool = True,
                 persist_prefix_cache: bool = False,
                 faults=None,
                 obs: Optional[Observability] = None):
        # per-engine fallback ledger FIRST: the kernel factories below
        # may fire the jnp-fallback warning while they build.  Scoping
        # the ledger to this instance (obslog.scope around the factory
        # build and serve()) keeps fallback_events replica-accurate
        # when R engines share the process — a process-global delta
        # would attribute every replica's events to one engine and
        # rate-suppress later replicas' first warnings.
        self.fallback_ledger = obslog.RateLimitedLogger()
        if mode not in ("batch", "continuous"):
            raise ValueError(f"unknown mode {mode!r}")
        if kv not in ("contiguous", "paged"):
            raise ValueError(f"unknown kv layout {kv!r}")
        if kv == "paged" and mode != "continuous":
            raise ValueError('kv="paged" requires mode="continuous"')
        if prefill not in ("stall", "chunked"):
            raise ValueError(f"unknown prefill mode {prefill!r}")
        if prefill == "chunked" and kv != "paged":
            raise ValueError('prefill="chunked" requires mode="continuous"'
                             ', kv="paged"')
        if prefix_cache and kv != "paged":
            raise ValueError('prefix_cache=True requires mode="continuous"'
                             ', kv="paged"')
        if decode_steps < 1:
            raise ValueError(f"decode_steps must be >= 1, got "
                             f"{decode_steps}")
        if decode_steps > 1 and mode != "continuous":
            raise ValueError('decode_steps > 1 requires mode="continuous" '
                             "(batch mode has no persistent decode loop)")
        if decode_steps - 1 > _MAX_LEN_SLACK:
            raise ValueError(
                f"decode_steps={decode_steps}: the eviction lag "
                f"(decode_steps - 1 overhang writes past a sequence's "
                f"end) exceeds the max_len slack ({_MAX_LEN_SLACK}) that "
                "keeps dead-row writes inside the slot's own KV range")
        if persist_prefix_cache and not prefix_cache:
            raise ValueError("persist_prefix_cache=True requires "
                             "prefix_cache=True")
        if faults is not None and (mode != "continuous"
                                   or prefill != "stall"):
            raise ValueError('faults (serving.faults.ReplicaFaults) '
                             'require mode="continuous", '
                             'prefill="stall"')
        self.params = params
        self.cfg = cfg
        self.policy = policy
        self.profile = profile
        self.persona = policy.persona
        self.input_bucket = input_bucket
        self.max_new_tokens = max_new_tokens
        self.xi = xi
        self.mode = mode
        self.eos_id = eos_id
        self.kv = kv
        self.max_len = input_bucket + max_new_tokens + _MAX_LEN_SLACK
        # async host pipeline knobs: N decode steps per launch (N=1 is
        # the bit-parity synchronous default) and AOT executable warmup
        # at serve() start
        self.decode_steps = decode_steps
        self.aot_warmup = aot_warmup
        self.persist_prefix_cache = persist_prefix_cache
        # observability bundle (repro.obs): OFF by default — every
        # emission site below is guarded, and with obs=None the serve
        # path is bit-identical to the unobserved engine
        self.obs = obs
        # continuous-mode decode width; paged engines raise it above the
        # persona batch size so the BLOCK BUDGET (not worst-case slot
        # length) bounds concurrency
        self.num_slots = (num_slots if num_slots is not None
                          else self.persona.batch_size)
        self.kv_block_size = kv_block_size
        # chunked-prefill knobs (repro.prefill): the per-iteration token
        # budget covers one decode token per active slot FIRST, then as
        # many prefill-chunk tokens as fit; the default budget leaves
        # one chunk of headroom above a fully busy decode loop.
        self.prefill = prefill
        self.chunk_size = chunk_size
        self.token_budget = (token_budget if token_budget is not None
                             else self.num_slots + chunk_size)
        if prefill == "chunked":
            # constructor-time validation (ChunkScheduler re-checks)
            ChunkScheduler(chunk_size, self.token_budget)
        self.use_pallas = use_pallas
        # default budget: the worst-case reservation fits in every slot
        # (no rejections) — benchmarks pass an explicit tighter budget
        self.kv_num_blocks = (
            kv_num_blocks if kv_num_blocks is not None
            else self.num_slots * blocks_for_tokens(self.max_len,
                                                    kv_block_size))
        if kv == "paged":
            ok, why = transformer.paged_supported(cfg)
            if not ok:
                raise NotImplementedError(f"paged KV cache: {why}")
            worst = blocks_for_tokens(input_bucket + max_new_tokens - 1,
                                      kv_block_size)
            if worst > self.kv_num_blocks:
                raise ValueError(
                    f"kv_num_blocks={self.kv_num_blocks} cannot hold one "
                    f"worst-case sequence ({worst} blocks) — admission "
                    "would deadlock")
        # batch-mode executables are preallocated at the policy's max
        # consolidated batch (b * C for UASCHED, C otherwise) so a
        # consolidated batch runs as ONE batch, matching the simulator;
        # padded rows are capped at a single token (see _run_batch).
        self.batch_capacity = policy.max_batch()
        self.prefix_cache_enabled = prefix_cache
        with obslog.scope(self.fallback_ledger):
            self._prefill = generate.make_prefill_fn(cfg, self.max_len)
            self._decode = generate.make_decode_fn(cfg)
            self._slot_prefill = generate.make_slot_prefill_fn(
                cfg, self.max_len)
            self._decode_steps_fn = generate.make_decode_steps_fn(cfg)
            if kv == "paged":
                self._paged_prefill = generate.make_paged_prefill_fn(
                    cfg, self.max_len)
                self._paged_decode = generate.make_paged_decode_fn(
                    cfg, use_pallas)
                self._paged_decode_steps = \
                    generate.make_paged_decode_steps_fn(cfg, use_pallas)
                if prefill == "chunked" or prefix_cache:
                    # the FUSED executable: every scheduled chunk of an
                    # iteration in one launch (padded-shape-keyed memo).
                    # Prefix-cached STALL admission routes its uncached
                    # suffix through the same executable as a
                    # single-chunk launch, so a prefix hit pays one
                    # fused dispatch.
                    self._ragged_prefill = \
                        generate.make_ragged_prefill_fn(cfg, use_pallas)
                if prefix_cache:
                    self._copy_block = generate.make_copy_block_fn(cfg)
        # AOT warm keys: the factory memo shares JitExecutables across
        # same-cfg engines, so every key carries the dims that fix this
        # engine's array shapes — two engines with identical dims share
        # warmed executables; differing dims never collide.
        self._aot_dims = (self.num_slots, self.input_bucket, self.max_len,
                          self.kv, self.kv_num_blocks, self.kv_block_size)
        self._window_key = ("window", self._aot_dims, self.decode_steps)
        self._admit_key = ("admit", self._aot_dims)
        self._cow_key = ("cow", self._aot_dims)
        self.scheduler_overhead_s = 0.0
        # exposed for the slot-recycling tests: per-slot cache after the
        # last continuous serve, and the admission audit trail
        self.slot_cache = None
        self.admission_log: List[Dict] = []
        # paged-KV state (populated by a paged continuous serve)
        self.paged_cache: Optional[PagedKVCache] = None
        self.allocator: Optional[BlockAllocator] = None
        # live PrefixCache of the last serve (when prefix_cache=True);
        # rebuilt per serve — cached block ids index that serve's pool
        self.prefix_cache: Optional[PrefixCache] = None
        # memory-efficiency accounting (reset per serve)
        self.kv_util_samples: List[float] = []
        self._rejected_ids: set = set()
        self.peak_concurrency = 0
        # tail-latency accounting (reset per serve): wall-clock spent on
        # prefill work while decode slots were live (the decode-stall
        # time chunked prefill bounds), and the chunked engine's
        # per-iteration (decode_tokens, prefill_tokens) budget trace —
        # the simulator's chunked mode reproduces it entry for entry.
        self.prefill_stall_s = 0.0
        self.prefill_stall_max_s = 0.0   # worst single-iteration stall
        self.budget_trace: List = []
        # dispatch accounting (reset per serve): prefill launches in
        # total and per iteration — the chunked engine issues exactly
        # ONE fused launch per iteration with scheduled chunks, versus
        # one per admission (stall) / one per chunk (the pre-fused
        # path); exec_cache_* count the fused executable's padded-shape
        # keys (miss = first launch at a new ChunkBatch.shape_key this
        # serve).  The simulator mirrors all four from the same plans.
        self.prefill_dispatches = 0
        self.prefill_dispatch_trace: List[int] = []
        self.exec_cache_hits = 0
        self.exec_cache_misses = 0
        self._exec_keys: set = set()
        # decode-dispatch accounting (reset per serve): launches and
        # steps of the multi-step decode window — steps/dispatches ==
        # decode_steps exactly (every window launches the full N; dead
        # rows ride along and are discarded at readback).  The trace
        # records steps per window (chunked mode aligns entries with
        # budget_trace, 0 = no decode that iteration).  The simulator
        # mirrors all three.
        self.decode_dispatches = 0
        self.decode_steps_total = 0
        self.decode_dispatch_trace: List[int] = []
        # completion worker (serving.pipeline) of the serve in flight
        self._worker: Optional[CompletionWorker] = None
        # failure-aware serving (serving.faults.ReplicaFaults): the
        # pre-admission shed pass, straggler slowdowns and the crash
        # point of the continuous stall loop.  The crash latch and the
        # final step coordinate persist across serve calls — failover
        # rounds (replica.ReplicatedEngine) continue a replica's step
        # stream via serve(step_offset=...), and a crash fires once.
        self.faults = faults
        self._crashed = False
        self.last_step = 0
        self.timed_out_tasks: List[prio.SimTask] = []
        self.shed_tasks: List[prio.SimTask] = []
        self.survivors: List[Request] = []

    # ------------------------------------------------------------------
    def _to_sim_task(self, req: Request) -> prio.SimTask:
        t0 = time.perf_counter()
        u = self.profile.predictor.score(req.text)
        d = prio.priority_point(req.arrival, len(req.text.split()),
                                self.persona.phi, None, xi=self.xi)
        self.scheduler_overhead_s += time.perf_counter() - t0
        st = prio.SimTask(task=req, u=float(max(u, 0.0)), r=req.arrival,
                          d=d, input_len=float(len(req.text.split())),
                          true_out_len=0)
        return st

    def _tokenize_padded(self, text: str) -> np.ndarray:
        return tokenize_padded(text, self.cfg.vocab_size,
                               self.input_bucket)

    def _cap(self, req: Request) -> int:
        cap = (req.max_new_tokens if req.max_new_tokens is not None
               else self.max_new_tokens)
        return max(1, min(cap, self.max_new_tokens))

    def _run_batch(self, batch: Sequence[prio.SimTask], lane: str,
                   now: float) -> float:
        """Execute a run-to-completion batch; returns finish time."""
        Cb = self.batch_capacity
        S = self.input_bucket
        arr = np.zeros((Cb, S), np.int32)
        for i, t in enumerate(batch):
            arr[i] = self._tokenize_padded(t.task.text)
        tokens = jnp.asarray(arr)
        # padded rows stop after one token so they never extend the
        # batch's decode horizon (the run-to-completion cost is set by
        # the longest REAL member, as in the simulator's latency model)
        caps = np.ones((Cb,), np.int32)
        caps[:len(batch)] = [self._cap(t.task) for t in batch]
        t0 = time.perf_counter()
        out_tokens, lengths = generate.generate(
            self.params, self.cfg, {"tokens": tokens},
            max_new_tokens=self.max_new_tokens, eos_id=self.eos_id,
            prefill_fn=self._prefill, decode_fn=self._decode,
            max_lens=caps)
        jax.block_until_ready(out_tokens)
        dur = time.perf_counter() - t0
        # one prefill launch per executed batch; the per-iteration trace
        # only covers batch mode — in continuous modes the trace is the
        # DECODE-LOOP launch profile (chunked: aligned with
        # budget_trace), so bulk-lane batches count in the total only
        self.prefill_dispatches += 1
        if self.mode == "batch":
            self.prefill_dispatch_trace.append(1)
        if lane == "cpu":
            dur *= self.persona.cpu_slowdown   # bulk-lane emulation
        finish = now + dur
        if self.mode == "batch":
            # batch-mode memory metric: rows used of the preallocated
            # executable; the continuous bulk lane must NOT sample here,
            # its KV metrics track the decode slots / block pool only
            self.kv_util_samples.append(len(batch) / Cb)
            self.peak_concurrency = max(self.peak_concurrency, len(batch))
        toks = np.asarray(out_tokens)
        # run-to-completion streaming model for the tail-latency
        # metrics: the batch decodes max(realized lengths) steps in
        # ``dur``, so member token j is emitted at a linear fraction of
        # the horizon (uniform ITL = dur / horizon).
        horizon = max(max((int(lengths[i]) for i in range(len(batch))),
                          default=1), 1)
        ob = self.obs
        for i, t in enumerate(batch):
            t.start, t.finish, t.lane = now, finish, lane
            t.task.start, t.task.finish, t.task.lane = now, finish, lane
            t.task.queue_wait_s = now - t.r
            t.task.out_len = int(lengths[i]) if i < len(lengths) else 0
            t.task.out_tokens = toks[i, :t.task.out_len].tolist()
            t.task.token_times = [now + dur * (j + 1) / horizon
                                  for j in range(t.task.out_len)]
        if ob is not None:
            ob.inc("prefill.dispatches")
            ob.span("bulk_batch", now, finish - now, lane=lane,
                    size=len(batch))
            for t in batch:
                tid = t.task.task_id
                cls = t.task.traffic_class
                ob.slo_observe("queue_wait", cls, now,
                               t.task.queue_wait_s)
                if t.task.token_times:
                    ob.event("first_token", t.task.token_times[0], tid,
                             lane=lane)
                    ob.slo_observe("ttft", cls, t.task.token_times[0],
                                   t.task.token_times[0] - t.r)
                    if t.task.out_len > 1:
                        # run-to-completion streaming model: uniform
                        # ITL across the batch's decode horizon
                        ob.slo_observe("itl", cls, finish,
                                       dur / horizon,
                                       n=t.task.out_len - 1)
                ob.event("complete", finish, tid, lane=lane,
                         out_len=t.task.out_len)
                ob.inc("sched.completions")
                ob.complete_request(cls, finish, u=t.u,
                                    out_len=t.task.out_len,
                                    latency_s=finish - t.r)
        return finish

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence[Request], *,
              step_offset: int = 0) -> Dict:
        """Run a full trace (virtual-time arrivals, real execution).

        ``step_offset`` starts the step coordinate above zero — the
        failover rounds of ``replica.ReplicatedEngine`` use it so a
        replica's event stream keeps counting steps where its previous
        serve stopped (the simulator's per-replica step counter never
        resets, so parity needs the continuation)."""
        if step_offset and (self.mode != "continuous"
                            or self.prefill != "stall"):
            raise ValueError("step_offset requires the continuous "
                             "stall serve loop")
        self.timed_out_tasks = []
        self.shed_tasks = []
        self.survivors = []
        self.kv_util_samples = []
        self._rejected_ids = set()
        self.peak_concurrency = 0
        self.prefill_stall_s = 0.0
        self.prefill_stall_max_s = 0.0
        self.budget_trace = []
        self.prefill_dispatches = 0
        self.prefill_dispatch_trace = []
        self.exec_cache_hits = 0
        self.exec_cache_misses = 0
        self._exec_keys = set()
        self.decode_dispatches = 0
        self.decode_steps_total = 0
        self.decode_dispatch_trace = []
        # the jnp-fallback warning is one-time PER SERVE (a process
        # running many engines must not mask later serves' fallbacks);
        # re-arm this engine's scoped ledger the same way
        generate.reset_fallback_warning()
        self.fallback_ledger.reset(generate.FALLBACK_KEY)
        if not self.persist_prefix_cache:
            # default: the device page pool is rebuilt per serve, so
            # cached block ids must not outlive it.  With persistence
            # the pool, allocator and index survive (the continuous
            # setup reuses them and resets the per-serve counters).
            self.prefix_cache = None
        # serve-time fallbacks (AOT warmup failure, late kernel
        # fallbacks) land in this engine's own ledger
        with obslog.scope(self.fallback_ledger):
            # the worker is constructed BEFORE the try: if it raises,
            # there is no half-built worker for the finally to trip
            # over, and any engine exception mid-window always reaches
            # a close() that joins the daemon thread (close() is
            # idempotent, so double-teardown is safe too)
            self._worker = CompletionWorker(
                metrics=self.obs.metrics
                if self.obs is not None else None)
            try:
                if self.mode == "continuous":
                    if self.prefill == "chunked":
                        return self._serve_continuous_chunked(requests)
                    return self._serve_continuous(
                        requests, step_offset=step_offset)
                return self._serve_batch(requests)
            finally:
                self._worker.close()
                self._worker = None

    def _result(self, done: List[prio.SimTask], n: int) -> Dict:
        ps = (self.prefix_cache.stats()
              if self.prefix_cache is not None else {})
        # a crashed or fully-shed serve can complete nothing — guard
        # the aggregates (zeros, not nan) instead of assuming done
        rts = (np.array([t.response_time for t in done]) if done
               else np.zeros(1))
        span = (max(t.finish for t in done) - min(t.r for t in done)
                if done else 0.0)
        util = (np.array(self.kv_util_samples)
                if self.kv_util_samples else np.zeros(1))
        # tail-latency metrics: TTFT per request (first token emission
        # minus arrival), the pooled inter-token latencies of every
        # request, and the per-request queue wait — all folded into the
        # shared log-bucketed streaming histograms (repro.obs.metrics),
        # the same quantile substrate SimResult uses, so engine and sim
        # tail metrics stay comparable and state stays O(buckets)
        # regardless of trace length.
        ttft_h, itl_h, qw_h = Histogram(), Histogram(), Histogram()
        for t in done:
            times = getattr(t.task, "token_times", None) or []
            if times:
                ttft_h.record(times[0] - t.r)
                for d in np.diff(times):
                    itl_h.record(float(d))
            qw = getattr(t.task, "queue_wait_s", -1.0)
            if qw >= 0.0:
                qw_h.record(qw)
        out = {
            "mean_response_s": float(rts.mean()),
            "max_response_s": float(rts.max()),
            "throughput_per_min": 60.0 * n / max(span, 1e-9),
            "scheduler_overhead_s": self.scheduler_overhead_s,
            "n_tasks": n,
            "tasks": done,
            "completion_order": [t.task.task_id for t in done],
            "mode": self.mode,
            # memory-efficiency metrics: KV utilization is the fraction
            # of the reserved KV memory in use, sampled per decode step
            # (paged: allocated/total blocks; contiguous continuous:
            # occupied/total slots — a slot pins max_len KV whether its
            # sequence is short or long; batch: rows used / capacity).
            # rejected_for_memory counts DISTINCT requests deferred at
            # least once by the block-budget gate (a blocked request is
            # retried every step; counting events would scale with
            # decode-step count, not workload)
            "kv_util_peak": float(util.max()),
            "kv_util_mean": float(util.mean()),
            "rejected_for_memory": len(self._rejected_ids),
            "peak_concurrency": self.peak_concurrency,
            "ttft_p50": ttft_h.quantile(0.50),
            "ttft_p90": ttft_h.quantile(0.90),
            "ttft_p99": ttft_h.quantile(0.99),
            "itl_p50": itl_h.quantile(0.50),
            "itl_p90": itl_h.quantile(0.90),
            "itl_p99": itl_h.quantile(0.99),
            "queue_wait_p50": qw_h.quantile(0.50),
            "queue_wait_p90": qw_h.quantile(0.90),
            "queue_wait_p99": qw_h.quantile(0.99),
            # countable silent degradations (repro.obs.log): jnp-kernel
            # fallback at factory build, AOT warmup failure — counted
            # by THIS engine's scoped ledger, so R replicas in one
            # process each report only their own events
            "fallback_events": self.fallback_ledger.count(),
            # wall-clock the obs emitters spent recording (0.0 with
            # obs=None) — the measured-overhead guard: recording happens
            # outside the timed device regions, so it never perturbs the
            # virtual clock, and its host cost is reported, not guessed
            "obs_overhead_s": (self.obs.overhead_s
                               if self.obs is not None else 0.0),
            # wall-clock spent prefilling while decode slots were live
            # (the head-of-line stall chunked prefill bounds); _max_s is
            # the worst stall injected between two consecutive decode
            # steps — the jitter spike the token budget caps
            "prefill_stall_s": self.prefill_stall_s,
            "prefill_stall_max_s": self.prefill_stall_max_s,
            "budget_trace": list(self.budget_trace),
            # dispatch accounting: total prefill launches (bulk-lane
            # batches included), and the DECODE-LOOP per-iteration
            # launch counts (chunked mode aligns entries with
            # budget_trace and every entry is <= 1 — ONE fused launch
            # per iteration; stall mode records admission-burst sizes;
            # batch mode one entry per executed batch), plus the fused
            # executable's padded-shape-key cache hits / misses this
            # serve (0/0 outside chunked mode).  All four parity-match
            # the simulator's SimResult fields.
            "prefill_dispatches": self.prefill_dispatches,
            "prefill_dispatch_trace": list(self.prefill_dispatch_trace),
            "exec_cache_hits": self.exec_cache_hits,
            "exec_cache_misses": self.exec_cache_misses,
            # decode-dispatch accounting (async host pipeline): one
            # launch per N-step window, so steps/dispatches ==
            # decode_steps exactly; the trace holds steps per window.
            # All three parity-match SimResult.
            "decode_dispatches": self.decode_dispatches,
            "decode_steps_executed": self.decode_steps_total,
            "decode_dispatch_trace": list(self.decode_dispatch_trace),
            # prefix-cache metrics (kvcache.prefix counters; the
            # simulator's cache model reports the identical fields —
            # the engine-vs-sim parity tests compare them directly).
            # hit_rate is hit / probed FULL prompt blocks across all
            # admissions; cached_tokens_reused counts prompt tokens NOT
            # recomputed; cow_copies counts full-match page copies.
            "prefix_hit_rate": ps.get("prefix_hit_rate", 0.0),
            "cached_tokens_reused": ps.get("cached_tokens_reused", 0),
            "cow_copies": ps.get("cow_copies", 0),
            "prefix_evictions": ps.get("prefix_evictions", 0),
            "kv": {"kind": self.kv, "num_slots": self.num_slots,
                   "block_size": self.kv_block_size,
                   "num_blocks": self.kv_num_blocks,
                   "prefix_cache": self.prefix_cache_enabled},
            "prefill": {"kind": self.prefill,
                        "chunk_size": self.chunk_size,
                        "token_budget": self.token_budget},
            "pipeline": {"decode_steps": self.decode_steps,
                         "aot_warmup": self.aot_warmup,
                         "persist_prefix_cache":
                             self.persist_prefix_cache},
            # SLO monitoring / predictor calibration / health snapshots
            # (PR 8): {} / [] with the features off, so the obs=None
            # result stays field-identical to pre-PR serves.
            # SimResult carries the same three fields.
            "slo_attainment": (self.obs.slo.attainment()
                               if self.obs is not None
                               and self.obs.slo is not None else {}),
            "calibration": (self.obs.calibration.summary()
                            if self.obs is not None
                            and self.obs.calibration is not None
                            else {}),
            "health_trace": (list(self.obs.health_trace)
                             if self.obs is not None else []),
        }
        if self.faults is not None:
            # fault-gated keys: present ONLY when a fault plan is
            # threaded, so unfaulted result dicts stay byte-identical
            # to pre-fault serves (SimResult mirrors the counts)
            out["timed_out"] = len(self.timed_out_tasks)
            out["shed"] = len(self.shed_tasks)
            out["timed_out_ids"] = [t.task.task_id
                                    for t in self.timed_out_tasks]
            out["shed_ids"] = [t.task.task_id for t in self.shed_tasks]
            out["crashed"] = self._crashed
            out["final_step"] = self.last_step
            out["survivor_ids"] = [q.task_id for q in self.survivors]
        return out

    def health(self) -> Dict:
        """Latest health snapshot of the current/last serve — the
        observation vector a future auto-tuner/router polls ({} with
        obs off or before the first snapshot fires)."""
        return self.obs.health() if self.obs is not None else {}

    def _serve_batch(self, requests: Sequence[Request]) -> Dict:
        pending = sorted(requests, key=lambda r: r.arrival)
        sim_tasks = [self._to_sim_task(r) for r in pending]
        queue: List[prio.SimTask] = []
        bulk: List[prio.SimTask] = []
        done: List[prio.SimTask] = []
        now = 0.0
        i = 0
        n = len(sim_tasks)
        C = self.persona.batch_size
        while len(done) < n:
            while i < n and sim_tasks[i].r <= now + 1e-9:
                if self.obs is not None:
                    cls = sim_tasks[i].task.traffic_class
                    self.obs.event("enqueue", sim_tasks[i].r,
                                   sim_tasks[i].task.task_id,
                                   **({"cls": cls} if cls else {}))
                queue.append(sim_tasks[i])
                i += 1
            if queue and (len(queue) >= C
                          or now - min(t.r for t in queue) >= self.xi
                          or i >= n):
                t0 = time.perf_counter()
                gpu_b, cpu_b, rest = self.policy.select(list(queue), now)
                self.scheduler_overhead_s += time.perf_counter() - t0
                queue = list(rest)
                bulk.extend(cpu_b)
                if gpu_b:
                    Cb = self.batch_capacity
                    now = self._run_batch(gpu_b[:Cb], "gpu", now)
                    done.extend(gpu_b[:Cb])
                    queue.extend(gpu_b[Cb:])
                    continue
            if bulk and not queue:
                batch, bulk = bulk[:C], bulk[C:]
                now = self._run_batch(batch, "cpu", now)
                done.extend(batch)
                continue
            # idle: advance to next arrival / window expiry
            cand = []
            if i < n:
                cand.append(sim_tasks[i].r)
            if queue:
                cand.append(min(t.r for t in queue) + self.xi)
            future = [c for c in cand if c > now]
            if future:
                now = min(future)
            else:
                now += self.xi
        return self._result(done, n)

    # ------------------------------------------------------------------
    # continuous batching: persistent decode loop with slot recycling
    # ------------------------------------------------------------------

    def _extend_block_tables(self, active, slot_task, slot_gen, slot_cap,
                             alloc, kvc, steps: int) -> None:
        """Boundary crossings before a paged decode WINDOW: extend each
        active slot's table to cover every useful write of the next
        ``steps`` launches-in-one (``kvcache.window_target_tokens`` —
        clamped at the admission reservation, so the pool can never run
        dry and rejection decisions are independent of ``steps``).
        Overhang writes past the clamp land on the trash page via the
        scatter primitives' table-width clamp.  Shared by the stall and
        chunked serve loops; ``steps=1`` is the original synchronous
        per-step rule."""
        S = self.input_bucket
        for s in active:
            tid = slot_task[s].task.task_id
            target = alloc.blocks_for(window_target_tokens(
                S, slot_gen[s], slot_cap[s], steps))
            have = len(alloc.table(tid))
            while target > have:
                kvc.extend_table(s, have, alloc.allocate(tid))
                have += 1

    def _advance_decode_window(self, active, window_host, now, dt,
                               slot_task, slot_gen, slot_cap, tokens,
                               done, *, alloc=None, kvc=None,
                               reserved=None, step: int = 0) -> None:
        """Window-END (in-arrears) bookkeeping shared by the stall and
        chunked serve loops: consume the (C, n) window tokens STEP-MAJOR
        (step j, slots in slot order — for n=1 this is exactly the old
        per-step loop, including completion order), record each token
        with its interpolated emission time, mark sequences finished at
        their EOS/cap step and discard their remaining window columns.
        Eviction happens only after the whole window is consumed: a
        finished sequence's blocks stayed held while the device stepped
        past its end (the eviction-lag invariant — overhang writes hit
        the slot's own blocks or the trash page, never a freed or
        foreign block), and are returned here, before any admission
        decision that could reuse them."""
        ob = self.obs
        n = window_host.shape[1]
        finished: List[int] = []
        for j in range(n):
            t_j = now - dt + dt * (j + 1) / n
            for s in active:
                if slot_task[s] is None or s in finished:
                    continue
                tok = int(window_host[s, j])
                slot_gen[s] += 1
                task = slot_task[s]
                prev_t = task.task.token_times[-1]
                task.task.out_tokens.append(tok)
                task.task.token_times.append(t_j)
                if ob is not None:
                    ob.event("token", t_j, task.task.task_id, step,
                             slot=s, idx=slot_gen[s])
                    ob.slo_observe("itl", task.task.traffic_class,
                                   t_j, t_j - prev_t)
                if tok == self.eos_id or slot_gen[s] >= slot_cap[s]:
                    task.finish = t_j
                    task.task.finish = t_j
                    task.task.out_len = slot_gen[s]
                    done.append(task)
                    finished.append(s)
                    if ob is not None:
                        ob.event("complete", t_j, task.task.task_id,
                                 step, lane="gpu", out_len=slot_gen[s])
                        ob.inc("sched.completions")
                        ob.complete_request(task.task.traffic_class,
                                            t_j, u=task.u,
                                            out_len=slot_gen[s],
                                            latency_s=t_j - task.r)
                        # eviction lag: window steps this slot's blocks
                        # stay held past its logical end (in arrears)
                        ob.observe("decode.eviction_lag_steps",
                                   n - 1 - j)
                else:
                    tokens[s, 0] = tok
        # eviction in arrears: frees happen at window end, in slot
        # order (the simulator frees in the same order, so allocator
        # free-list state stays bit-identical)
        for s in active:
            if s not in finished:
                continue
            tid = slot_task[s].task.task_id
            slot_task[s] = None
            tokens[s, 0] = generate.PAD_ID
            if ob is not None:
                ob.event("evict", now, tid, step, slot=s)
            if alloc is not None:
                alloc.free_sequence(tid)
                kvc.clear_table(s)
                reserved[s] = 0

    # ------------------------------------------------------------------
    def _paged_setup(self):
        """Build — or, with ``persist_prefix_cache=True``, revive — the
        paged serve state (page pool, allocator, prefix cache).  On the
        persistent path the device pool's cached blocks carry their KV
        content across serves (all decode slots were evicted at the
        previous serve's end, so only cache-pinned blocks are live) and
        the prefix index keeps its entries while its per-serve counters
        reset."""
        C = self.num_slots
        mreg = self.obs.metrics if self.obs is not None else None
        if (self.persist_prefix_cache and self.paged_cache is not None
                and self.prefix_cache is not None):
            kvc, alloc = self.paged_cache, self.allocator
            pc = self.prefix_cache
            pc.reset_stats()
            pc.metrics = mreg
            return kvc, alloc, pc, kvc.state
        kvc = PagedKVCache(self.cfg, C, self.kv_num_blocks,
                           self.kv_block_size, self.max_len)
        alloc = BlockAllocator(self.kv_num_blocks, self.kv_block_size)
        self.paged_cache, self.allocator = kvc, alloc
        pc = None
        if self.prefix_cache_enabled:
            pc = PrefixCache(alloc, self.kv_block_size)
            pc.metrics = mreg
            self.prefix_cache = pc
        return kvc, alloc, pc, kvc.state

    def _ragged_aot_key(self, shape_key: tuple) -> tuple:
        return ("ragged", self._aot_dims, shape_key)

    def _aot_warm(self, cache, kvc=None) -> None:
        """AOT-compile the continuous serve loop's executables at
        ``serve()`` start (``jit.lower(avals).compile()`` per shape
        key), so the first request pays neither trace nor compile time.
        ``lower().compile()`` does NOT populate the jit call cache —
        the ``Compiled`` objects live in each ``JitExecutable``'s AOT
        store (shared across same-shape engines via the factory memo)
        and the loops dispatch through ``call_aot``.

        Warmed: the N-step decode window, the admission prefill (stall
        mode), the CoW page copy and the block-quantized
        prefix-suffix ragged keys (prefix cache), and the single-chunk
        ragged keys a chunked serve typically opens with.  Ragged keys
        outside the warmed set (workload-dependent ChunkBatch shapes)
        fall back to jit-on-first-call, counted by exec_cache_misses as
        before.  Warmup failure degrades to jit-on-first-call."""
        if not self.aot_warmup:
            return
        C, S, n = self.num_slots, self.input_bucket, self.decode_steps

        def sds(tree):
            return jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)

        p_s, c_s = sds(self.params), sds(cache)
        tok_s = jax.ShapeDtypeStruct((C, 1), jnp.int32)
        i32 = jax.ShapeDtypeStruct((), jnp.int32)
        batch_s = {"tokens": jax.ShapeDtypeStruct((1, S), jnp.int32)}
        try:
            if self.kv == "paged":
                nb = kvc.max_blocks_per_seq
                tables_s = jax.ShapeDtypeStruct((C, nb), jnp.int32)
                row_s = jax.ShapeDtypeStruct((nb,), jnp.int32)
                self._paged_decode_steps.warm(
                    self._window_key, (p_s, c_s, tok_s, tables_s),
                    {"num_steps": n})
                if self.prefill == "stall":
                    self._paged_prefill.warm(
                        self._admit_key, (p_s, c_s, batch_s, i32, row_s))
                ragged_lens: set = set()
                if self.prefix_cache_enabled:
                    self._copy_block.warm(self._cow_key, (c_s, i32, i32))
                    if self.prefill == "stall":
                        # every reachable uncached-suffix length: prefix
                        # matches are block-quantized, plus the L=1
                        # full-match recompute
                        bs = self.kv_block_size
                        ragged_lens |= {S - k * bs
                                        for k in range(1, S // bs + 1)
                                        if S - k * bs > 0} | {1}
                if self.prefill == "chunked":
                    ragged_lens |= {min(self.chunk_size, S), S}
                for L in sorted(ragged_lens):
                    key = suffix_shape_key(L)
                    TTp, Cp, Tp = key
                    self._ragged_prefill.warm(
                        self._ragged_aot_key(key),
                        (p_s, c_s,
                         {"tokens": jax.ShapeDtypeStruct((1, TTp),
                                                         jnp.int32)},
                         jax.ShapeDtypeStruct((TTp,), jnp.int32),
                         jax.ShapeDtypeStruct((Cp, 4), jnp.int32),
                         jax.ShapeDtypeStruct((Cp, nb), jnp.int32)),
                        {"chunk_pad": Tp})
            else:
                self._decode_steps_fn.warm(
                    self._window_key, (p_s, c_s, tok_s), {"num_steps": n})
                self._slot_prefill.warm(
                    self._admit_key, (p_s, c_s, batch_s, i32))
        except Exception as exc:  # pragma: no cover - environment-specific
            obslog.warn_once(logger, "aot-warmup",
                             "AOT warmup failed (%s); executables will "
                             "trace on first call", exc)

    def _serve_continuous(self, requests: Sequence[Request], *,
                          step_offset: int = 0) -> Dict:
        persona = self.persona
        ob = self.obs
        rf = self.faults
        C = self.num_slots
        S = self.input_bucket
        paged = self.kv == "paged"
        pending = sorted(requests, key=lambda r: r.arrival)
        sim_tasks = [self._to_sim_task(r) for r in pending]
        n = len(sim_tasks)
        queue: List[prio.SimTask] = []
        bulk: List[prio.SimTask] = []
        done: List[prio.SimTask] = []
        pc = None
        kvc = alloc = None
        if paged:
            kvc, alloc, pc, cache = self._paged_setup()
            reserved = [0] * C       # per-slot worst-case block holdback
        else:
            cache = transformer.init_slot_cache(self.cfg, C, self.max_len)
        self._aot_warm(cache, kvc)
        slot_task: List[Optional[prio.SimTask]] = [None] * C
        slot_gen = [0] * C
        slot_cap = [0] * C
        tokens = np.zeros((C, 1), np.int32)     # host copy of next tokens
        self.admission_log = []
        now = 0.0
        i = 0
        step = step_offset
        while (len(done) + len(self.timed_out_tasks)
               + len(self.shed_tasks)) < n:
            if (rf is not None and rf.crash_at_step is not None
                    and not self._crashed and step >= rf.crash_at_step):
                # replica death (serving.faults.CrashFault): evict the
                # active slots in slot order (freeing their KV blocks),
                # then every unfinished request — active, queued,
                # bulk-lane, not-yet-arrived — survives for the fault
                # coordinator to re-dispatch.  The simulator's
                # _ReplicaSim.crash() mirrors this sequence exactly.
                crash_surv: List[prio.SimTask] = []
                for slot in range(C):
                    t = slot_task[slot]
                    if t is None:
                        continue
                    if ob is not None:
                        ob.event("evict", now, t.task.task_id, step,
                                 slot=slot)
                    if paged:
                        alloc.free_sequence(t.task.task_id)
                        kvc.clear_table(slot)
                        reserved[slot] = 0
                    slot_task[slot] = None
                    crash_surv.append(t)
                crash_surv += list(queue) + list(bulk) + sim_tasks[i:]
                queue, bulk = [], []
                self._crashed = True
                self.survivors = [t.task for t in crash_surv]
                if ob is not None:
                    ob.event("replica_down", now, None, step,
                             reason="crash", survivors=len(crash_surv))
                    ob.inc("faults.replica_down")
                break
            while i < n and sim_tasks[i].r <= now + 1e-9:
                if ob is not None:
                    cls = sim_tasks[i].task.traffic_class
                    ob.event("enqueue", sim_tasks[i].r,
                             sim_tasks[i].task.task_id, step,
                             **({"cls": cls} if cls else {}))
                queue.append(sim_tasks[i])
                i += 1
            if rf is not None and queue:
                # failure-aware pre-admission pass (serving.faults):
                # doomed-request timeouts + pressure shedding — the
                # same shed_pass call the simulator's iterate() makes
                # at the same point, so events/counters parity-match
                queue, timed, dropped = shed_pass(
                    queue, now=now, step=step, rf=rf,
                    slo=ob.slo if ob is not None else None, obs=ob)
                self.timed_out_tasks += timed
                self.shed_tasks += dropped
            iter_stall = 0.0
            iter_launches = 0

            # --- admissions: fill freed slots, one policy call per slot
            while queue and None in slot_task:
                running = [t for t in slot_task if t is not None]
                prev_queue = list(queue)
                t0 = time.perf_counter()
                task, lane, rest = self.policy.admit(list(queue), now,
                                                     running)
                self.scheduler_overhead_s += time.perf_counter() - t0
                if task is None:
                    break
                queue = list(rest)
                if lane == "cpu":
                    if ob is not None:
                        ob.event("offload", now, task.task.task_id, step)
                        ob.inc("sched.offloads")
                    bulk.append(task)
                    continue
                cap = self._cap(task.task)
                need = 0
                if paged:
                    # admission gate: reserve the sequence's worst case
                    # (prompt + cap - 1 written positions) so boundary
                    # crossings can never exhaust the pool.  The
                    # simulator's block-budget model mirrors this check
                    # bit for bit (simulate_continuous).
                    need = blocks_for_tokens(S + cap - 1,
                                             self.kv_block_size)
                    if need > self.kv_num_blocks - sum(reserved):
                        queue = prev_queue       # leave it queued
                        self._rejected_ids.add(task.task.task_id)
                        if ob is not None:
                            ob.event("reject", now, task.task.task_id,
                                     step, kv_blocks=need)
                            ob.inc("sched.rejections")
                        break
                slot = slot_task.index(None)
                tid = task.task.task_id
                task.task.queue_wait_s = now - task.r
                if ob is not None:
                    ob.event("admit", now, tid, step, slot=slot,
                             u=task.u, kv_blocks=need)
                    ob.inc("sched.admissions")
                    ob.observe("queue_wait_s", task.task.queue_wait_s)
                    ob.slo_observe("queue_wait",
                                   task.task.traffic_class, now,
                                   task.task.queue_wait_s)
                stalled = any(t is not None for t in slot_task)
                toks = self._tokenize_padded(task.task.text)
                batch = {"tokens": jnp.asarray(toks[None, :])}
                pf_start = 0
                pf_key = "admit"
                t0 = time.perf_counter()
                if paged and pc is not None:
                    # longest-cached-prefix admission: matched blocks
                    # are SHARED into the table (refcounted), the CoW
                    # page copy covers a full-prompt match, and prefill
                    # runs only from the first uncached position —
                    # through the SAME fused ragged executable as
                    # chunked mode, as a single-chunk launch
                    reserved[slot] = need
                    tid = task.task.task_id
                    plan = pc.admit(tid, toks)
                    kvc.set_table(slot, alloc.table(tid))
                    for src, dst in plan.cow:
                        cache = self._copy_block.call_aot(
                            self._cow_key, cache, jnp.int32(src),
                            jnp.int32(dst))
                    if plan.start == 0:
                        cache, last_logits = self._paged_prefill.call_aot(
                            self._admit_key, self.params, cache, batch,
                            jnp.int32(slot), kvc.table_row(slot))
                    else:
                        key = suffix_shape_key(S - plan.start)
                        pf_start, pf_key = plan.start, str(key)
                        pf_hit = key in self._exec_keys
                        if pf_hit:
                            self.exec_cache_hits += 1
                        else:
                            self._exec_keys.add(key)
                            self.exec_cache_misses += 1
                        tokens_arr, token_chunk, meta, tabs = \
                            build_packed_arrays(
                                key,
                                [(slot, plan.start, toks[plan.start:],
                                  alloc.table(tid))],
                                pad_slot=C,
                                table_width=kvc.max_blocks_per_seq,
                                trash_block=kvc.trash_block)
                        cache, last_logits = self._ragged_prefill.call_aot(
                            self._ragged_aot_key(key), self.params, cache,
                            {"tokens": jnp.asarray(tokens_arr)},
                            jnp.asarray(token_chunk), jnp.asarray(meta),
                            jnp.asarray(tabs), chunk_pad=key[2])
                        last_logits = last_logits[0]   # chunk row 0
                    pc.commit(tid, toks)
                elif paged:
                    reserved[slot] = need
                    kvc.set_table(slot, alloc.allocate_n(
                        task.task.task_id, alloc.blocks_for(S)))
                    cache, last_logits = self._paged_prefill.call_aot(
                        self._admit_key, self.params, cache, batch,
                        jnp.int32(slot), kvc.table_row(slot))
                else:
                    cache, last_logits = self._slot_prefill.call_aot(
                        self._admit_key, self.params, cache, batch,
                        jnp.int32(slot))
                first = int(jnp.argmax(last_logits))
                dt = time.perf_counter() - t0
                now += dt
                self.prefill_dispatches += 1   # one launch per admission
                iter_launches += 1
                if stalled:       # live slots waited out this prefill
                    self.prefill_stall_s += dt
                    iter_stall += dt
                if ob is not None:
                    # emitted AFTER the timed launch region so recording
                    # cost never lands on the virtual clock; the order
                    # (prefix_hit -> exec_cache -> prefill_chunk ->
                    # first_token) is what the simulator mirrors
                    if paged and pc is not None and plan.matched_blocks:
                        ob.event("prefix_hit", now, tid, step,
                                 cached_tokens=plan.start,
                                 matched_blocks=plan.matched_blocks,
                                 cow=len(plan.cow))
                    if pf_key != "admit":
                        ob.event("exec_cache", now, tid, step, hit=pf_hit,
                                 shape_key=pf_key)
                        ob.inc("exec_cache.hits" if pf_hit
                               else "exec_cache.misses")
                    ob.inc("prefill.dispatches")
                    ob.span("prefill.admit", now - dt, dt, task=tid,
                            slot=slot)
                    ob.event("prefill_chunk", now, tid, step, slot=slot,
                             start=pf_start, length=S - pf_start,
                             finishes=True, shape_key=pf_key)
                    ob.event("first_token", now, tid, step, slot=slot)
                    ob.slo_observe("ttft", task.task.traffic_class,
                                   now, now - task.r)
                task.start, task.lane = now, "gpu"
                task.task.start, task.task.lane = now, "gpu"
                task.task.slot = slot
                task.task.out_tokens = [first]
                task.task.token_times = [now]
                self.admission_log.append(
                    {"task_id": task.task.task_id, "slot": slot,
                     "step": step, "now": now})
                if first == self.eos_id or cap <= 1:
                    task.finish = now
                    task.task.finish, task.task.out_len = now, 1
                    done.append(task)
                    if ob is not None:
                        ob.event("complete", now, tid, step, lane="gpu",
                                 out_len=1)
                        ob.event("evict", now, tid, step, slot=slot)
                        ob.inc("sched.completions")
                        ob.complete_request(task.task.traffic_class,
                                            now, u=task.u, out_len=1,
                                            latency_s=now - task.r)
                    if paged:
                        alloc.free_sequence(task.task.task_id)
                        kvc.clear_table(slot)
                        reserved[slot] = 0
                else:
                    slot_task[slot] = task
                    slot_gen[slot], slot_cap[slot] = 1, cap
                    tokens[slot, 0] = first

            self.prefill_stall_max_s = max(self.prefill_stall_max_s,
                                           iter_stall)
            if iter_launches:
                self.prefill_dispatch_trace.append(iter_launches)
            active = [s for s in range(C) if slot_task[s] is not None]
            if active:
                self.peak_concurrency = max(self.peak_concurrency,
                                            len(active))
                # --- one N-step decode WINDOW over ALL slots: a single
                # scanned launch; the completion worker handles the
                # blocking readback off the scheduler thread, and all
                # bookkeeping (token recording, eviction) happens at
                # window end, in arrears
                nsteps = self.decode_steps
                t0 = time.perf_counter()
                if paged:
                    self._extend_block_tables(active, slot_task,
                                              slot_gen, slot_cap,
                                              alloc, kvc, nsteps)
                    window_tok, cache = self._paged_decode_steps.call_aot(
                        self._window_key, self.params, cache,
                        jnp.asarray(tokens), kvc.tables_device(),
                        num_steps=nsteps)
                else:
                    window_tok, cache = self._decode_steps_fn.call_aot(
                        self._window_key, self.params, cache,
                        jnp.asarray(tokens), num_steps=nsteps)
                self._worker.submit(window_tok, t0)
                window_host, dt = self._worker.collect()
                if rf is not None:
                    # straggler fault (SlowFault): stretch the window's
                    # charge to the virtual clock.  Wall-only — parity
                    # streams strip time fields by construction.
                    dt *= rf.slow_factor(step)
                now += dt
                step += nsteps
                self.decode_dispatches += 1
                self.decode_steps_total += nsteps
                self.decode_dispatch_trace.append(nsteps)
                if paged:
                    self.kv_util_samples.append(alloc.utilization())
                else:
                    self.kv_util_samples.append(len(active) / C)
                if ob is not None:
                    ob.inc("decode.dispatches")
                    ob.inc("decode.steps", nsteps)
                    ob.gauge("kv.util", self.kv_util_samples[-1])
                    ob.counter_sample("kv.util", now,
                                      self.kv_util_samples[-1])
                    ob.span("decode.window", now - dt, dt, steps=nsteps,
                            active=len(active))
                    ob.event("decode_window", now, None, step,
                             steps=nsteps, active=len(active), dur=dt)
                self._advance_decode_window(
                    active, window_host, now, dt, slot_task, slot_gen,
                    slot_cap, tokens, done,
                    alloc=alloc if paged else None,
                    kvc=kvc if paged else None,
                    reserved=reserved if paged else None, step=step)
                if ob is not None:
                    # snapshot cadence keys off ``step`` (the shared
                    # iteration coordinate), AFTER window bookkeeping —
                    # the simulator snapshots at the identical point
                    ob.maybe_snapshot(
                        now, step, queue_depth=len(queue),
                        active=sum(t is not None for t in slot_task),
                        kv_util=self.kv_util_samples[-1],
                        wall={"collect_wait":
                              self._worker.wait_snapshot()})
                continue

            if bulk and not queue:
                batch, bulk = bulk[:C], bulk[C:]
                now = self._run_batch(batch, "cpu", now)
                done.extend(batch)
                continue

            # idle: advance to the next arrival
            if i < n:
                now = max(now, sim_tasks[i].r)
            else:
                now += self.xi
        if paged:
            kvc.state = cache
        else:
            self.slot_cache = cache
        self.last_step = step
        return self._result(done, n)

    # ------------------------------------------------------------------
    # chunked prefill: token-budgeted prefill/decode interleaving
    # ------------------------------------------------------------------

    def _serve_continuous_chunked(self, requests: Sequence[Request]) -> Dict:
        """Continuous serve with ``prefill="chunked"`` (kv="paged").

        Admission allocates a slot plus the prompt's blocks and enqueues
        a ChunkJob instead of stalling the loop for a full prefill; each
        iteration then packs the token budget — decode tokens first,
        prefill chunks in the policy's uncertainty-priority order — so
        per-iteration prefill work (and therefore every live request's
        ITL) is bounded by ``token_budget``, not by the admission burst.

        Execution is FUSED: the whole iteration's plan becomes one
        ``ChunkBatch`` (``repro.prefill.pack_plans``) and runs through
        a single ragged-prefill launch (``generate.make_ragged_prefill_fn``
        → ``model.prefill_chunks``), with the chunk K/V scatter inside
        — exactly ONE prefill dispatch per iteration instead of one
        scatter + one kernel per chunk (asserted via
        ``prefill_dispatches`` / ``prefill_dispatch_trace``).  Chunk
        writes land at exact position offsets, so output is
        token-for-token identical to the stall-admission paged engine;
        ``simulate_continuous(prefill="chunked")`` drives the same
        ChunkScheduler + pack_plans and reproduces the completion
        order, the per-iteration budget trace AND the dispatch /
        executable-cache counters.
        """
        C = self.num_slots
        S = self.input_bucket
        ob = self.obs
        pending = sorted(requests, key=lambda r: r.arrival)
        sim_tasks = [self._to_sim_task(r) for r in pending]
        n = len(sim_tasks)
        queue: List[prio.SimTask] = []
        bulk: List[prio.SimTask] = []
        done: List[prio.SimTask] = []
        kvc, alloc, pc, cache = self._paged_setup()
        reserved = [0] * C           # per-slot worst-case block holdback
        self._aot_warm(cache, kvc)
        sched = ChunkScheduler(self.chunk_size, self.token_budget,
                               metrics=ob.metrics if ob is not None
                               else None)
        slot_task: List[Optional[prio.SimTask]] = [None] * C  # decoding
        slot_gen = [0] * C
        slot_cap = [0] * C
        job_cap: Dict[int, int] = {}      # slot -> decode cap
        job_tokens: Dict[int, np.ndarray] = {}  # slot -> padded prompt
        job_row: Dict[int, np.ndarray] = {}     # slot -> host table row
        job_start: Dict[int, int] = {}    # slot -> cached-prefix offset
        tokens = np.zeros((C, 1), np.int32)
        self.admission_log = []
        now = 0.0
        i = 0
        step = 0
        while len(done) < n:
            while i < n and sim_tasks[i].r <= now + 1e-9:
                if ob is not None:
                    cls = sim_tasks[i].task.traffic_class
                    ob.event("enqueue", sim_tasks[i].r,
                             sim_tasks[i].task.task_id, step,
                             **({"cls": cls} if cls else {}))
                queue.append(sim_tasks[i])
                i += 1

            # --- admissions: allocate slot + blocks, enqueue chunk job
            free = [s for s in range(C) if slot_task[s] is None
                    and s not in job_cap]
            while queue and free:
                running = ([t for t in slot_task if t is not None]
                           + [j.task for j in sorted(sched.jobs,
                                                     key=lambda j: j.seq)])
                prev_queue = list(queue)
                t0 = time.perf_counter()
                task, lane, rest = self.policy.admit(list(queue), now,
                                                     running)
                self.scheduler_overhead_s += time.perf_counter() - t0
                if task is None:
                    break
                queue = list(rest)
                if lane == "cpu":
                    if ob is not None:
                        ob.event("offload", now, task.task.task_id, step)
                        ob.inc("sched.offloads")
                    bulk.append(task)
                    continue
                cap = self._cap(task.task)
                # identical reservation gate to the stall path — the
                # chunked simulator mirrors it bit for bit
                need = blocks_for_tokens(S + cap - 1, self.kv_block_size)
                if need > self.kv_num_blocks - sum(reserved):
                    queue = prev_queue           # leave it queued
                    self._rejected_ids.add(task.task.task_id)
                    if ob is not None:
                        ob.event("reject", now, task.task.task_id, step,
                                 kv_blocks=need)
                        ob.inc("sched.rejections")
                    break
                slot = free.pop(0)
                reserved[slot] = need
                task.task.queue_wait_s = now - task.r
                if ob is not None:
                    ob.event("admit", now, task.task.task_id, step,
                             slot=slot, u=task.u, kv_blocks=need)
                    ob.inc("sched.admissions")
                    ob.observe("queue_wait_s", task.task.queue_wait_s)
                    ob.slo_observe("queue_wait",
                                   task.task.traffic_class, now,
                                   task.task.queue_wait_s)
                # all of the prompt's blocks up front: every chunk
                # position is backed, but kvc's DECODE table row stays
                # on the trash page until prefill completes (the decode
                # step writes a KV entry for every row, and a
                # mid-prefill slot must not scribble real blocks)
                toks = self._tokenize_padded(task.task.text)
                start = 0
                if pc is not None:
                    # matched prefix blocks are shared into the table;
                    # the chunk job covers only the uncached suffix
                    plan = pc.admit(task.task.task_id, toks)
                    start = plan.start
                    if ob is not None and plan.matched_blocks:
                        ob.event("prefix_hit", now, task.task.task_id,
                                 step, cached_tokens=plan.start,
                                 matched_blocks=plan.matched_blocks,
                                 cow=len(plan.cow))
                    for src, dst in plan.cow:
                        cache = self._copy_block.call_aot(
                            self._cow_key, cache, jnp.int32(src),
                            jnp.int32(dst))
                else:
                    alloc.allocate_n(task.task.task_id,
                                     alloc.blocks_for(S))
                row = np.full((kvc.max_blocks_per_seq,), kvc.trash_block,
                              np.int32)
                tbl = alloc.table(task.task.task_id)
                row[:len(tbl)] = tbl
                job_row[slot] = row
                job_tokens[slot] = toks
                job_start[slot] = start
                job_cap[slot] = cap
                sched.add(task, slot, S - start,
                          self.policy.assign_priority(task))
                self.admission_log.append(
                    {"task_id": task.task.task_id, "slot": slot,
                     "step": step, "now": now})

            # --- chunk phase: pack the budget, decode tokens first;
            # the WHOLE plan executes as one fused ragged launch
            iter_stall = 0.0
            active0 = [s for s in range(C) if slot_task[s] is not None]
            plans = sched.schedule(len(active0)) if sched.has_jobs else []
            batch_plan = pack_plans(plans)
            if batch_plan is not None:
                key = batch_plan.shape_key
                hit = key in self._exec_keys
                if hit:
                    self.exec_cache_hits += 1
                else:
                    self._exec_keys.add(key)
                    self.exec_cache_misses += 1
                if ob is not None:
                    ob.event("exec_cache", now, None, step, hit=hit,
                             shape_key=str(key))
                    ob.inc("exec_cache.hits" if hit
                           else "exec_cache.misses")
                Tp = batch_plan.padded_chunk_len
                # chunk offsets are relative to the job (the uncached
                # suffix); job_start shifts them to absolute prompt
                # positions when a cached prefix was skipped.  The
                # packed layout itself (metadata rows, padding rules)
                # is encoded once in prefill.build_packed_arrays.
                entries = []
                for ch in batch_plan.chunks:
                    s = ch.slot
                    base = job_start[s] + ch.start
                    entries.append((s, base,
                                    job_tokens[s][base:base + ch.length],
                                    job_row[s]))
                tokens_arr, token_chunk, meta, tabs = build_packed_arrays(
                    key, entries, pad_slot=C,
                    table_width=kvc.max_blocks_per_seq,
                    trash_block=kvc.trash_block)
                stalled = any(t is not None for t in slot_task)
                t0 = time.perf_counter()
                cache, last_logits = self._ragged_prefill.call_aot(
                    self._ragged_aot_key(key), self.params, cache,
                    {"tokens": jnp.asarray(tokens_arr)},
                    jnp.asarray(token_chunk), jnp.asarray(meta),
                    jnp.asarray(tabs), chunk_pad=Tp)
                # greedy-pick on device: only (Cp,) token ids cross the
                # host link, not the (Cp, V) logits; the completion
                # worker does the blocking readback off this thread
                self._worker.submit(jnp.argmax(last_logits, axis=-1), t0)
                next_ids, dt = self._worker.collect()
                now += dt
                self.prefill_dispatches += 1     # ONE launch, all chunks
                if stalled:      # live slots waited out this launch
                    self.prefill_stall_s += dt
                    iter_stall += dt
                if ob is not None:
                    ob.inc("prefill.dispatches")
                    ob.span("prefill.ragged", now - dt, dt,
                            chunks=len(batch_plan.chunks),
                            tokens=batch_plan.total_tokens)
                    for ch in batch_plan.chunks:
                        ob.event("prefill_chunk", now,
                                 ch.job.task.task.task_id, step,
                                 slot=ch.slot, start=ch.start,
                                 length=ch.length, finishes=ch.finishes,
                                 shape_key=str(key))
                for ci, ch in enumerate(batch_plan.chunks):
                    if not ch.finishes:
                        continue
                    s = ch.slot
                    task = ch.job.task
                    first = int(next_ids[ci])
                    if pc is not None:
                        pc.commit(task.task.task_id, job_tokens[s])
                    cap = job_cap.pop(s)
                    del job_tokens[s], job_row[s], job_start[s]
                    task.start, task.lane = now, "gpu"
                    task.task.start, task.task.lane = now, "gpu"
                    task.task.slot = s
                    task.task.out_tokens = [first]
                    task.task.token_times = [now]
                    if ob is not None:
                        ob.event("first_token", now, task.task.task_id,
                                 step, slot=s)
                        ob.slo_observe("ttft", task.task.traffic_class,
                                       now, now - task.r)
                    if first == self.eos_id or cap <= 1:
                        task.finish = now
                        task.task.finish, task.task.out_len = now, 1
                        done.append(task)
                        if ob is not None:
                            ob.event("complete", now, task.task.task_id,
                                     step, lane="gpu", out_len=1)
                            ob.event("evict", now, task.task.task_id,
                                     step, slot=s)
                            ob.inc("sched.completions")
                            ob.complete_request(
                                task.task.traffic_class, now,
                                u=task.u, out_len=1,
                                latency_s=now - task.r)
                        alloc.free_sequence(task.task.task_id)
                        reserved[s] = 0
                    else:
                        # install the real table: the slot joins THIS
                        # iteration's decode step (as a stall admission
                        # would), writing token 1's KV at position S
                        kvc.set_table(s, alloc.table(task.task.task_id))
                        slot_task[s] = task
                        slot_gen[s], slot_cap[s] = 1, cap
                        tokens[s, 0] = first
            prefill_toks = sum(p.length for p in plans)
            self.prefill_stall_max_s = max(self.prefill_stall_max_s,
                                           iter_stall)

            active = [s for s in range(C) if slot_task[s] is not None]
            nsteps = self.decode_steps
            if plans or active:
                self.budget_trace.append((len(active0), prefill_toks))
                self.prefill_dispatch_trace.append(1 if plans else 0)
                # aligned with budget_trace: steps launched this
                # iteration (0 = prefill-only iteration, no decode)
                self.decode_dispatch_trace.append(nsteps if active else 0)
            if active:
                self.peak_concurrency = max(self.peak_concurrency,
                                            len(active))
                # --- one N-step decode WINDOW over ALL slots (see
                # _serve_continuous; identical launch/readback recipe)
                t0 = time.perf_counter()
                self._extend_block_tables(active, slot_task, slot_gen,
                                          slot_cap, alloc, kvc, nsteps)
                window_tok, cache = self._paged_decode_steps.call_aot(
                    self._window_key, self.params, cache,
                    jnp.asarray(tokens), kvc.tables_device(),
                    num_steps=nsteps)
                self._worker.submit(window_tok, t0)
                window_host, dt = self._worker.collect()
                now += dt
                step += nsteps
                self.decode_dispatches += 1
                self.decode_steps_total += nsteps
                self.kv_util_samples.append(alloc.utilization())
                if ob is not None:
                    ob.inc("decode.dispatches")
                    ob.inc("decode.steps", nsteps)
                    ob.gauge("kv.util", self.kv_util_samples[-1])
                    ob.counter_sample("kv.util", now,
                                      self.kv_util_samples[-1])
                    ob.span("decode.window", now - dt, dt, steps=nsteps,
                            active=len(active))
                    ob.event("decode_window", now, None, step,
                             steps=nsteps, active=len(active), dur=dt)
                self._advance_decode_window(
                    active, window_host, now, dt, slot_task, slot_gen,
                    slot_cap, tokens, done, alloc=alloc, kvc=kvc,
                    reserved=reserved, step=step)
                if ob is not None:
                    # same post-window snapshot point as the stall loop
                    ob.maybe_snapshot(
                        now, step, queue_depth=len(queue),
                        active=sum(t is not None for t in slot_task),
                        kv_util=self.kv_util_samples[-1],
                        wall={"collect_wait":
                              self._worker.wait_snapshot()})
                continue
            if plans:
                continue

            if bulk and not queue:
                batch, bulk = bulk[:C], bulk[C:]
                now = self._run_batch(batch, "cpu", now)
                done.extend(batch)
                continue

            # idle: advance to the next arrival
            if i < n:
                now = max(now, sim_tasks[i].r)
            else:
                now += self.xi
        kvc.state = cache
        return self._result(done, n)
