"""Discrete-event simulator invariants (both execution modes).

Deterministic seeded sweeps only — the hypothesis-powered versions of
these invariants live in tests/test_properties.py, which skips cleanly
on environments without the `hypothesis` dev dependency
(requirements-dev.txt).
"""

import numpy as np
import pytest

from repro.core import (datagen, personas, priority as prio,
                        scheduler as sched, simulator, workload)

PERSONA = personas.get_persona("dialogpt")

ALL_POLICIES = ["fifo", "hpf", "luf", "muf", "up", "up+c", "rt-lm"]


def _sim_tasks(us, arrivals):
    return [prio.SimTask(task=None, u=float(u), r=float(r),
                         d=float(r) + 4.0, input_len=5.0,
                         true_out_len=max(1, int(u)))
            for u, r in zip(us, arrivals)]


def _random_workload(seed, n=40):
    rng = np.random.default_rng(seed)
    us = rng.uniform(0.5, 60.0, size=n)
    arrivals = np.cumsum(rng.exponential(0.3, n))
    return _sim_tasks(us, arrivals)


def _check_invariants(tasks, res, mode):
    assert len(res.tasks) == len(tasks)                 # conservation
    ids = sorted(id(t) for t in res.tasks)
    assert len(set(ids)) == len(ids)                    # no duplication
    for t in res.tasks:
        assert t.finish >= t.start >= 0
        assert t.start + 1e-9 >= t.r                    # causality
        if mode == "batch":
            min_service = PERSONA.setup_time + PERSONA.eta * t.true_out_len
            slow = PERSONA.cpu_slowdown if t.lane == "cpu" else 1.0
            assert t.finish - t.start + 1e-6 >= min_service * min(slow, 1.0)
        elif t.lane == "gpu":
            # continuous: a task occupies its slot for out_len - 1 steps
            assert t.finish - t.start + 1e-6 >= \
                PERSONA.eta * (t.true_out_len - 1)
    assert np.isfinite(res.makespan)


@pytest.mark.parametrize("mode", ["batch", "continuous"])
@pytest.mark.parametrize("policy", ALL_POLICIES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_simulation_invariants(seed, policy, mode):
    """No task lost or duplicated; response >= service; finite makespan."""
    tasks = _random_workload(seed)
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=35.0)
    res = simulator.run_policy(tasks, policy, PERSONA, pcfg, mode=mode)
    _check_invariants(tasks, res, mode)


def test_fifo_order_preserved_within_lane():
    tasks = _sim_tasks([5] * 20, np.arange(20) * 0.1)
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=1e18)
    res = simulator.run_policy(tasks, "fifo", PERSONA, pcfg)
    starts = [t.start for t in sorted(res.tasks, key=lambda t: t.r)]
    assert all(a <= b + 1e-9 for a, b in zip(starts, starts[1:]))


def test_fifo_completion_order_continuous_homogeneous():
    """Equal lengths + FIFO admission -> completion follows arrival."""
    tasks = _sim_tasks([5] * 20, np.arange(20) * 0.1)
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=1e18)
    res = simulator.run_policy(tasks, "fifo", PERSONA, pcfg,
                               mode="continuous")
    finishes = [t.finish for t in sorted(res.tasks, key=lambda t: t.r)]
    assert all(a <= b + 1e-9 for a, b in zip(finishes, finishes[1:]))


def test_rtlm_improves_large_variance_workload():
    """End-to-end reproduction of the paper's headline direction:
    on a large-uncertainty-variance saturated workload, RT-LM beats FIFO
    on mean response time and max response time."""
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["large"], 1600, seed=0)
    train, test = datagen.train_test_split(corpus, train_frac=0.3)
    prof = sched.offline_profile(train, PERSONA, epochs=40)
    arrivals = workload.poisson_trace(
        len(test), betas=list(range(60, 301, 60)), seed=1)
    tasks = sched.make_sim_tasks(test, prof, PERSONA, arrivals)
    pcfg = prof.policy_config()
    fifo = simulator.run_policy(tasks, "fifo", PERSONA, pcfg)
    rtlm = simulator.run_policy(tasks, "rt-lm", PERSONA, pcfg)
    assert rtlm.mean_response < fifo.mean_response
    assert rtlm.max_response < fifo.max_response
    assert rtlm.throughput_per_min >= 0.95 * fifo.throughput_per_min


def test_malicious_resilience():
    """Fig. 14: at 30% malicious ratio RT-LM's mean response stays far
    below FIFO's."""
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 1200, seed=2,
        malicious_frac=0.3)
    train, test = datagen.train_test_split(corpus, train_frac=0.3)
    prof = sched.offline_profile(train, PERSONA, epochs=40)
    arrivals = workload.poisson_trace(
        len(test), betas=list(range(60, 301, 60)), seed=3)
    tasks = sched.make_sim_tasks(test, prof, PERSONA, arrivals)
    pcfg = prof.policy_config()
    fifo = simulator.run_policy(tasks, "fifo", PERSONA, pcfg)
    rtlm = simulator.run_policy(tasks, "rt-lm", PERSONA, pcfg)
    assert rtlm.mean_response < 0.5 * fifo.mean_response


@pytest.mark.parametrize("beta,n,seed", [(10, 5, 0), (120, 40, 3),
                                         (300, 80, 5)])
def test_poisson_trace_properties(beta, n, seed):
    arr = workload.constant_rate_trace(n, beta, seed)
    assert len(arr) == n
    assert all(b >= a for a, b in zip(arr, arr[1:]))
    assert arr[0] >= 0
