"""Prefix-cache subsystem coverage (ISSUE 4).

Acceptance properties:

  * hashing — ``block_hashes`` is a longest-prefix chain at block
    granularity: equal prefixes share hashes, the first divergent
    block (and everything after it) differs, partial blocks are never
    hashed;
  * cache/allocator — matched blocks are shared (refcounted), a CoW
    never touches the source block's remaining readers, LRU eviction
    only reclaims blocks nobody references, and a ``clear()`` makes
    the pool whole again;
  * engine — with ``prefix_cache=True`` output is TOKEN-FOR-TOKEN
    identical to the uncached path (stall and chunked prefill), repeat
    prompts hit the cache, full-prompt matches exercise copy-on-write;
  * engine-vs-sim — ``simulate_continuous(prefix_cache=True)`` drives
    the same host-side ``PrefixCache`` + ``BlockAllocator`` and
    reproduces the engine's completion order, hit/CoW/eviction
    counters and per-step utilization trace bit for bit, including
    under a tight block budget with memory rejections and cache
    eviction pressure.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import datagen, personas, priority as prio
from repro.core import scheduler as sched, simulator
from repro.kvcache import BlockAllocator, PrefixCache, block_hashes
from repro.serving.engine import Request, ServingEngine, tokenize_padded

SLOTS = 3
MAX_NEW = 6
BUCKET = 8
BS = 4
CAPS = [2, 6, 1, 4, 6, 2, 3, 5, 1, 6, 2, 4]
CHUNK = 3
BUDGET = 8


def _persona(batch_size=SLOTS):
    return dataclasses.replace(personas.get_persona("bart"),
                               batch_size=batch_size)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib_init(cfg)
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 64, seed=0)
    train, test = datagen.train_test_split(corpus, train_frac=0.5)
    persona = _persona()
    profile = sched.offline_profile(train, persona, epochs=15)
    # cycle a few distinct texts so identical padded buckets REPEAT —
    # the repeats are what the prefix cache reuses (full matches, so
    # the CoW path is exercised as well)
    texts = [test[i % 4].text for i in range(len(CAPS))]
    return cfg, params, persona, profile, texts


def model_lib_init(cfg):
    from repro.models import model as model_lib
    return model_lib.init_params(jax.random.PRNGKey(0), cfg)


def _requests(texts, caps):
    return [Request(text=t, arrival=0.0, task_id=i, max_new_tokens=c)
            for i, (t, c) in enumerate(zip(texts, caps))]


def _sim_tasks(texts, caps, profile, persona, xi=2.0):
    out = []
    for i, (t, c) in enumerate(zip(texts, caps)):
        u = profile.predictor.score(t)
        d = prio.priority_point(0.0, len(t.split()), persona.phi,
                                None, xi=xi)
        out.append(prio.SimTask(
            task=Request(text=t, arrival=0.0, task_id=i),
            u=float(max(u, 0.0)), r=0.0, d=d,
            input_len=float(len(t.split())), true_out_len=int(c)))
    return out


def _prompt_tokens_fn(cfg, bucket=BUCKET):
    """The engine's exact admission-bucket recipe — what the parity
    tests hand to ``simulate_continuous(prompt_tokens=...)``."""
    def fn(task):
        return tokenize_padded(task.task.text, cfg.vocab_size, bucket)
    return fn


def _engine(setup, policy_name="fifo", **kw):
    cfg, params, persona, profile, _ = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    return ServingEngine(
        params, cfg, sched.POLICIES[policy_name](persona, pcfg), profile,
        input_bucket=BUCKET, max_new_tokens=MAX_NEW, mode="continuous",
        eos_id=-1, kv="paged", kv_block_size=BS, **kw)


# ---------------------------------------------------------------------------
# hash chain
# ---------------------------------------------------------------------------


def test_block_hashes_longest_prefix_chain():
    a = list(range(1, 17))                      # 4 full blocks of 4
    b = a[:8] + [99] + a[9:]                    # diverges in block 2
    ha, hb = block_hashes(a, 4), block_hashes(b, 4)
    assert len(ha) == len(hb) == 4
    assert ha[:2] == hb[:2]                     # shared prefix blocks
    assert ha[2] != hb[2] and ha[3] != hb[3]    # divergence propagates
    assert block_hashes(a[:10], 4) == ha[:2]    # partial block unhashed
    assert block_hashes(a[:3], 4) == []         # shorter than one block
    assert block_hashes(a, 4) == ha             # deterministic


# ---------------------------------------------------------------------------
# PrefixCache + allocator (host-side, no device work)
# ---------------------------------------------------------------------------


def test_prefix_cache_share_commit_and_free():
    alloc = BlockAllocator(16, 4)
    pc = PrefixCache(alloc, 4)
    toks = list(range(1, 11))                   # 10 tokens: 2 full + tail
    adm = pc.admit(0, toks)
    assert adm.start == 0 and adm.matched_blocks == 0 and not adm.cow
    assert len(alloc.table(0)) == 3             # blocks_for(10, 4)
    pc.commit(0, toks)
    assert pc.num_cached_blocks == 2            # full blocks only
    # second sequence with the same prompt: shares both full blocks
    adm = pc.admit(1, toks)
    assert adm.start == 8 and adm.matched_blocks == 2 and not adm.cow
    assert alloc.table(1)[:2] == alloc.table(0)[:2]
    assert alloc.table(1)[2] != alloc.table(0)[2]   # private tail
    for blk in alloc.table(1)[:2]:
        assert alloc.refcount(blk) == 3         # cache + two sequences
    # freeing the FIRST owner must not free shared blocks
    alloc.free_sequence(0)
    for blk in alloc.table(1)[:2]:
        assert alloc.refcount(blk) == 2
    alloc.free_sequence(1)
    assert pc.clear() == 2
    alloc.check_no_leaks()


def test_prefix_cache_full_match_cow():
    alloc = BlockAllocator(16, 4)
    pc = PrefixCache(alloc, 4)
    toks = list(range(1, 9))                    # exactly 2 full blocks
    pc.admit(0, toks)
    pc.commit(0, toks)
    shared = list(alloc.table(0))
    adm = pc.admit(1, toks)
    # full-prompt match: last position recomputed => CoW of last block
    assert adm.matched_blocks == 2 and adm.start == 7
    assert len(adm.cow) == 1
    src, dst = adm.cow[0]
    assert src == shared[1] and alloc.table(1) == [shared[0], dst]
    assert alloc.refcount(src) == 2             # cache + seq 0 untouched
    assert alloc.refcount(dst) == 1             # private copy
    assert pc.cow_copies == 1
    alloc.free_sequence(0)
    alloc.free_sequence(1)
    pc.clear()
    alloc.check_no_leaks()


def test_prefix_cache_lru_eviction_only_under_pressure():
    alloc = BlockAllocator(5, 4)
    pc = PrefixCache(alloc, 4)
    a, b = [1, 2, 3, 4], [5, 6, 7, 8]
    pc.admit(0, a), pc.commit(0, a)
    alloc.free_sequence(0)
    pc.admit(1, b), pc.commit(1, b)
    alloc.free_sequence(1)
    assert pc.num_cached_blocks == 2 and alloc.num_free == 3
    # touching `a` makes `b` the LRU entry; the full match CoWs one
    # block (position 3 recomputed for its logits)
    adm = pc.admit(2, a)
    assert adm.matched_blocks == 1 and pc.cow_copies == 1
    assert alloc.num_free == 2
    # no eviction so far: pressure only — and then exactly ONE (b's
    # LRU block), not a's still-cached entry
    assert pc.evictions == 0
    alloc.allocate_n(3, 3)
    assert pc.evictions == 1 and pc.num_cached_blocks == 1
    alloc.free_sequence(3)                      # release the pressure
    assert pc.admit(4, b).matched_blocks == 0   # b was evicted
    assert pc.admit(5, a).matched_blocks == 1   # a survived
    for s in (2, 4, 5):
        alloc.free_sequence(s)
    pc.clear()
    alloc.check_no_leaks()


def test_prefix_cache_hash_collision_degrades_to_miss():
    """A hit is honored only on verbatim token match: forging a
    colliding entry (same hash, different content) must read as a
    MISS, never as silent reuse of wrong KV."""
    alloc = BlockAllocator(8, 4)
    pc = PrefixCache(alloc, 4)
    toks = [1, 2, 3, 4]
    pc.admit(0, toks)
    pc.commit(0, toks)
    h = block_hashes(toks, 4)[0]
    blk, _ = pc._entries[h]
    pc._entries[h] = (blk, (9, 9, 9, 9))        # forged collision
    adm = pc.admit(1, toks)
    assert adm.matched_blocks == 0 and not adm.cow
    alloc.free_sequence(0)
    alloc.free_sequence(1)
    pc.clear()
    alloc.check_no_leaks()


def test_prefix_cache_never_evicts_referenced_blocks():
    from repro.kvcache.allocator import OutOfBlocksError
    alloc = BlockAllocator(2, 4)
    pc = PrefixCache(alloc, 4)
    toks = [1, 2, 3, 4]
    pc.admit(0, toks), pc.commit(0, toks)       # block 0: seq 0 + cache
    alloc.allocate(1)                           # block 1: private
    # pool exhausted and the only cached block is still referenced by
    # seq 0 -> reclaim must refuse rather than evict a read block
    with pytest.raises(OutOfBlocksError):
        alloc.allocate(2)
    assert pc.evictions == 0
    alloc.free_sequence(0)
    alloc.free_sequence(1)
    pc.clear()
    alloc.check_no_leaks()


# ---------------------------------------------------------------------------
# engine: token parity, metrics, CoW
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("prefill_kw", [
    {},
    dict(prefill="chunked", chunk_size=CHUNK, token_budget=BUDGET),
], ids=["stall", "chunked"])
def test_engine_prefix_cache_token_parity(setup, prefill_kw):
    """The acceptance gate: prefix_cache=True reuses most prompt blocks
    (repeat prompts, CoW on the full matches) yet every request's
    greedy output is identical to the uncached engine's."""
    _, _, _, _, texts = setup
    res = {}
    for on in (False, True):
        eng = _engine(setup, prefix_cache=on, **prefill_kw)
        res[on] = eng.serve(_requests(texts, CAPS))
        if on:
            assert eng.prefix_cache is not None
            eng.prefix_cache.clear()
        eng.allocator.check_no_leaks()
    cold = {t.task.task_id: t.task for t in res[False]["tasks"]}
    warm = {t.task.task_id: t.task for t in res[True]["tasks"]}
    for i, c in enumerate(CAPS):
        assert warm[i].out_len == cold[i].out_len == c
        assert warm[i].out_tokens == cold[i].out_tokens
    # repeats of 4 distinct prompts: the cache must actually hit, reuse
    # tokens, and exercise copy-on-write (identical buckets fully match)
    assert res[True]["prefix_hit_rate"] > 0.5
    assert res[True]["cached_tokens_reused"] > 0
    assert res[True]["cow_copies"] > 0
    assert res[False]["prefix_hit_rate"] == 0.0
    assert res[False]["cow_copies"] == 0
    assert res[True]["kv"]["prefix_cache"] is True


def test_engine_prefix_cache_stall_preserves_completion_order(setup):
    """Stall admission: caching changes WHEN prefill compute happens
    but not the admission/eviction schedule, so with simultaneous
    arrivals the completion order matches the uncached engine's."""
    _, _, _, _, texts = setup
    orders = {}
    for on in (False, True):
        eng = _engine(setup, prefix_cache=on)
        orders[on] = eng.serve(_requests(texts, CAPS))["completion_order"]
    assert orders[True] == orders[False]


# ---------------------------------------------------------------------------
# engine-vs-sim parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy_name", ["fifo", "rt-lm"])
@pytest.mark.parametrize("prefill_kw", [
    {},
    dict(prefill="chunked", chunk_size=CHUNK, token_budget=BUDGET),
], ids=["stall", "chunked"])
def test_engine_vs_sim_prefix_parity(setup, policy_name, prefill_kw):
    """The simulator's prefix-cache model (the same PrefixCache class,
    driven host-side) reproduces the engine's completion order, hit /
    CoW counters and per-step utilization trace exactly."""
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng = _engine(setup, policy_name, prefix_cache=True, **prefill_kw)
    res = eng.serve(_requests(texts, CAPS))
    sim = simulator.simulate_continuous(
        _sim_tasks(texts, CAPS, profile, persona),
        sched.POLICIES[policy_name](persona, pcfg),
        num_slots=SLOTS, kv_block_size=BS,
        kv_num_blocks=eng.kv_num_blocks, prompt_len=BUCKET,
        prefix_cache=True, prompt_tokens=_prompt_tokens_fn(cfg),
        **prefill_kw)
    assert res["completion_order"] == [t.task.task_id for t in sim.tasks]
    assert res["prefix_hit_rate"] == sim.prefix_hit_rate
    assert res["cached_tokens_reused"] == sim.cached_tokens_reused
    assert res["cow_copies"] == sim.cow_copies
    assert res["prefix_evictions"] == sim.prefix_evictions
    np.testing.assert_allclose(res["kv_util_peak"], sim.kv_util_peak)
    np.testing.assert_allclose(res["kv_util_mean"], sim.kv_util_mean)
    if prefill_kw:
        assert res["budget_trace"] == sim.budget_trace


def test_engine_vs_sim_prefix_parity_tight_budget(setup):
    """Memory rejections, LRU cache eviction and prefix sharing
    compose: under a pool too small to keep every cached block, engine
    and simulator still decide identically."""
    cfg, params, persona, profile, texts = setup
    bs, nb, slots = 4, 7, 4
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng = _engine(setup, num_slots=slots, kv_num_blocks=nb,
                  prefix_cache=True)
    res = eng.serve(_requests(texts, CAPS))
    assert res["rejected_for_memory"] > 0        # budget actually binds
    assert res["prefix_evictions"] > 0           # cache under pressure
    eng.prefix_cache.clear()
    eng.allocator.check_no_leaks()
    sim = simulator.simulate_continuous(
        _sim_tasks(texts, CAPS, profile, persona),
        sched.POLICIES["fifo"](persona, pcfg),
        num_slots=slots, kv_block_size=bs, kv_num_blocks=nb,
        prompt_len=BUCKET, prefix_cache=True,
        prompt_tokens=_prompt_tokens_fn(cfg))
    assert res["completion_order"] == [t.task.task_id for t in sim.tasks]
    assert res["rejected_for_memory"] == sim.kv_rejected
    assert res["prefix_evictions"] == sim.prefix_evictions
    assert res["prefix_hit_rate"] == sim.prefix_hit_rate
    assert res["cached_tokens_reused"] == sim.cached_tokens_reused
    np.testing.assert_allclose(res["kv_util_peak"], sim.kv_util_peak)
    np.testing.assert_allclose(res["kv_util_mean"], sim.kv_util_mean)


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_prefix_cache_validation():
    cfg = configs.get_smoke_config("starcoder2-3b")
    persona = _persona()
    policy = sched.POLICIES["fifo"](persona, sched.PolicyConfig())
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(None, cfg, policy, None, mode="continuous",
                      kv="contiguous", prefix_cache=True)
    with pytest.raises(ValueError, match="block-budget"):
        simulator.simulate_continuous([], policy, prompt_len=8,
                                      prefix_cache=True)
    with pytest.raises(ValueError, match="prompt_tokens"):
        simulator.simulate_continuous(
            [], policy, prompt_len=8, kv_block_size=4, kv_num_blocks=32,
            prefix_cache=True)
    with pytest.raises(ValueError, match="block_size"):
        PrefixCache(BlockAllocator(8, 4), 8)
