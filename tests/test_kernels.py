"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in interpret
mode (the kernel bodies execute in Python on CPU; on TPU the same bodies
compile via Mosaic)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (chunked_prefill_attention as cpa,
                           decode_attention as fd, flash_attention as fa,
                           paged_decode_attention as pfd, ref,
                           rmsnorm as rn)

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,H,KV,D,causal,window", [
    (1, 64, 4, 2, 32, True, None),
    (2, 48, 4, 1, 16, True, None),     # MQA + padding (48 % 32 != 0)
    (1, 96, 8, 8, 64, True, 24),       # MHA sliding window
    (1, 32, 2, 2, 128, False, None),   # bidirectional (encoder)
])
def test_flash_attention_sweep(B, S, H, KV, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32).astype(dtype)
    out = fa.flash_attention(q, k, v, causal=causal, window=window,
                             block_q=32, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    assert out.dtype == dtype
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,S,H,KV,D,block_k", [
    (2, 128, 4, 2, 32, 32),
    (1, 100, 8, 1, 64, 64),     # padding (100 % 64)
    (3, 64, 4, 4, 16, 16),
    (1, 512, 8, 2, 128, 128),   # long cache
])
def test_flash_decode_sweep(B, S, H, KV, D, block_k, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32).astype(dtype)
    mask = jax.random.bernoulli(ks[3], 0.8, (B, S)).at[:, 0].set(True)
    out = fd.flash_decode_attention(q, kc, vc, mask, block_k=block_k,
                                    interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, mask=mask)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("B,H,KV,D,block_size,nb", [
    (2, 4, 2, 32, 16, 4),       # GQA, 4-entry tables
    (1, 8, 1, 64, 32, 3),       # MQA
    (3, 4, 4, 16, 64, 2),       # MHA, big pages
    (2, 8, 2, 128, 16, 5),      # long table, wide heads
])
def test_paged_decode_sweep(B, H, KV, D, block_size, nb, dtype):
    """Paged flash-decode vs the block-table gather oracle across block
    sizes and RAGGED per-sequence lengths (tables deliberately permuted
    so physical order != logical order)."""
    N = B * nb + 3               # spare pages: stale/garbage content
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (N, block_size, KV, D),
                           jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (N, block_size, KV, D),
                           jnp.float32).astype(dtype)
    rng = np.random.default_rng(B * 131 + block_size)
    tables = jnp.asarray(np.stack(
        [rng.permutation(N)[:nb] for _ in range(B)]).astype(np.int32))
    lens = jnp.asarray(
        rng.integers(1, nb * block_size + 1, (B,)).astype(np.int32))
    out = pfd.paged_flash_decode_attention(q, kp, vp, tables, lens,
                                           interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, tables, lens)
    assert out.shape == (B, H, D) and out.dtype == dtype
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


def test_paged_decode_matches_contiguous_decode():
    """Triangle closure: a paged cache holding the same logical KV as a
    contiguous cache gives the same attention output (paged ref vs the
    contiguous decode oracle)."""
    B, H, KV, D, bs, nb = 2, 4, 2, 32, 16, 4
    S = nb * bs
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kc = jax.random.normal(ks[1], (B, S, KV, D))
    vc = jax.random.normal(ks[2], (B, S, KV, D))
    lens = jnp.asarray([S - 7, 9], jnp.int32)
    # lay the contiguous caches out into per-sequence pages
    kp = kc.reshape(B * nb, bs, KV, D)
    vp = vc.reshape(B * nb, bs, KV, D)
    tables = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    mask = jnp.arange(S)[None, :] < lens[:, None]
    want = ref.decode_attention_ref(q, kc, vc, mask=mask)
    got = ref.paged_decode_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    got_kernel = pfd.paged_flash_decode_attention(q, kp, vp, tables, lens,
                                                  interpret=True)
    np.testing.assert_allclose(np.asarray(got_kernel), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_empty_row_returns_zeros():
    """A seq_len == 0 row (nothing valid to attend to) must yield zeros,
    not an average of garbage page contents; other rows are unaffected."""
    B, H, KV, D, bs, nb = 2, 4, 2, 32, 16, 3
    N = B * nb
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (N, bs, KV, D))
    vp = jax.random.normal(ks[2], (N, bs, KV, D))
    tables = jnp.arange(N, dtype=jnp.int32).reshape(B, nb)
    lens = jnp.asarray([0, 11], jnp.int32)
    out = pfd.paged_flash_decode_attention(q, kp, vp, tables, lens,
                                           interpret=True)
    np.testing.assert_array_equal(np.asarray(out[0]),
                                  np.zeros((H, D), np.float32))
    want = ref.paged_decode_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(want[1]),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("T,B,H,KV,D,block_size,nb", [
    (16, 2, 4, 2, 32, 16, 4),    # GQA, smallest chunk
    (16, 1, 8, 2, 128, 64, 2),   # wide heads, big pages
    (64, 1, 8, 1, 64, 32, 4),    # MQA, mid chunk
    (128, 2, 4, 4, 16, 16, 12),  # MHA, acceptance chunk sweep top end
])
def test_chunked_prefill_sweep(T, B, H, KV, D, block_size, nb, dtype):
    """Chunked-prefill kernel vs the block-table gather oracle across
    chunk sizes {16, 64, 128} and RAGGED prior-context lengths,
    including the zero-prior-context (first chunk) edge; tables are
    permuted so physical order != logical order."""
    N = B * nb + 3               # spare pages: stale/garbage content
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (B, T, H, D), jnp.float32).astype(dtype)
    kp = jax.random.normal(ks[1], (N, block_size, KV, D),
                           jnp.float32).astype(dtype)
    vp = jax.random.normal(ks[2], (N, block_size, KV, D),
                           jnp.float32).astype(dtype)
    rng = np.random.default_rng(T * 7 + B * 131 + block_size)
    tables = jnp.asarray(np.stack(
        [rng.permutation(N)[:nb] for _ in range(B)]).astype(np.int32))
    # row 0 is always the first-chunk edge (zero prior context); others
    # ragged in [0, nb*bs - T]
    maxc = nb * block_size - T
    clens = jnp.asarray(
        [0] + [int(rng.integers(0, maxc + 1)) for _ in range(B - 1)],
        jnp.int32)
    out = cpa.chunked_prefill_attention(q, kp, vp, tables, clens,
                                        interpret=True)
    want = ref.chunked_prefill_attention_ref(q, kp, vp, tables, clens)
    assert out.shape == (B, T, H, D) and out.dtype == dtype
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


def test_chunked_prefill_matches_full_causal():
    """Triangle closure: when the pages hold a full sequence and the
    chunk is its tail, chunked-prefill attention equals rows
    [ctx:ctx+T] of ordinary causal attention over the sequence."""
    B, H, KV, D, bs, nb, T = 1, 4, 2, 32, 16, 4, 16
    S = nb * bs
    ctx = S - T
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q_full = jax.random.normal(ks[0], (B, S, H, D))
    kc = jax.random.normal(ks[1], (B, S, KV, D))
    vc = jax.random.normal(ks[2], (B, S, KV, D))
    want = ref.attention_ref(q_full, kc, vc, causal=True)[:, ctx:]
    kp = kc.reshape(B * nb, bs, KV, D)
    vp = vc.reshape(B * nb, bs, KV, D)
    tables = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    clens = jnp.asarray([ctx], jnp.int32)
    got = ref.chunked_prefill_attention_ref(q_full[:, ctx:], kp, vp,
                                            tables, clens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    got_kernel = cpa.chunked_prefill_attention(q_full[:, ctx:], kp, vp,
                                               tables, clens,
                                               interpret=True)
    np.testing.assert_allclose(np.asarray(got_kernel), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape,block_rows", [
    ((8, 128), 4), ((3, 5, 256), 8), ((17, 64), 8), ((1, 1024), 1),
])
def test_rmsnorm_sweep(shape, block_rows, dtype):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, shape, jnp.float32).astype(dtype)
    w = (jax.random.normal(key, shape[-1:], jnp.float32) * 0.2).astype(dtype)
    out = rn.rms_norm(x, w, block_rows=block_rows, interpret=True)
    want = ref.rms_norm_ref(x, w)
    assert out.shape == x.shape and out.dtype == dtype
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


def test_ops_wrappers_dispatch():
    """use_pallas=False falls back to the layers implementations."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 32, 4, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    a = ops.flash_attention(q, k, v, use_pallas=True, interpret=True,
                            block_q=16, block_k=16)
    b = ops.flash_attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)
    x = jax.random.normal(ks[0], (4, 64))
    w = jnp.zeros(64)
    np.testing.assert_allclose(
        ops.rms_norm(x, w, use_pallas=True, interpret=True),
        ops.rms_norm(x, w, use_pallas=False), atol=1e-5, rtol=1e-5)
    qd = jax.random.normal(ks[0], (2, 4, 16))
    kp = jax.random.normal(ks[1], (6, 8, 2, 16))
    vp = jax.random.normal(ks[2], (6, 8, 2, 16))
    tables = jnp.asarray([[0, 2, 4], [1, 3, 5]], jnp.int32)
    lens = jnp.asarray([17, 9], jnp.int32)
    np.testing.assert_allclose(
        ops.paged_decode_attention(qd, kp, vp, tables, lens,
                                   use_pallas=True, interpret=True),
        ops.paged_decode_attention(qd, kp, vp, tables, lens,
                                   use_pallas=False),
        atol=1e-4, rtol=1e-4)
    qc = jax.random.normal(ks[0], (2, 8, 4, 16))
    clens = jnp.asarray([0, 9], jnp.int32)
    np.testing.assert_allclose(
        ops.chunked_prefill_attention(qc, kp, vp, tables, clens,
                                      use_pallas=True, interpret=True),
        ops.chunked_prefill_attention(qc, kp, vp, tables, clens,
                                      use_pallas=False),
        atol=1e-4, rtol=1e-4)
