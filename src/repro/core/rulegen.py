"""RULEGEN — hand-crafted linguistic-uncertainty rules (paper §III-B).

Reproduces the paper's rule generator: the input text is tokenized and
PoS-tagged (spaCy in the paper; a self-contained lexicon PoS-lite here,
since the container is offline), then six uncertainty intensities are
measured by searching for pre-defined patterns — Listing 1 of the paper
shows the vague-expression rule; the other five follow the same recipe
from the cited literature (Table I).

``rulegen(text)`` returns the 6-vector of intensities
(structural, syntactic, semantic, vague, open_ended, multi_part);
``features(text)`` appends the input length (the paper's fallback signal
for sentences with none of the six sources, Fig. 2a/2e).
"""

from __future__ import annotations

import re
from typing import Dict, List

import numpy as np

# ---------------------------------------------------------------------------
# tokenizer + PoS-lite lexicon
# ---------------------------------------------------------------------------

_TOKEN_RE = re.compile(r"[a-zA-Z']+|[?.,!;:]")


def tokenize(text: str) -> List[str]:
    return _TOKEN_RE.findall(text.lower())


# words whose lexicon entry carries >1 PoS tag (syntactic ambiguity)
MULTI_POS = {
    "flies": ("NOUN", "VERB"), "like": ("VERB", "ADP"),
    "watch": ("NOUN", "VERB"), "duck": ("NOUN", "VERB"),
    "saw": ("NOUN", "VERB"), "rounds": ("NOUN", "VERB"), "park": ("NOUN", "VERB"),
    "train": ("NOUN", "VERB"), "book": ("NOUN", "VERB"),
    "plant": ("NOUN", "VERB"), "play": ("NOUN", "VERB"),
    "runs": ("NOUN", "VERB"),
    "walks": ("NOUN", "VERB"), "files": ("NOUN", "VERB"),
    "races": ("NOUN", "VERB"), "cooks": ("NOUN", "VERB"),
    "fly": ("NOUN", "VERB"), "face": ("NOUN", "VERB"),
    "hand": ("NOUN", "VERB"), "man": ("NOUN", "VERB"),
    "dust": ("NOUN", "VERB"), "seed": ("NOUN", "VERB"),
    "sand": ("NOUN", "VERB"), "water": ("NOUN", "VERB"),
    "rice": ("NOUN", "ADJ"),
}

# polysemy lexicon: word -> number of common senses (semantic ambiguity)
POLYSEMOUS: Dict[str, int] = {
    "bat": 3, "bats": 3, "trunk": 4, "monitor": 3, "bank": 3, "banks": 3,
    "spring": 4, "pitch": 4, "crane": 3, "seal": 3, "bolt": 3, "chest": 2,
    "club": 3, "court": 3, "date": 3, "draft": 4, "fair": 3, "jam": 3,
    "letter": 2, "match": 3, "mine": 2, "nail": 2, "organ": 2, "palm": 2,
    "pool": 3, "pupil": 2, "ring": 3, "rock": 3, "scale": 4, "tie": 3,
    "wave": 3, "well": 3, "cell": 3, "mouse": 2, "virus": 2, "bug": 3,
    "table": 2, "key": 3, "note": 3, "bar": 4, "board": 3, "cap": 3,
    "light": 3, "mole": 3, "port": 3, "present": 3, "racket": 2,
}

PREPOSITIONS = {"in", "on", "at", "with", "by", "near", "under", "over",
                "behind", "beside", "from", "through", "across", "about"}
DETERMINERS = {"a", "an", "the", "this", "that", "these", "those", "my",
               "your", "his", "her", "its", "our", "their", "some"}
WH_WORDS = {"what", "why", "how", "when", "where", "who", "which", "whose"}
CONJ = {"and", "or"}

# Listing-1 style lexicons for the vague-expression rule
VAGUE_NOUNS = {"history", "nature", "concept", "idea", "meaning", "essence",
               "philosophy", "culture", "society", "art", "life", "things",
               "stuff", "future", "past", "world", "universe", "role",
               "impact", "significance", "importance", "state", "notion"}
VAGUE_ADJS = {"general", "broad", "various", "overall", "abstract", "vague",
              "complex", "global", "universal", "fundamental", "big",
              "whole", "entire", "many", "several", "countless", "endless"}
VAGUE_QUANT = {"some", "many", "much", "lots", "plenty", "several",
               "a lot of", "kind of", "sort of", "somewhat"}
OPEN_HEADS = {"causes", "consequences", "implications", "effects",
              "significance", "meaning", "purpose", "origins", "reasons",
              "future", "pros", "cons", "benefits", "drawbacks",
              "advantages", "disadvantages"}
OPINION_PAT = re.compile(
    r"\b(what do you think|do you think|your (opinion|view|thoughts)|"
    r"in your opinion|how do you feel)\b")
VAGUE_OF_PAT = re.compile(
    r"\b(tell me|talk|tell us|know|learn|hear) (\w+ ){0,2}about\b|"
    r"\b(history|nature|concept|meaning|philosophy|essence|idea|future|"
    r"state|role|impact) of\b")


def _pos_tags(tokens: List[str]) -> List[str]:
    tags = []
    for i, t in enumerate(tokens):
        if t in DETERMINERS:
            tags.append("DET")
        elif t in PREPOSITIONS:
            tags.append("ADP")
        elif t in WH_WORDS:
            tags.append("WH")
        elif t in CONJ:
            tags.append("CCONJ")
        elif t in ("?", ".", ",", "!", ";", ":"):
            tags.append("PUNCT")
        elif t in MULTI_POS:
            tags.append("AMBIG")
        elif t.endswith("ing") or t.endswith("ed") or t in (
                "is", "are", "was", "were", "be", "do", "does", "did",
                "can", "could", "should", "would", "will", "tell", "saw",
                "differ", "deal", "think", "know", "talk", "eat", "love"):
            tags.append("VERB")
        elif t.endswith("ly"):
            tags.append("ADV")
        elif t in VAGUE_ADJS:
            tags.append("ADJ")
        else:
            tags.append("NOUN")
    return tags


# ---------------------------------------------------------------------------
# the six rules
# ---------------------------------------------------------------------------


def structural_score(tokens, tags) -> float:
    """PP-attachment ambiguity: >=2 prepositional phrases after a verb can
    each attach to the verb or a preceding NP ('saw a boy in the park with
    a telescope')."""
    if "VERB" not in tags and "AMBIG" not in tags:
        return 0.0
    first_v = min((i for i, t in enumerate(tags)
                   if t in ("VERB", "AMBIG")), default=len(tags))
    pps = [i for i, t in enumerate(tags[first_v + 1:], first_v + 1)
           if t == "ADP"]
    # each PP beyond the first has >=2 attachment sites
    score = max(0, len(pps) - 1) * 2.0
    # coordination right after an NP adds bracketing readings
    score += sum(1.0 for i in pps if i + 2 < len(tags)
                 and tags[i + 1] == "DET" and tags[i + 2] == "NOUN") * 0.5
    return score


def syntactic_score(tokens, tags) -> float:
    """Words carrying multiple PoS tags ('Rice flies like sand')."""
    n = sum(1.0 for t in tokens if t in MULTI_POS)
    # adjacent ambiguous words multiply the parse count
    runs = sum(1.0 for a, b in zip(tokens, tokens[1:])
               if a in MULTI_POS and b in MULTI_POS)
    return n + runs


def semantic_score(tokens, tags) -> float:
    """Polysemous words, weighted by (senses - 1)."""
    return float(sum(POLYSEMOUS.get(t, 1) - 1 for t in tokens))


def vague_score(text, tokens, tags) -> float:
    """Listing 1: PoS-tagged tokens + regex patterns for broad concepts."""
    score = 0.0
    if VAGUE_OF_PAT.search(text.lower()):
        score += 2.0
    score += sum(1.0 for t in tokens if t in VAGUE_NOUNS) * 0.8
    score += sum(1.0 for t in tokens if t in VAGUE_ADJS) * 0.6
    score += sum(0.4 for q in VAGUE_QUANT if q in text.lower())
    return score


def open_score(text, tokens, tags) -> float:
    """Open-ended questions lacking a single definitive answer."""
    tl = text.lower()
    score = 0.0
    if tokens and tokens[0] in ("why", "how"):
        score += 1.5
    if re.search(r"\bwhat (are|is) the\b", tl):
        score += 0.5
    score += sum(1.2 for h in OPEN_HEADS if h in tokens)
    if OPINION_PAT.search(tl):
        score += 1.5
    if "?" in text and not any(
            t in tokens for t in ("when", "where", "who")):
        score += 0.3
    return score


def multipart_score(text, tokens, tags) -> float:
    """Multiple sub-questions / enumerated topics demanding each an answer."""
    score = 0.0
    score += text.count("?") - 1 if text.count("?") > 1 else 0
    # 'X and Y' coordinations
    coords = sum(1.0 for a, b in zip(tags, tags[1:] + ["PUNCT"])
                 if a == "CCONJ")
    score += max(0.0, coords - 0.0) * 0.8
    # comma enumerations: 'A, B, and C'
    commas = tokens.count(",")
    if commas >= 1 and coords >= 1:
        score += commas * 0.8
    if re.search(r"\bdiffer in\b|\bcompare\b|\bboth\b|respectively",
                 text.lower()):
        score += 1.0
    return score


UNCERTAINTY_TYPES = ("structural", "syntactic", "semantic", "vague",
                     "open_ended", "multi_part")


def rulegen(text: str) -> np.ndarray:
    """The paper's RULEGEN(J): 6-vector of uncertainty intensities."""
    tokens = tokenize(text)
    tags = _pos_tags(tokens)
    return np.array([
        structural_score(tokens, tags),
        syntactic_score(tokens, tags),
        semantic_score(tokens, tags),
        vague_score(text, tokens, tags),
        open_score(text, tokens, tags),
        multipart_score(text, tokens, tags),
    ], dtype=np.float32)


def input_length(text: str) -> float:
    return float(len(tokenize(text)))


def features(text: str) -> np.ndarray:
    """6 rule scores + input length (the fallback channel of Fig. 2a/2e)."""
    return np.concatenate([rulegen(text),
                           [input_length(text)]]).astype(np.float32)


FEATURE_DIM = 7


def single_rule_score(text: str) -> float:
    """Paper §III-B 'single rule': the dominant rule intensity, falling
    back to input length when no uncertainty pattern fires."""
    r = rulegen(text)
    if r.max() <= 0:
        return input_length(text)
    return float(r.max())


def weighted_rule_score(text: str, weights: np.ndarray) -> float:
    """Paper §III-B 'weighted rule': linear blend fitted offline."""
    r = features(text)
    return float(r @ weights[:FEATURE_DIM])
