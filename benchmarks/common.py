"""Shared benchmark scaffolding: corpora, profiles, trace construction.

One benchmark module per paper table/figure (see run.py); they all share
this cache so the five per-persona predictors are trained once per
variance subset.
"""

from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.core import (datagen, personas, scheduler as sched, simulator,
                        workload)

OUTDIR = os.environ.get("RTLM_BENCH_OUT", "experiments/bench")

# workload calibration (DESIGN.md §6): the paper's beta ramp is 10..150
# q/min against an RTX A4500 — per-LM batch sizes C_f differ 3x, so a
# single ramp saturates DialoGPT (C=11) while leaving T5 (C=33) idle and
# policy-insensitive.  We preserve the ramp SHAPE (14 linear steps, one
# simulated minute each) but scale its peak per persona to the same
# 2x-capacity sustained-overload regime the paper's tables were
# measured in (their Figs. 9-12 show multi-second queueing delays, i.e.
# saturated peaks).
N_RAMP_STEPS = 14
PEAK_UTILIZATION = 2.0
N_TASKS = 2800
TRAIN_FRAC = 0.3
EPOCHS = 60
SEED = 0


def persona_betas(persona_name: str, variance: str,
                  malicious_pct: int = 0,
                  platform: str = "edge_server") -> list:
    import numpy as _np
    persona = personas.on_platform(
        personas.get_persona(persona_name), platform)
    train, _ = corpus(variance, malicious_pct)
    lens = _np.array([t.out_lens[persona_name] for t in train])
    # batched decode runs to ~the long tail of its batch
    t_batch = (persona.setup_time + persona.eta * _np.quantile(lens, 0.9)
               + persona.item_time * persona.batch_size)
    peak = 60.0 * persona.batch_size / t_batch * PEAK_UTILIZATION
    return [max(5, int(peak * i / N_RAMP_STEPS))
            for i in range(1, N_RAMP_STEPS + 1)]

POLICIES = ("fifo", "hpf", "luf", "muf", "rt-lm")
ABLATION = ("fifo", "hpf", "slack-eq2", "up", "up+c", "rt-lm")
VARIANCES = ("small", "normal", "large")


@functools.lru_cache(maxsize=None)
def corpus(variance: str, malicious_pct: int = 0, seed: int = SEED):
    tasks = datagen.generate_corpus(
        datagen.VARIANCE_MIXES[variance], N_TASKS, seed=seed,
        malicious_frac=malicious_pct / 100.0)
    return datagen.train_test_split(tasks, train_frac=TRAIN_FRAC,
                                    seed=seed)


@functools.lru_cache(maxsize=None)
def profile(variance: str, persona_name: str, malicious_pct: int = 0,
            seed: int = SEED, tail_quantile=None):
    train, _ = corpus(variance, malicious_pct, seed)
    persona = personas.get_persona(persona_name)
    t0 = time.time()
    prof = sched.offline_profile(train, persona, epochs=EPOCHS, seed=seed,
                                 tail_quantile=tail_quantile)
    prof.train_wall_s = time.time() - t0
    return prof


def sim_tasks(variance: str, persona_name: str, malicious_pct: int = 0,
              seed: int = SEED, platform: str = "edge_server",
              tail_quantile=None):
    _, test = corpus(variance, malicious_pct, seed)
    prof = profile(variance, persona_name, malicious_pct, seed,
                   tail_quantile)
    persona = personas.on_platform(
        personas.get_persona(persona_name), platform)
    betas = persona_betas(persona_name, variance, malicious_pct, platform)
    arrivals = workload.poisson_trace(len(test), betas=betas,
                                      seed=seed + 1)
    return sched.make_sim_tasks(test, prof, persona, arrivals), prof


def run(variance: str, persona_name: str, policy: str, *,
        malicious_pct: int = 0, alpha: float = 1.0, lam: float = 1.5,
        b: float = 1.8, seed: int = SEED, platform: str = "edge_server",
        tail_quantile=None) -> simulator.SimResult:
    tasks, prof = sim_tasks(variance, persona_name, malicious_pct, seed,
                            platform, tail_quantile)
    persona = personas.on_platform(
        personas.get_persona(persona_name), platform)
    pcfg = prof.policy_config(alpha=alpha, lam=lam, b=b)
    return simulator.run_policy(tasks, policy, persona, pcfg)


def provenance(seed: int = SEED) -> Dict:
    """Reproducibility stamp attached to every saved benchmark JSON:
    enough to re-run the exact measurement (git_sha of the tree,
    jax version, backend platform, workload seed, wall timestamp)."""
    import subprocess
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip() or "unknown"
    except Exception:                       # noqa: BLE001 - no git
        sha = "unknown"
    try:
        import jax
        jax_version = jax.__version__
        platform = jax.default_backend()
    except Exception:                       # noqa: BLE001 - jax-free use
        jax_version = platform = "unknown"
    return {
        "git_sha": sha,
        "jax_version": jax_version,
        "platform": platform,
        "seed": seed,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def save(name: str, payload, seed: int = SEED) -> str:
    os.makedirs(OUTDIR, exist_ok=True)
    path = os.path.join(OUTDIR, f"{name}.json")
    # stamp provenance without disturbing the payload rows: dict
    # payloads get a "_provenance" key, anything else is wrapped
    stamp = provenance(seed)
    if isinstance(payload, dict):
        payload = {"_provenance": stamp, **payload}
    else:
        payload = {"_provenance": stamp, "rows": payload}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def summarize(outdir: str = None) -> Dict:
    """Collate every ``<outdir>/*.json`` into one BENCH_SUMMARY.json:
    per-benchmark provenance + top-level scalar fields (nested rows are
    elided — the summary is a cross-run index, not a data copy)."""
    outdir = outdir or OUTDIR
    summary: Dict[str, Dict] = {}
    for fname in sorted(os.listdir(outdir) if os.path.isdir(outdir)
                        else []):
        if not fname.endswith(".json") or fname == "BENCH_SUMMARY.json":
            continue
        path = os.path.join(outdir, fname)
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            continue
        entry: Dict = {}
        if isinstance(payload, dict):
            entry["provenance"] = payload.get("_provenance")
            entry["scalars"] = {
                k: v for k, v in payload.items()
                if k != "_provenance"
                and isinstance(v, (int, float, str, bool))}
            entry["keys"] = sorted(k for k in payload
                                   if k != "_provenance")
        else:
            entry["keys"] = [f"list[{len(payload)}]"]
        summary[fname[:-len(".json")]] = entry
    out = {"generated": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
           "n_benchmarks": len(summary), "benchmarks": summary}
    with open(os.path.join(outdir, "BENCH_SUMMARY.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def emit(name: str, wall_s: float, derived: str):
    """The harness CSV contract: name,us_per_call,derived."""
    print(f"{name},{wall_s*1e6:.0f},{derived}")
