"""Chunked-prefill scheduling (token-budgeted prefill/decode interleave).

Entry points:

  * ``ChunkScheduler`` — the host-side packer: per-iteration token
    budget filled with decode tokens first, then whole prefill chunks
    in the policy's uncertainty-priority order (FIFO tie-break).  Pure
    Python, JAX-free, and shared VERBATIM by the real serving engine
    (``ServingEngine(prefill="chunked")``) and the simulator
    (``simulate_continuous(prefill="chunked")``) — which is what makes
    their per-iteration budget traces comparable bit for bit.
  * ``ChunkJob`` / ``ChunkPlan`` — one admitted prompt's remaining
    work, and one scheduled chunk (start offset, length, finishes).
    With the prefix cache on, a job covers only the UNCACHED suffix of
    the prompt; the engine shifts plan offsets by the cached-prefix
    length.
  * ``pack_plans`` / ``ChunkBatch`` / ``PackedChunk`` — one
    iteration's plans merged (adjacent same-job plans fuse into one
    contiguous ragged chunk) and padded to power-of-two shape buckets
    for the FUSED ragged prefill executable: one launch per iteration,
    ``shape_key`` as the traced-executable memo key.  Shared by engine
    and simulator so dispatch counts and executable-cache hit/miss
    counters parity-match.

Invariants (property-tested in tests/test_properties.py): scheduled
chunk tokens never exceed ``max(0, token_budget - decode_tokens)``;
each job's chunks cover ``[0, total)`` in order exactly once; whenever
jobs pend and a whole chunk fits, at least one chunk is scheduled (no
starvation — FIFO ties drain in admission order).

Kernel dispatch: the chunked engine executes ALL of an iteration's
scheduled chunks in ONE launch — ``pack_plans`` builds the packed
batch, ``model.prefill_chunks`` →
``transformer.prefill_chunks_paged_batched`` runs it through the
stack, and each attention layer either calls the fused Pallas
``kernels/ragged_chunked_prefill.py`` kernel (per-chunk
``[slot, ctx_len, chunk_len, q_offset]`` scalar-prefetch metadata,
block-table indirection, K/V scatter fused in via aliased page
outputs) under ``use_pallas``, or the exact jnp path (drop-mode packed
scatter ``kvcache.paged.scatter_packed`` + per-chunk
``layers.chunked_attention`` over the gathered view) elsewhere.
Prefix-cached STALL admission routes its uncached suffix through the
SAME fused executable as a single-chunk launch (``suffix_shape_key``),
so a prefix hit pays one fused dispatch, not the per-chunk path.  All
paths are bit-identical to the stall prefill, so chunking never
changes greedy output.
"""

from .scheduler import (ChunkBatch, ChunkJob, ChunkPlan, ChunkScheduler,
                        PackedChunk, build_packed_arrays, pack_plans,
                        pow2_bucket, suffix_shape_key)

__all__ = ["ChunkBatch", "ChunkJob", "ChunkPlan", "ChunkScheduler",
           "PackedChunk", "build_packed_arrays", "pack_plans",
           "pow2_bucket", "suffix_shape_key"]
