"""Serving-engine integration: real JAX execution under the scheduler,
cross-checked against the discrete-event simulator's structure."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import datagen, personas, scheduler as sched, workload
from repro.models import model as model_lib
from repro.serving.engine import Request, ServingEngine, hash_tokenize


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 160, seed=0)
    train, test = datagen.train_test_split(corpus, train_frac=0.5)
    persona = personas.get_persona("bart")
    profile = sched.offline_profile(train, persona, epochs=15)
    arrivals = workload.poisson_trace(len(test), betas=[200, 400], seed=1)
    reqs = [Request(text=t.text, arrival=a, task_id=i)
            for i, (t, a) in enumerate(zip(test, arrivals))]
    return cfg, params, persona, profile, reqs


def test_hash_tokenize_deterministic():
    a = hash_tokenize("hello world", 1000, 16)
    b = hash_tokenize("hello world", 1000, 16)
    assert a == b
    assert all(2 <= t < 1000 for t in a)


@pytest.mark.parametrize("policy_name", ["fifo", "rt-lm"])
def test_engine_serves_all_requests(setup, policy_name):
    cfg, params, persona, profile, reqs = setup
    policy = sched.POLICIES[policy_name](persona, profile.policy_config())
    engine = ServingEngine(params, cfg, policy, profile,
                           input_bucket=16, max_new_tokens=8)
    res = engine.serve([Request(r.text, r.arrival, r.task_id)
                        for r in reqs])
    assert res["n_tasks"] == len(reqs)
    assert res["mean_response_s"] > 0
    assert np.isfinite(res["max_response_s"])
    # every request actually decoded something on the real engine
    assert all(t.task.out_len >= 1 for t in res["tasks"])
    # scheduler overhead is small relative to execution (paper Table VII)
    assert res["scheduler_overhead_s"] < 0.2 * res["max_response_s"] * \
        res["n_tasks"]


def test_engine_rtlm_offloads_only_high_u(setup):
    cfg, params, persona, profile, reqs = setup
    policy = sched.POLICIES["rt-lm"](persona, profile.policy_config())
    engine = ServingEngine(params, cfg, policy, profile,
                           input_bucket=16, max_new_tokens=8)
    res = engine.serve([Request(r.text, r.arrival, r.task_id)
                        for r in reqs])
    lanes = {}
    for t in res["tasks"]:
        lanes.setdefault(t.lane, []).append(t.u)
    if "cpu" in lanes:
        assert min(lanes["cpu"]) >= profile.tau - 1e-6
