"""Quickstart: the whole RT-LM ecosystem in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. quantify uncertainty of a few inputs with RULEGEN,
2. train the lightweight predictor m_theta on a synthetic corpus,
3. schedule a Poisson burst of requests with UASCHED vs FIFO,
4. compare response times.
"""

import numpy as np

from repro.core import (datagen, personas, rulegen, scheduler, simulator,
                        workload)

# --- 1. RULEGEN on the paper's Table I examples ---------------------------
for text in [
    "John saw a boy in the park with a telescope.",
    "Tell me about the history of art.",
    "How do cats and dogs differ in behavior, diet, and social interaction?",
    "I had pasta for dinner yesterday.",
]:
    scores = rulegen.rulegen(text)
    print(f"u={dict(zip(rulegen.UNCERTAINTY_TYPES, scores.round(1)))}"
          f"  <- {text!r}")

# --- 2. offline profiling (Alg. 1 lines 2-9) -------------------------------
persona = personas.get_persona("dialogpt")
corpus = datagen.generate_corpus(datagen.VARIANCE_MIXES["large"], 2000,
                                 seed=0)
train, test = datagen.train_test_split(corpus, train_frac=0.4)
print(f"\ntraining m_theta on {len(train)} tasks ...")
profile = scheduler.offline_profile(train, persona, epochs=40)
pred = profile.predictor.score_batch([t.text for t in test])
true = np.array([t.out_lens[persona.name] for t in test])
print(f"predictor corr(u, true output length) = "
      f"{np.corrcoef(pred, true)[0, 1]:.3f}; tau = {profile.tau:.1f}")

# --- 3+4. online scheduling under a bursty Poisson trace -------------------
arrivals = workload.poisson_trace(len(test),
                                  betas=list(range(40, 281, 40)), seed=1)
tasks = scheduler.make_sim_tasks(test, profile, persona, arrivals)
print(f"\nserving {len(tasks)} requests "
      f"(beta ramps 40->280 q/min):")
for policy in ("fifo", "rt-lm"):
    res = simulator.run_policy(tasks, policy, persona,
                               profile.policy_config())
    s = res.summary()
    print(f"  {policy:6s} mean={s['mean_response_s']:.2f}s "
          f"max={s['max_response_s']:.2f}s "
          f"throughput={s['throughput_per_min']:.1f}/min")
