"""Optimizers, loss descent on the synthetic pipeline, checkpoints."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as model_lib
from repro.training import (checkpoint as ckpt, data as data_lib,
                            optimizer as opt_lib, train_step as ts_lib)


def quad_loss(p):
    return 0.5 * jnp.sum(jnp.square(p["w"] - 3.0)) + \
        0.5 * jnp.sum(jnp.square(p["b"] + 1.0))


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges_quadratic(name):
    opt = opt_lib.make_optimizer(name, 0.1)
    params = {"w": jnp.zeros((4, 256)), "b": jnp.zeros((8,))}
    state = opt.init(params)
    for _ in range(300):
        grads = jax.grad(quad_loss)(params)
        updates, state = opt.update(grads, state, params)
        params = opt_lib.apply_updates(params, updates)
    assert float(quad_loss(params)) < 1e-2


def test_adafactor_memory_is_factored():
    opt = opt_lib.adafactor()
    p = {"big": jnp.zeros((512, 1024)), "vec": jnp.zeros((300,)),
         "stacked_norm": jnp.zeros((56, 6144))}
    st = opt.init(p)
    assert set(st["stats"]["big"]) == {"r", "c"}
    assert st["stats"]["big"]["r"].shape == (512,)
    assert st["stats"]["big"]["c"].shape == (1024,)
    assert set(st["stats"]["vec"]) == {"v"}
    # (L, D) stacked norms must NOT factor across the layer axis
    assert set(st["stats"]["stacked_norm"]) == {"v"}


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0 * np.sqrt(10), rel=1e-5)
    assert float(opt_lib.global_norm(clipped)) == pytest.approx(1.0,
                                                                rel=1e-4)


def test_loss_decreases_tiny_model(rng_key):
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(rng_key, cfg)
    opt = opt_lib.make_optimizer("adamw", 3e-3)
    step = jax.jit(ts_lib.make_train_step(cfg, opt, remat=False),
                   donate_argnums=(0, 1))
    state = opt.init(params)
    pipe = data_lib.SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=64,
                                    batch_size=8, seed=0)
    losses = []
    for batch in pipe.batches(30):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
    assert not any(np.isnan(l) for l in losses)


def test_synthetic_data_is_learnable_structure():
    pipe = data_lib.SyntheticLMData(vocab_size=128, seq_len=256,
                                    batch_size=4, seed=0)
    b1 = next(iter(pipe.batches(1)))
    assert b1["tokens"].shape == (4, 256)
    # labels are the shifted tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_checkpoint_roundtrip(tmp_path, rng_key):
    cfg = configs.get_smoke_config("mamba2-1.3b")
    params = model_lib.init_params(rng_key, cfg)
    path = os.path.join(tmp_path, "ckpt")
    ckpt.save(path, {"params": params}, step=17)
    restored, step = ckpt.restore(path, {"params": params})
    assert step == 17
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)),
        params, restored["params"])


def test_checkpoint_structure_mismatch_raises(tmp_path, rng_key):
    path = os.path.join(tmp_path, "ckpt")
    ckpt.save(path, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError, match="mismatch"):
        ckpt.restore(path, {"b": jnp.zeros(3)})
