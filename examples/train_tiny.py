"""Train a ~100M-param model for a few hundred steps on the synthetic
pipeline (end-to-end training driver, deliverable b).

    PYTHONPATH=src python examples/train_tiny.py [--steps 300]

Uses a scaled-up smoke variant of yi-6b (~100M params) and AdamW; loss
should fall well below the unigram entropy of the synthetic stream.
"""

import argparse
import dataclasses
import time

import jax

from repro import configs
from repro.models import model as model_lib
from repro.training import data as data_lib, optimizer as opt_lib
from repro.training import train_step as ts_lib

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
args = ap.parse_args()

base = configs.get_smoke_config("yi-6b")
cfg = dataclasses.replace(
    base, name="yi-100m", num_layers=8, d_model=768, num_heads=12,
    num_kv_heads=4, head_dim=64, d_ff=2304, vocab_size=49152)
params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
n = sum(x.size for x in jax.tree.leaves(params))
print(f"model: {cfg.name}  params={n/1e6:.1f}M")

opt = opt_lib.make_optimizer("adamw", 1e-3)
step = jax.jit(ts_lib.make_train_step(cfg, opt, remat=False),
               donate_argnums=(0, 1))
state = opt.init(params)
pipe = data_lib.SyntheticLMData(vocab_size=cfg.vocab_size,
                                seq_len=args.seq, batch_size=args.batch,
                                seed=0)
t0 = time.time()
for i, batch in enumerate(pipe.batches(args.steps)):
    params, state, m = step(params, state, batch)
    if i % 20 == 0 or i == args.steps - 1:
        print(f"step {i:4d}  loss={float(m['loss']):.4f}  "
              f"grad_norm={float(m['grad_norm']):.3f}  "
              f"({(time.time()-t0)/(i+1):.2f}s/step)")
