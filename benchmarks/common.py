"""Shared benchmark scaffolding: corpora, profiles, trace construction.

One benchmark module per paper table/figure (see run.py); they all share
this cache so the five per-persona predictors are trained once per
variance subset.
"""

from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, List

import numpy as np

from repro.core import (datagen, personas, scheduler as sched, simulator,
                        workload)

OUTDIR = os.environ.get("RTLM_BENCH_OUT", "experiments/bench")

# workload calibration (DESIGN.md §6): the paper's beta ramp is 10..150
# q/min against an RTX A4500 — per-LM batch sizes C_f differ 3x, so a
# single ramp saturates DialoGPT (C=11) while leaving T5 (C=33) idle and
# policy-insensitive.  We preserve the ramp SHAPE (14 linear steps, one
# simulated minute each) but scale its peak per persona to the same
# 2x-capacity sustained-overload regime the paper's tables were
# measured in (their Figs. 9-12 show multi-second queueing delays, i.e.
# saturated peaks).
N_RAMP_STEPS = 14
PEAK_UTILIZATION = 2.0
N_TASKS = 2800
TRAIN_FRAC = 0.3
EPOCHS = 60
SEED = 0


def persona_betas(persona_name: str, variance: str,
                  malicious_pct: int = 0,
                  platform: str = "edge_server") -> list:
    import numpy as _np
    persona = personas.on_platform(
        personas.get_persona(persona_name), platform)
    train, _ = corpus(variance, malicious_pct)
    lens = _np.array([t.out_lens[persona_name] for t in train])
    # batched decode runs to ~the long tail of its batch
    t_batch = (persona.setup_time + persona.eta * _np.quantile(lens, 0.9)
               + persona.item_time * persona.batch_size)
    peak = 60.0 * persona.batch_size / t_batch * PEAK_UTILIZATION
    return [max(5, int(peak * i / N_RAMP_STEPS))
            for i in range(1, N_RAMP_STEPS + 1)]

POLICIES = ("fifo", "hpf", "luf", "muf", "rt-lm")
ABLATION = ("fifo", "hpf", "slack-eq2", "up", "up+c", "rt-lm")
VARIANCES = ("small", "normal", "large")


@functools.lru_cache(maxsize=None)
def corpus(variance: str, malicious_pct: int = 0, seed: int = SEED):
    tasks = datagen.generate_corpus(
        datagen.VARIANCE_MIXES[variance], N_TASKS, seed=seed,
        malicious_frac=malicious_pct / 100.0)
    return datagen.train_test_split(tasks, train_frac=TRAIN_FRAC,
                                    seed=seed)


@functools.lru_cache(maxsize=None)
def profile(variance: str, persona_name: str, malicious_pct: int = 0,
            seed: int = SEED, tail_quantile=None):
    train, _ = corpus(variance, malicious_pct, seed)
    persona = personas.get_persona(persona_name)
    t0 = time.time()
    prof = sched.offline_profile(train, persona, epochs=EPOCHS, seed=seed,
                                 tail_quantile=tail_quantile)
    prof.train_wall_s = time.time() - t0
    return prof


def sim_tasks(variance: str, persona_name: str, malicious_pct: int = 0,
              seed: int = SEED, platform: str = "edge_server",
              tail_quantile=None):
    _, test = corpus(variance, malicious_pct, seed)
    prof = profile(variance, persona_name, malicious_pct, seed,
                   tail_quantile)
    persona = personas.on_platform(
        personas.get_persona(persona_name), platform)
    betas = persona_betas(persona_name, variance, malicious_pct, platform)
    arrivals = workload.poisson_trace(len(test), betas=betas,
                                      seed=seed + 1)
    return sched.make_sim_tasks(test, prof, persona, arrivals), prof


def run(variance: str, persona_name: str, policy: str, *,
        malicious_pct: int = 0, alpha: float = 1.0, lam: float = 1.5,
        b: float = 1.8, seed: int = SEED, platform: str = "edge_server",
        tail_quantile=None) -> simulator.SimResult:
    tasks, prof = sim_tasks(variance, persona_name, malicious_pct, seed,
                            platform, tail_quantile)
    persona = personas.on_platform(
        personas.get_persona(persona_name), platform)
    pcfg = prof.policy_config(alpha=alpha, lam=lam, b=b)
    return simulator.run_policy(tasks, policy, persona, pcfg)


def save(name: str, payload) -> str:
    os.makedirs(OUTDIR, exist_ok=True)
    path = os.path.join(OUTDIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def emit(name: str, wall_s: float, derived: str):
    """The harness CSV contract: name,us_per_call,derived."""
    print(f"{name},{wall_s*1e6:.0f},{derived}")
