"""Dry-run machinery: input specs per shape/family, and two real
512-placeholder-device lower+compile runs in subprocesses (the module
sets XLA_FLAGS before importing jax, so it must own the process)."""

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import specs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(configs.INPUT_SHAPES))
def test_input_specs_shapes(arch, shape_name):
    cfg = configs.get_config(arch)
    shape = configs.INPUT_SHAPES[shape_name]
    ok, _ = configs.shape_applicable(cfg, shape)
    if not ok:
        pytest.skip("documented long_500k skip")
    args, _ = specs.input_specs(cfg, shape)
    if shape.kind == "train":
        params, opt_state, batch = args
        assert batch["tokens"].shape[0] == shape.global_batch
        total = batch["tokens"].shape[1] + (
            cfg.num_patch_tokens if cfg.frontend == "vision" else 0)
        assert total == shape.seq_len
        assert batch["labels"].dtype == jnp.int32
        if cfg.family == "encdec":
            assert batch["frames"].shape == (
                shape.global_batch, cfg.encoder_seq_len, cfg.d_model)
    elif shape.kind == "prefill":
        params, batch = args
        assert batch["tokens"].shape[0] == shape.global_batch
    else:
        params, cache, token = args
        assert token.shape == (shape.global_batch, 1)
        # decode cache state is bounded for subquadratic archs
        if cfg.family in ("ssm",):
            assert "scan0" in cache

    # no leaf is a concrete array (ShapeDtypeStructs only)
    import jax
    for leaf in jax.tree.leaves(args):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("arch,shape", [
    ("h2o-danube-3-4b", "long_500k"),
    ("mamba2-1.3b", "decode_32k"),
])
def test_dryrun_subprocess_512dev(arch, shape, tmp_path):
    """Real production-mesh lower+compile in a fresh process."""
    out = os.path.join(tmp_path, "res.json")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--out", out],
        capture_output=True, text=True, timeout=540, env=env, cwd=REPO)
    assert r.returncode == 0, r.stderr[-2000:]
    res = json.load(open(out))
    assert res["status"] == "ok"
    assert res["mesh"]["shape"] == [16, 16]
    assert res["roofline"]["flops_per_dev"] > 0
    assert res["memory"]["resident_bytes_per_device"] > 0


def test_long500k_skips_quadratic_archs():
    shape = configs.INPUT_SHAPES["long_500k"]
    cfg = configs.get_config("yi-6b")
    ok, reason = configs.shape_applicable(cfg, shape)
    assert not ok and "quadratic" in reason
