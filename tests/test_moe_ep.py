"""Expert-parallel MoE (shard_map) vs the single-device oracle.

Needs a multi-device mesh, so it runs in a subprocess with 8 placeholder
CPU devices (the main pytest process keeps its single real device).
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
from repro import configs
from repro.launch import mesh as mesh_lib
from repro.sharding import context as shctx, policy as policy_lib
from repro.models import moe

mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)

for arch in ("kimi-k2-1t-a32b", "mixtral-8x22b"):
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              capacity_factor=8.0)
    params = moe.init_moe(key, cfg, jnp.float32)
    for B, S in ((4, 8), (1, 1)):
        x = jax.random.normal(jax.random.PRNGKey(B), (B, S, cfg.d_model))
        want, aux_want = moe.apply_moe_local(params, x, cfg)
        for serving in (False, True):
            policy = policy_lib.make_policy(mesh)
            policy.serving = serving
            with mesh, shctx.use_policy(policy):
                got, aux = jax.jit(
                    lambda p, x: moe.apply_moe(p, x, cfg))(params, x)
            err = float(jnp.abs(got - want).max())
            assert err < 2e-3, (arch, B, S, serving, err)
            da = abs(float(aux["moe_aux_loss"])
                     - float(aux_want["moe_aux_loss"]))
            assert da < 1e-4, (arch, B, S, serving, da)
print("EP_OK")
"""


@pytest.mark.parametrize("rep", [0])
def test_moe_ep_matches_oracle(rep, tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, timeout=480,
                       env=env, cwd=REPO)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "EP_OK" in r.stdout
