"""Windowed SLO monitor: per-traffic-class sliding-window latency
percentiles and attainment fractions.

``WindowedHistogram`` is a ring of epoch-bucketed ``Histogram``s
(``obs.metrics``): each sample lands in the histogram for its clock
epoch ``int(ts // window_s)``, and rotation is just dropping epochs
older than ``num_windows`` — O(buckets) thanks to the associative
``Histogram.merge``.  Expired epochs are folded into a lifetime
archive, so ``lifetime()`` always equals a histogram fed every sample
directly (tests/test_slo.py pins the bit-equality).  Rotation is
driven by the caller's virtual clock (``advance``/``record`` take
``ts``), so the engine and the simulator rotate on their own clocks —
window CONTENTS are wall-dependent by nature and excluded from the
parity view, while the attainment COUNTS under judgment-invariant
targets (``inf`` always attains, ``-1.0`` never — latencies are >= 0,
and 0.0 is a reachable boundary) are deterministic and parity-tested.

``SLOMonitor`` owns one ``WindowedHistogram`` + one attainment count
ring per (traffic class, metric) for the four latency metrics
``ttft``/``itl``/``e2e``/``queue_wait``, judged against per-class
``SLOSpec`` targets declared in the workload spec
(``repro.core.workload.TrafficClass``).  Unknown or empty class names
resolve to ``default_class`` so classless traffic is still monitored.

This module is imported by ``repro.core.workload`` (``SLOSpec`` is the
declaration type) — it must stay free of ``repro.core`` imports.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from .metrics import Histogram

#: the latency metrics the monitor windows, in reporting order
SLO_METRICS = ("ttft", "itl", "e2e", "queue_wait")


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Per-class latency targets in seconds (``inf`` = unconstrained).

    ``ttft_s``/``itl_s`` are the paper-facing pair (RT-LM §V judges
    responsiveness on first-token and inter-token latency); ``e2e_s``
    and ``queue_wait_s`` round out the serving-side view.
    """

    ttft_s: float = math.inf
    itl_s: float = math.inf
    e2e_s: float = math.inf
    queue_wait_s: float = math.inf

    def target(self, metric: str) -> float:
        try:
            return getattr(self, metric + "_s")
        except AttributeError:
            raise KeyError(f"unknown SLO metric {metric!r}; "
                           f"expected one of {SLO_METRICS}") from None

    def to_json(self) -> Dict[str, float]:
        """Finite targets only — the trace-meta serialization."""
        return {m + "_s": self.target(m) for m in SLO_METRICS
                if math.isfinite(self.target(m))}

    @classmethod
    def from_json(cls, obj: Dict[str, float]) -> "SLOSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: float(v) for k, v in obj.items() if k in known})


class WindowedHistogram:
    """Sliding-window histogram: a ring of per-epoch ``Histogram``s.

    ``record(ts, v)`` lands ``v`` in the epoch ``int(ts // window_s)``;
    ``advance(ts)`` folds epochs older than ``num_windows`` into the
    ``expired`` lifetime archive.  ``merged()`` is the live-window
    view, ``lifetime()`` the archive plus live windows — bit-equal to
    one histogram fed all samples, because ``Histogram.merge`` is
    associative.
    """

    __slots__ = ("window_s", "num_windows", "growth", "windows",
                 "expired", "_latest")

    def __init__(self, window_s: float = 60.0, num_windows: int = 5,
                 growth: float = Histogram.GROWTH) -> None:
        if window_s <= 0.0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        if num_windows < 1:
            raise ValueError(f"num_windows must be >= 1, got {num_windows}")
        self.window_s = float(window_s)
        self.num_windows = int(num_windows)
        self.growth = float(growth)
        self.windows: Dict[int, Histogram] = {}
        self.expired = Histogram(growth)
        self._latest: Optional[int] = None

    def _epoch(self, ts: float) -> int:
        return int(ts // self.window_s)

    def advance(self, ts: float) -> None:
        """Rotate to the epoch containing ``ts`` (monotone in ``ts``)."""
        epoch = self._epoch(ts)
        if self._latest is not None and epoch <= self._latest:
            return
        self._latest = epoch
        floor_epoch = epoch - self.num_windows + 1
        for k in [k for k in self.windows if k < floor_epoch]:
            self.expired.merge(self.windows.pop(k))

    def record(self, ts: float, v: float, n: int = 1) -> None:
        self.advance(ts)
        epoch = self._epoch(ts)
        h = self.windows.get(epoch)
        if h is None:
            h = self.windows[epoch] = Histogram(self.growth)
        h.record(v, n)

    # ------------------------------------------------------------------
    def merged(self) -> Histogram:
        """Fresh merge of the live (non-expired) windows."""
        h = Histogram(self.growth)
        for k in sorted(self.windows):
            h.merge(self.windows[k])
        return h

    def lifetime(self) -> Histogram:
        """Archive + live windows == one histogram fed every sample."""
        h = Histogram(self.growth)
        h.merge(self.expired)
        for k in sorted(self.windows):
            h.merge(self.windows[k])
        return h

    @property
    def count(self) -> int:
        return self.expired.count + sum(h.count
                                        for h in self.windows.values())

    def quantile(self, q: float) -> float:
        return self.merged().quantile(q)

    def snapshot(self) -> Dict:
        return {"windowed": self.merged().snapshot(),
                "lifetime": self.lifetime().snapshot()}


class _WindowCounts:
    """Ring of per-epoch ``[ok, total]`` attainment counts plus
    lifetime cumulative integers (the deterministic parity view)."""

    __slots__ = ("window_s", "num_windows", "windows", "ok", "total",
                 "_latest")

    def __init__(self, window_s: float = 60.0,
                 num_windows: int = 5) -> None:
        self.window_s = float(window_s)
        self.num_windows = int(num_windows)
        self.windows: Dict[int, List[int]] = {}
        self.ok = 0
        self.total = 0
        self._latest: Optional[int] = None

    def _epoch(self, ts: float) -> int:
        return int(ts // self.window_s)

    def advance(self, ts: float) -> None:
        epoch = self._epoch(ts)
        if self._latest is not None and epoch <= self._latest:
            return
        self._latest = epoch
        floor_epoch = epoch - self.num_windows + 1
        for k in [k for k in self.windows if k < floor_epoch]:
            del self.windows[k]

    def record(self, ts: float, ok: bool, n: int = 1) -> None:
        self.advance(ts)
        cell = self.windows.setdefault(self._epoch(ts), [0, 0])
        if ok:
            cell[0] += n
            self.ok += n
        cell[1] += n
        self.total += n

    def windowed(self) -> Tuple[int, int]:
        ok = sum(c[0] for c in self.windows.values())
        total = sum(c[1] for c in self.windows.values())
        return ok, total


def _frac(ok: int, total: int) -> float:
    """Attainment fraction; an idle window (no observations) counts as
    fully attained rather than NaN — the satellite-1 guard."""
    return ok / total if total else 1.0


class SLOMonitor:
    """Per-traffic-class windowed latency + SLO attainment tracker.

    One ``WindowedHistogram`` and one ``_WindowCounts`` per
    (class, metric); observations are judged ``value <= target`` at
    record time against the class's ``SLOSpec``, so attainment needs no
    retained samples.  ``parity_counters()`` exposes the cumulative
    integer counts — bit-for-bit engine-vs-sim comparable whenever the
    targets make the judgement deterministic (``inf``/``-1.0``).
    """

    def __init__(self, classes: Optional[Dict[str, SLOSpec]] = None, *,
                 window_s: float = 60.0, num_windows: int = 5,
                 default_class: str = "default",
                 growth: float = Histogram.GROWTH) -> None:
        self.classes: Dict[str, SLOSpec] = dict(classes or {})
        self.window_s = float(window_s)
        self.num_windows = int(num_windows)
        self.default_class = default_class
        self.growth = float(growth)
        self._hists: Dict[Tuple[str, str], WindowedHistogram] = {}
        self._counts: Dict[Tuple[str, str], _WindowCounts] = {}
        self.completions: Dict[str, int] = {}
        # per-replica split (PR 9, multi-replica serving): cumulative
        # [ok, total] per (replica, class, metric) and completions per
        # (replica, class) — populated only when observations carry a
        # replica label, so single-replica runs are unchanged
        self._r_counts: Dict[Tuple[int, str, str], List[int]] = {}
        self.r_completions: Dict[Tuple[int, str], int] = {}

    # ------------------------------------------------------------------
    def resolve(self, cls: str) -> str:
        """Map empty/unknown class names onto a registered class."""
        if cls and cls in self.classes:
            return cls
        if self.default_class not in self.classes:
            self.classes[self.default_class] = SLOSpec()
        return self.default_class

    def _hist(self, cls: str, metric: str) -> WindowedHistogram:
        key = (cls, metric)
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = WindowedHistogram(
                self.window_s, self.num_windows, self.growth)
        return h

    def _count(self, cls: str, metric: str) -> _WindowCounts:
        key = (cls, metric)
        c = self._counts.get(key)
        if c is None:
            c = self._counts[key] = _WindowCounts(self.window_s,
                                                  self.num_windows)
        return c

    # ------------------------------------------------------------------
    def observe(self, metric: str, cls: str, ts: float, value: float,
                n: int = 1, *, replica: Optional[int] = None) -> None:
        """Record ``n`` observations of ``value`` for (class, metric)
        at clock time ``ts`` and judge them against the class target.
        ``replica`` additionally lands the judgement in the per-replica
        cumulative split (multi-replica serving)."""
        if metric not in SLO_METRICS:
            raise KeyError(f"unknown SLO metric {metric!r}; "
                           f"expected one of {SLO_METRICS}")
        cls = self.resolve(cls)
        target = self.classes[cls].target(metric)
        self._hist(cls, metric).record(ts, value, n)
        self._count(cls, metric).record(ts, value <= target, n)
        if replica is not None:
            cell = self._r_counts.setdefault((replica, cls, metric),
                                             [0, 0])
            if value <= target:
                cell[0] += n
            cell[1] += n

    def complete(self, cls: str, *,
                 replica: Optional[int] = None) -> str:
        """Count a completion; returns the resolved class name."""
        cls = self.resolve(cls)
        self.completions[cls] = self.completions.get(cls, 0) + 1
        if replica is not None:
            key = (replica, cls)
            self.r_completions[key] = self.r_completions.get(key, 0) + 1
        return cls

    # ------------------------------------------------------------------
    def attainment(self) -> Dict[str, Dict]:
        """Cumulative per-class attainment + latency percentiles."""
        out: Dict[str, Dict] = {}
        for cls in sorted(self.classes):
            spec = self.classes[cls]
            row: Dict = {"completions": self.completions.get(cls, 0)}
            for m in SLO_METRICS:
                c = self._counts.get((cls, m))
                ok, total = (c.ok, c.total) if c is not None else (0, 0)
                h = self._hists.get((cls, m))
                row[m] = {"target_s": spec.target(m), "ok": ok,
                          "total": total, "frac": _frac(ok, total)}
                if h is not None:
                    row[m]["lifetime"] = h.lifetime().snapshot()
            out[cls] = row
        return out

    def windowed_attainment(self) -> Dict[str, Dict[str, float]]:
        """Live-window attainment fractions — the snapshot-event /
        ``health()`` view (idle windows report 1.0, never NaN)."""
        out: Dict[str, Dict[str, float]] = {}
        for cls in sorted(self.classes):
            row: Dict[str, float] = {}
            for m in SLO_METRICS:
                c = self._counts.get((cls, m))
                ok, total = c.windowed() if c is not None else (0, 0)
                row[m] = _frac(ok, total)
            out[cls] = row
        return out

    def parity_counters(self) -> Dict[str, int]:
        """Flat deterministic integer counters (engine-vs-sim view);
        per-replica splits appear as ``slo.r{N}.…`` keys when replica
        labels were recorded."""
        out: Dict[str, int] = {}
        for (cls, m) in sorted(self._counts):
            c = self._counts[(cls, m)]
            out[f"slo.{cls}.{m}.ok"] = c.ok
            out[f"slo.{cls}.{m}.total"] = c.total
        for cls in sorted(self.completions):
            out[f"slo.{cls}.completions"] = self.completions[cls]
        for (r, cls, m) in sorted(self._r_counts):
            ok, total = self._r_counts[(r, cls, m)]
            out[f"slo.r{r}.{cls}.{m}.ok"] = ok
            out[f"slo.r{r}.{cls}.{m}.total"] = total
        for (r, cls) in sorted(self.r_completions):
            out[f"slo.r{r}.{cls}.completions"] = \
                self.r_completions[(r, cls)]
        return out

    def replica_attainment(self) -> Dict[int, Dict[str, Dict]]:
        """Cumulative attainment fractions split by replica label —
        {} unless observations carried replica labels (R > 1 serving).
        Kept separate from ``attainment()`` (whose keys are class
        names) so existing consumers see no new keys."""
        out: Dict[int, Dict[str, Dict]] = {}
        for (r, cls, m) in sorted(self._r_counts):
            ok, total = self._r_counts[(r, cls, m)]
            row = out.setdefault(r, {}).setdefault(cls, {})
            row[m] = {"ok": ok, "total": total,
                      "frac": _frac(ok, total)}
        for (r, cls) in sorted(self.r_completions):
            out.setdefault(r, {}).setdefault(cls, {})["completions"] = \
                self.r_completions[(r, cls)]
        return out

    def lifetime_quantile(self, cls: str, metric: str,
                          q: float) -> float:
        """Lifetime (archive + live) quantile for (class, metric) —
        0.0 when nothing was observed."""
        h = self._hists.get((self.resolve(cls), metric))
        return h.lifetime().quantile(q) if h is not None else 0.0

    def targets_json(self) -> Dict[str, Dict[str, float]]:
        return {cls: spec.to_json()
                for cls, spec in sorted(self.classes.items())}
