from . import engine, generate, replica, router  # noqa: F401
