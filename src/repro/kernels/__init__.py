"""Pallas TPU kernels for the serving substrate's compute hot spots.

RT-LM itself is a scheduling layer (no kernel-level contribution), but
the LM substrate it manages has three hot spots that a production TPU
deployment tiles by hand; each has a pl.pallas_call implementation with
explicit VMEM BlockSpecs, a jitted wrapper (ops.py) and a pure-jnp
oracle (ref.py):

  flash_attention          — FA2-style prefill attention (causal /
                             sliding window), online softmax in VMEM
  decode_attention         — flash-decode GQA attention over long KV
                             caches
  paged_decode_attention   — flash-decode over a block table (paged KV
                             cache; indirect page gather via
                             scalar-prefetch BlockSpec index_map)
  chunked_prefill_attention — one prompt chunk over a paged prefix
                             (block-table scalar prefetch, (T*G, D)
                             query tile)
  ragged_chunked_prefill   — EVERY scheduled prefill chunk of an
                             engine iteration in ONE launch: packed
                             ragged queries, per-chunk
                             [slot, ctx_len, chunk_len, q_offset]
                             scalar-prefetch metadata rows, and the
                             chunk K/V scatter fused in via aliased
                             page outputs
  rmsnorm                  — fused normalization (one HBM round-trip)

Validated in interpret mode on CPU (tests/test_kernels.py sweeps
shapes/dtypes against ref.py); compiled on TPU targets.
"""

from . import ops, ref  # noqa: F401
