"""Multi-replica serving: R independent engines behind the Router.

``ReplicatedEngine`` owns R ``ServingEngine`` instances — each with
its OWN KV pool, ``BlockAllocator``, ``PrefixCache`` and continuous
decode loop (nothing is shared but the model parameters, the policy
object and the observability bundle) — and a front-end
``repro.serving.router.Router`` that places every arriving request on
exactly one replica.

Placement protocol (the engine half of the parity discipline with
``repro.core.simulator.simulate_replicated``):

  1. requests are sorted by arrival (stable, as every serve loop does);
  2. for each request, the front-end computes the router inputs the
     simulator computes for its twin task — ``u`` from the offline
     profile's predictor (the engine's own ``_to_sim_task`` recipe) and
     ``need`` from the paged admission gate's reservation formula
     (``blocks_for_tokens(input_bucket + cap - 1, block_size)``);
  3. ``Router.place`` scores per-replica ``ReplicaView``s built from
     placement bookkeeping (placed counts, running ``u_load`` sums,
     pool capacities).  On all-at-t0 traces every placement precedes
     any engine work, so these views are bitwise identical to the
     simulator's live views and the decisions parity-match;
  4. a ``route`` event ``{replica, score, policy}`` fires per placement
     (R > 1 only — R=1 traces stay byte-identical to single-engine);
  5. each replica then serves its group with ``obs.replica_label`` set
     (R > 1 only), so every event/counter/SLO observation lands in that
     replica's parity substream
     (``TraceRecorder.parity_events(replica=r)``).

Device mapping is metadata, not magic: ``replica_devices()`` exposes
``repro.launch.mesh.replica_groups`` — contiguous data-parallel device
slices when the host has >= R devices, shared-device (thread-level)
replicas otherwise (the CPU case: R engine instances time-share one
host device, which is exactly what this in-process front-end models).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.kvcache import blocks_for_tokens
from repro.obs import Observability

from .engine import Request, ServingEngine
from .router import ReplicaView, Router


class ReplicatedEngine:
    """R independent ``ServingEngine`` replicas behind one ``Router``.

    ``engine_kwargs`` forward verbatim to every replica's
    ``ServingEngine`` constructor (equal pools — ``kv_num_blocks`` is
    PER replica, as in ``simulate_replicated``).
    """

    def __init__(self, params, cfg, policy, profile, *,
                 replicas: int = 1,
                 router: Optional[Router] = None,
                 faults=None,
                 obs: Optional[Observability] = None,
                 **engine_kwargs):
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.R = int(replicas)
        self.router = router if router is not None else Router(self.R)
        if self.router.R != self.R:
            raise ValueError(f"router expects R={self.router.R}, got "
                             f"replicas={self.R}")
        self.obs = obs
        self.profile = profile
        # failure-aware serving (serving.faults.FaultPlan): each
        # replica gets its per-replica fault slice; the pool-level
        # machinery (health-gated placement, retry/failover,
        # dead-letter) runs in _serve_faulted
        self.faults = faults
        if faults is not None:
            faults.validate(self.R)
        self.engines = [ServingEngine(params, cfg, policy, profile,
                                      obs=obs,
                                      faults=(None if faults is None
                                              else faults.for_replica(r)),
                                      **engine_kwargs)
                        for r in range(self.R)]
        self.placements: List[int] = []

    # ------------------------------------------------------------------
    def replica_devices(self) -> List[list]:
        """Device group per replica (``launch.mesh.replica_groups``)."""
        from repro.launch.mesh import replica_groups
        return replica_groups(self.R)

    def _need(self, req: Request) -> int:
        """The arrival's worst-case block reservation — the SAME
        formula the paged admission gate applies (0 when unpaged)."""
        eng = self.engines[0]
        if eng.kv != "paged":
            return 0
        return blocks_for_tokens(eng.input_bucket + eng._cap(req) - 1,
                                 eng.kv_block_size)

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence[Request]) -> Dict:
        """Place every request, then serve each replica's group.

        Returns a pool-level result dict wrapping the per-replica
        ``ServingEngine`` results (``None`` for a replica that received
        no requests — an idle replica runs nothing).
        """
        if self.faults is not None:
            return self._serve_faulted(requests)
        reqs = sorted(requests, key=lambda q: q.arrival)
        label = self.obs is not None and self.R > 1
        placed: List[List[Request]] = [[] for _ in range(self.R)]
        u_placed: List[List[float]] = [[] for _ in range(self.R)]
        placements: List[int] = []
        for req in reqs:
            # router inputs, computed exactly as the simulator twin
            # computes them for its SimTask
            u = float(max(self.profile.predictor.score(req.text), 0.0))
            need = self._need(req)
            views = [ReplicaView(
                replica=r,
                queued=len(placed[r]),
                active=0,
                free_blocks=(self.engines[r].kv_num_blocks
                             if self.engines[r].kv == "paged" else 0),
                num_blocks=(self.engines[r].kv_num_blocks
                            if self.engines[r].kv == "paged" else 0),
                u_load=float(sum(u_placed[r])),
                is_bulk=self.router.is_bulk(r))
                for r in range(self.R)]
            d = self.router.place(views, u=u, cls=req.traffic_class,
                                  need=need)
            placements.append(d.replica)
            if label:
                self.obs.event("route", req.arrival, req.task_id, None,
                               replica=d.replica, score=d.score,
                               policy=d.policy)
            placed[d.replica].append(req)
            u_placed[d.replica].append(u)
        self.placements = placements

        results: List[Optional[Dict]] = []
        for r in range(self.R):
            if not placed[r]:
                results.append(None)
                continue
            if label:
                self.obs.replica_label = r
            try:
                results.append(self.engines[r].serve(placed[r]))
            finally:
                if self.obs is not None:
                    self.obs.replica_label = None
        return {
            "mode": "replicated",
            "replicas": self.R,
            "router_policy": self.router.policy,
            "n_tasks": len(reqs),
            "placements": placements,
            "placement_counts": [len(g) for g in placed],
            "per_replica": results,
            "completion_orders": [
                res["completion_order"] if res is not None else []
                for res in results],
            "rejected_for_memory": sum(
                res["rejected_for_memory"] for res in results
                if res is not None),
            "fallback_events": sum(
                res["fallback_events"] for res in results
                if res is not None),
        }

    # ------------------------------------------------------------------
    def _serve_faulted(self, requests: Sequence[Request]) -> Dict:
        """Failure-aware pool serve: coordinator-gated placement, then
        ROUND-based serving — round k+1 serves the failover groups of
        the replicas that crashed in round k, with ``step_offset``
        continuing each target's step coordinate where its previous
        serve stopped — until no crash adds new work.  Crashes are
        one-shot per replica, so at most R+1 rounds run.  This drives
        the IDENTICAL ``FaultCoordinator`` call sequence as
        ``simulate_replicated(faults=...)``: placement gating, retry/
        backoff, failover and dead-letter decisions — and their events
        and counters — parity-match bit for bit.
        """
        from .faults import FaultCoordinator

        reqs = sorted(requests, key=lambda q: q.arrival)
        label = self.obs is not None and self.R > 1
        eng0 = self.engines[0]
        coord = FaultCoordinator(
            self.faults, self.R, self.router, self.obs,
            kv_num_blocks=(eng0.kv_num_blocks
                           if eng0.kv == "paged" else 0))
        req_u: Dict = {}
        placements: List[int] = []
        groups: List[List[Request]] = [[] for _ in range(self.R)]
        for req in reqs:
            u = float(max(self.profile.predictor.score(req.text), 0.0))
            req_u[req.task_id] = u
            # the coordinator's ledger views ARE this front-end's
            # placement bookkeeping (placed counts, u sums, full
            # pools); it emits the route event and dead-letters
            # (placement -1) when gating empties the eligible set
            tgt = coord.place(coord.ledger_views(), task_id=req.task_id,
                              u=u, cls=req.traffic_class,
                              arrival=req.arrival, need=self._need(req))
            placements.append(-1 if tgt is None else tgt)
            if tgt is not None:
                groups[tgt].append(req)
        self.placements = placements

        merged: List[List[Dict]] = [[] for _ in range(self.R)]
        step_offsets = [0] * self.R
        next_groups = groups
        while any(next_groups):
            cur, next_groups = next_groups, [[] for _ in range(self.R)]
            for r in range(self.R):
                if not cur[r]:
                    continue
                if coord.crashed[r] and not coord.functional(r):
                    # the target died in an earlier round before this
                    # failover group could run: the group re-enters the
                    # coordinator (attempt N+1) exactly as the
                    # simulator's crash survivors do — re-placed on a
                    # functional replica or dead-lettered
                    descs = [coord.survivor(
                        task_id=q.task_id, u=req_u[q.task_id],
                        cls=q.traffic_class, arrival=q.arrival,
                        need=self._need(q), payload=q)
                        for q in cur[r]]
                    for payload, tgt in coord.redispatch(
                            descs, from_replica=r):
                        next_groups[tgt].append(payload)
                    continue
                if label:
                    self.obs.replica_label = r
                try:
                    res = self.engines[r].serve(
                        cur[r], step_offset=step_offsets[r])
                finally:
                    if self.obs is not None:
                        self.obs.replica_label = None
                merged[r].append(res)
                step_offsets[r] = res["final_step"]
                if res["crashed"] and not coord.crashed[r]:
                    coord.note_crash(r)
                    survivors = list(self.engines[r].survivors)
                    descs = [coord.survivor(
                        task_id=q.task_id, u=req_u[q.task_id],
                        cls=q.traffic_class, arrival=q.arrival,
                        need=self._need(q), payload=q)
                        for q in survivors]
                    for payload, tgt in coord.redispatch(
                            descs, from_replica=r):
                        next_groups[tgt].append(payload)

        results = [self._merge_rounds(rl) for rl in merged]
        return {
            "mode": "replicated",
            "replicas": self.R,
            "router_policy": self.router.policy,
            "n_tasks": len(reqs),
            "placements": placements,
            "placement_counts": [placements.count(r)
                                 for r in range(self.R)],
            "per_replica": results,
            "completion_orders": [
                res["completion_order"] if res is not None else []
                for res in results],
            "rejected_for_memory": sum(
                res["rejected_for_memory"] for res in results
                if res is not None),
            "fallback_events": sum(
                res["fallback_events"] for res in results
                if res is not None),
            "timed_out": sum(res["timed_out"] for res in results
                             if res is not None),
            "shed": sum(res["shed"] for res in results
                        if res is not None),
            "retries": coord.retries,
            "failovers": coord.failovers,
            "dead_lettered": coord.dead_lettered,
            "failover_placements": list(coord.failover_placements),
        }

    @staticmethod
    def _merge_rounds(rounds: List[Dict]) -> Optional[Dict]:
        """Fold one replica's per-round serve results (its initial
        group plus any failover rounds) into a single result dict: the
        trailing round's engine-state fields, with the completion /
        terminal accounting concatenated in round order."""
        if not rounds:
            return None
        if len(rounds) == 1:
            return rounds[0]
        out = dict(rounds[-1])
        out["n_tasks"] = sum(res["n_tasks"] for res in rounds)
        out["tasks"] = [t for res in rounds for t in res["tasks"]]
        out["completion_order"] = [tid for res in rounds
                                   for tid in res["completion_order"]]
        for key in ("timed_out", "shed", "rejected_for_memory",
                    "fallback_events"):
            out[key] = sum(res[key] for res in rounds)
        for key in ("timed_out_ids", "shed_ids", "survivor_ids"):
            out[key] = [tid for res in rounds for tid in res[key]]
        return out
