"""Flash-decode: single-token GQA attention against a long KV cache.

The decode hot spot is memory-bound (the whole KV cache streams through
once per token), so the kernel's job is to keep the online-softmax state
in VMEM while the cache is read exactly once, in MXU-aligned blocks:

  grid = (B, KV, nk)  — innermost sequential over cache blocks;
  per step: q-group tile (G, D) x cache block (block_k, D) on the MXU,
  masked by a precomputed validity mask (ring-buffer slot positions are
  resolved to a boolean mask outside the kernel — cheap, (S,) bool);
  running (m, l, acc) scratch identical to the prefill kernel.

VMEM per step (defaults G<=8, block_k=512, D<=256, bf16):
  k,v (2x512x256x2) + q (8x256x2) + acc (8x256x4) ~= 540 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fd_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_scr, l_scr, acc_scr,
               *, scale: float):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (G, bk)
    valid = mask_ref[0]                           # (bk,) bool
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_decode_attention(q, k_cache, v_cache, mask, *,
                           block_k: int = 512, interpret: bool = False):
    """q: (B, H, D); caches: (B, S, KV, D); mask: (B, S) bool valid slots.

    Returns (B, H, D).
    """
    B, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / (D ** 0.5)
    block_k = min(block_k, max(S, 8))
    pk = (-S) % block_k
    if pk:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, pk)))
    nk = (S + pk) // block_k

    qt = q.reshape(B, KV, G, D)
    kt = k_cache.transpose(0, 2, 1, 3)           # (B, KV, S, D)
    vt = v_cache.transpose(0, 2, 1, 3)

    kernel = functools.partial(_fd_kernel, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, ki: (b, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, mask)
    return out.reshape(B, H, D)
