"""Attention/layer primitives vs naive oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models import layers


@pytest.mark.parametrize("B,Sq,Sk,H,KV,D,window", [
    (2, 17, 17, 4, 2, 16, None),
    (1, 64, 64, 4, 1, 32, None),
    (2, 33, 33, 6, 6, 8, 9),
    (1, 128, 128, 4, 2, 32, 16),
])
def test_chunked_attention_matches_ref(B, Sq, Sk, H, KV, D, window):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, KV, D), jnp.float32)
    pos = jnp.arange(Sq)
    out = layers.chunked_attention(q, k, v, q_positions=pos,
                                   kv_positions=pos, causal=True,
                                   window=window, q_chunk=16, kv_chunk=16)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_windowed_attention_matches_chunked():
    key = jax.random.PRNGKey(2)
    B, S, H, KV, D, W = 2, 96, 4, 2, 16, 24
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    pos = jnp.arange(S)
    got = layers.windowed_attention(q, k, v, q_positions=pos,
                                    kv_positions=pos, window=W, q_chunk=32)
    want = ref.attention_ref(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_decode_attention_ring_cache_semantics():
    """Ring-buffer slot positions (non-monotonic) mask correctly."""
    key = jax.random.PRNGKey(3)
    B, H, KV, D, cap = 1, 2, 1, 8, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, cap, KV, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, cap, KV, D), jnp.float32)
    # cache holds positions 4..11 in ring order (8,9,10,11,4,5,6,7)
    slot_pos = jnp.array([8, 9, 10, 11, 4, 5, 6, 7])
    q_position = jnp.int32(12)
    out = layers.decode_attention(q, kc, vc, q_position=q_position,
                                  kv_positions=slot_pos,
                                  valid_len=jnp.int32(cap), window=8)
    # oracle: window 8 from pos 12 keeps positions 5..12 -> masks slot 4
    mask = (slot_pos <= 12) & ((12 - slot_pos) < 8)
    want = ref.decode_attention_ref(q[:, 0], kc, vc, mask=mask)
    np.testing.assert_allclose(out[:, 0], want, atol=2e-5, rtol=2e-5)


def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (1, 6, 2, 16), jnp.float32)
    pos = jnp.arange(6)
    y = layers.apply_rope(x, pos)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1),
        atol=1e-5, rtol=1e-5)
    # relativity: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(5), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = layers.apply_rope(q, jnp.array([i]))
        kj = layers.apply_rope(k, jnp.array([j]))
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4


def test_rms_norm_zero_weight_unit_scale():
    x = jnp.array([[3.0, 4.0]])
    out = layers.rms_norm(x, jnp.zeros(2))
    np.testing.assert_allclose(
        jnp.sqrt(jnp.mean(out ** 2, -1)), 1.0, atol=1e-4)
