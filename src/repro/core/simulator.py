"""Discrete-event simulator of the serving node (GPU lane + CPU lane).

Execution-time model, calibrated to the paper's published coefficients
(personas.py) and cross-checked against the real JAX engine on tiny
configs (tests/test_engine_vs_sim.py):

    t_batch(GPU) = setup_f + eta_f * max(out_len in batch)
    t_batch(CPU) = cpu_slowdown_f * t_batch(GPU-model)

Batched autoregressive decoding runs until its *longest* member finishes
— this is precisely the head-of-line effect RT-LM's consolidation
exploits: batches with homogeneous output lengths waste no decode steps.

The simulator owns the clock; the policy is consulted whenever the GPU
lane is free and the dispatch condition holds (>= C queued, or the oldest
task has waited the xi batching window).  The CPU lane drains offloaded
tasks independently.

Two execution models, cross-checkable against the real engine
(tests/test_continuous.py::test_engine_vs_sim_*):

  * ``simulate``            — run-to-completion batches (paper model).
  * ``simulate_continuous`` — iteration-level batching: C decode slots,
    finished sequences evicted per step, the policy's ``admit`` consulted
    per freed slot.  Per-step cost model: eta per decode step (the
    decode loop is latency-bound, independent of slot occupancy),
    item_time per admission (the per-member bandwidth term the batch
    model charges once per batch), setup_time only when the engine
    restarts from idle.  Admission is modeled as AMORTIZED prefill: the
    first token materializes at admission without an eta charge, so a
    saturated homogeneous wave costs setup + (L-1)*eta + C*item — one
    eta LESS than the batch model's linear fit (setup + L*eta + C*item),
    which folds the prefill-emitted first token into eta*L.  This is a
    deliberate idealization (real continuous engines chunk/overlap
    prefill; ours serializes it and still wins — see the wall-clock
    benchmark in benchmarks/continuous_vs_batch.py, the unbiased check);
    beyond that one amortized step per wave, continuous batching's
    advantage comes from eliminating head-of-line blocking and the xi
    dispatch wait.

The continuous state machine lives in ``_ReplicaSim`` — one replica's
slots, queues, KV reservations and clock behind ``deliver`` / ``iterate``
/ ``advance_idle``.  ``simulate_continuous`` drives exactly one instance
(the single-node model, bit-identical to the pre-factoring loop);
``simulate_replicated`` drives R instances behind a front-end
``repro.serving.router.Router`` on a shared virtual clock — the
simulator twin of ``repro.serving.replica.ReplicatedEngine``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.kvcache import (BlockAllocator, PrefixCache, blocks_for_tokens,
                           window_target_tokens)
from repro.obs.metrics import Histogram
from repro.prefill import ChunkScheduler, pack_plans, suffix_shape_key

from . import scheduler as sched_lib
from .personas import Persona
from .priority import SimTask


def _pct(samples, q: float) -> float:
    return float(np.quantile(np.asarray(samples), q)) if len(samples) \
        else 0.0


def _tid(t: SimTask):
    """Event task id: the wrapped request's task_id when present (the
    engine stamps the same id, which is what makes event streams
    comparable), else None."""
    return getattr(getattr(t, "task", None), "task_id", None)


def _cls(t: SimTask) -> str:
    """Traffic class of the wrapped request ("" when unclassed) — the
    same attribute the engine reads (Request.traffic_class)."""
    return getattr(getattr(t, "task", None), "traffic_class", "") or ""


@dataclasses.dataclass
class SimResult:
    tasks: List[SimTask]
    makespan: float
    overhead_s: float = 0.0
    # block-budget admission model (continuous mode with a paged KV
    # cache): engine-side mirrors in ServingEngine._result
    kv_rejected: int = 0
    kv_util_peak: float = 0.0
    kv_util_mean: float = 0.0
    peak_concurrency: int = 0
    # tail-latency metrics (engine-side mirrors in _result): TTFT per
    # task, pooled inter-token latencies — p99 ITL is where stall
    # prefill shows up as decode jitter.  batch mode models streaming
    # linearly across the batch's decode horizon.  All percentile
    # fields come from ``repro.obs.metrics.Histogram`` (log-bucketed
    # streaming state — the same substrate the engine's _result uses),
    # so they are estimates within one bucket's relative width
    # (~2.5%) of the exact order statistic.
    ttft_p50: float = 0.0
    ttft_p90: float = 0.0
    ttft_p99: float = 0.0
    itl_p50: float = 0.0
    itl_p90: float = 0.0
    itl_p99: float = 0.0
    # per-request time from arrival to admission (bulk lane: batch
    # start) — engine mirror stamps Request.queue_wait_s
    queue_wait_p50: float = 0.0
    queue_wait_p90: float = 0.0
    queue_wait_p99: float = 0.0
    # engine mirror counts rate-limited kernel/warmup fallbacks
    # (repro.obs.log); the simulator runs no kernels, so always 0 —
    # kept so result dicts stay field-compatible
    fallback_events: int = 0
    # chunked-prefill mode: per-iteration (decode_tokens,
    # prefill_tokens) — the engine records the identical trace
    budget_trace: List = dataclasses.field(default_factory=list)
    # dispatch accounting (engine-side mirrors in _result): total
    # prefill launches and per-iteration launch counts — the fused
    # chunked engine issues exactly ONE launch per iteration with
    # scheduled chunks (trace aligned with budget_trace, entries <= 1);
    # stall mode records admission-burst sizes; batch mode one launch
    # per executed batch.  exec_cache_* mirror the engine's fused
    # executable padded-shape-key novelty (ChunkBatch.shape_key via
    # the SAME pack_plans call, so parity is straight equality).
    prefill_dispatches: int = 0
    prefill_dispatch_trace: List = dataclasses.field(default_factory=list)
    exec_cache_hits: int = 0
    exec_cache_misses: int = 0
    # prefix-cache model (kvcache.prefix driven host-side, the same
    # class the engine drives): counter definitions match
    # ServingEngine._result field for field, so parity on the
    # hit/CoW/eviction numbers is straight equality
    prefix_hit_rate: float = 0.0
    cached_tokens_reused: int = 0
    cow_copies: int = 0
    prefix_evictions: int = 0
    # decode-dispatch accounting (async host pipeline mirror of the
    # engine's multi-step decode window): launches, total steps
    # (steps/dispatches == decode_steps exactly) and steps per window
    # (chunked mode aligns entries with budget_trace, 0 = prefill-only
    # iteration) — all three parity-match ServingEngine._result.
    decode_dispatches: int = 0
    decode_steps_executed: int = 0
    decode_dispatch_trace: List = dataclasses.field(default_factory=list)
    # SLO monitoring / predictor calibration / health snapshots (PR 8,
    # engine mirrors in ServingEngine._result): {} / [] with the
    # features off; the deterministic members (per-class counts,
    # calibration counters, non-wall snapshot fields) parity-match the
    # engine bit for bit under deterministic SLO judgements
    slo_attainment: Dict = dataclasses.field(default_factory=dict)
    calibration: Dict = dataclasses.field(default_factory=dict)
    health_trace: List = dataclasses.field(default_factory=list)
    # failure-aware serving (serving.faults; engine mirror in
    # ServingEngine._result): requests dropped by the pre-admission
    # deadline / pressure shed pass, and whether the replica crashed
    # mid-serve.  Zero/False without a fault plan, so unfaulted results
    # stay field-for-field identical to pre-fault runs.
    timed_out: int = 0
    shed: int = 0
    crashed: bool = False
    #: KV blocks still reserved when the run ended — 0 under every
    #: fault schedule (crash eviction frees them), asserted by the
    #: no-leak property test
    kv_blocks_in_use: int = 0

    # ---- paper metrics ------------------------------------------------
    @property
    def response_times(self) -> np.ndarray:
        return np.array([t.response_time for t in self.tasks])

    @property
    def mean_response(self) -> float:
        return float(self.response_times.mean())

    @property
    def max_response(self) -> float:
        return float(self.response_times.max())

    @property
    def throughput_per_min(self) -> float:
        return 60.0 * len(self.tasks) / max(self.makespan, 1e-9)

    @property
    def miss_rate(self) -> float:
        return float(np.mean([t.missed for t in self.tasks]))

    def summary(self) -> Dict[str, float]:
        return {
            "mean_response_s": self.mean_response,
            "max_response_s": self.max_response,
            "p95_response_s": float(np.quantile(self.response_times, 0.95)),
            "throughput_per_min": self.throughput_per_min,
            "miss_rate": self.miss_rate,
            "n_tasks": len(self.tasks),
            "ttft_p50": self.ttft_p50,
            "ttft_p90": self.ttft_p90,
            "ttft_p99": self.ttft_p99,
            "itl_p50": self.itl_p50,
            "itl_p90": self.itl_p90,
            "itl_p99": self.itl_p99,
            "queue_wait_p50": self.queue_wait_p50,
            "queue_wait_p90": self.queue_wait_p90,
            "queue_wait_p99": self.queue_wait_p99,
        }


class Lane:
    def __init__(self, slowdown: float = 1.0):
        self.free_at = 0.0
        self.slowdown = slowdown
        self.busy_time = 0.0

    def run_batch(self, batch: List[SimTask], now: float,
                  persona: Persona, lane_name: str,
                  ttfts: Optional[Histogram] = None,
                  itls: Optional[Histogram] = None,
                  qwaits: Optional[Histogram] = None,
                  obs=None) -> float:
        start = max(now, self.free_at)
        dur = persona.batch_latency(
            [t.true_out_len for t in batch]) * self.slowdown
        finish = start + dur
        # linear streaming model for the tail metrics: the batch decodes
        # max(out_len) steps over ``dur``, so token j of every member is
        # emitted at a linear fraction of the horizon (uniform ITL)
        horizon = max(max((t.true_out_len for t in batch), default=1), 1)
        if obs is not None:
            # the engine's _run_batch emits the identical sequence
            # (events carry no step — bulk batches run outside the
            # iteration loop)
            obs.inc("prefill.dispatches")
            obs.span("bulk_batch", start, dur, lane=lane_name,
                     size=len(batch))
        for t in batch:
            t.start, t.finish, t.lane = start, finish, lane_name
            if ttfts is not None:
                ttfts.record(start + dur / horizon - t.r)
            if itls is not None and t.true_out_len > 1:
                itls.record(dur / horizon, t.true_out_len - 1)
            if qwaits is not None:
                qwaits.record(start - t.r)
            if obs is not None:
                obs.slo_observe("queue_wait", _cls(t), start,
                                start - t.r)
                if t.true_out_len >= 1:
                    obs.event("first_token", start + dur / horizon,
                              _tid(t), lane=lane_name)
                    obs.slo_observe("ttft", _cls(t),
                                    start + dur / horizon,
                                    start + dur / horizon - t.r)
                    if t.true_out_len > 1:
                        obs.slo_observe("itl", _cls(t), finish,
                                        dur / horizon,
                                        n=t.true_out_len - 1)
                obs.event("complete", finish, _tid(t), lane=lane_name,
                          out_len=t.true_out_len)
                obs.inc("sched.completions")
                obs.complete_request(_cls(t), finish, u=t.u,
                                     out_len=t.true_out_len,
                                     latency_s=finish - t.r)
        self.free_at = finish
        self.busy_time += dur
        return finish


def _obs_result_fields(obs) -> Dict:
    """The SLO/calibration/health members of ``SimResult`` pulled off
    an ``Observability`` bundle ({} / [] with the features off) — the
    exact mirror of the corresponding ``ServingEngine._result`` keys."""
    return {
        "slo_attainment": (obs.slo.attainment()
                           if obs is not None and obs.slo is not None
                           else {}),
        "calibration": (obs.calibration.summary()
                        if obs is not None
                        and obs.calibration is not None else {}),
        "health_trace": (list(obs.health_trace)
                         if obs is not None else []),
    }


def simulate(tasks: Sequence[SimTask], policy: sched_lib.Policy, *,
             xi: float = 2.0, per_task_overhead_s: float = 0.0,
             obs=None) -> SimResult:
    """Run the full trace through the node under ``policy``.

    per_task_overhead_s models the scheduler's own latency (Table VII);
    it is added to the dispatch instant of every formed batch.

    ``obs`` — optional ``repro.obs.Observability``: records the same
    lifecycle event stream / counters as ``ServingEngine`` in batch
    mode (enqueue / first_token / complete, ``sched.completions``,
    ``prefill.dispatches``).
    """
    persona = policy.persona
    pending = sorted(tasks, key=lambda t: t.r)
    n_total = len(pending)
    queue: List[SimTask] = []
    cpu_queue: List[SimTask] = []
    done: List[SimTask] = []
    gpu = Lane(1.0)
    cpu = Lane(persona.cpu_slowdown)
    now = 0.0
    overhead_total = 0.0
    ttft_h, itl_h, qw_h = Histogram(), Histogram(), Histogram()
    dispatches = 0                  # one prefill launch per run batch
    dispatch_trace: List[int] = []
    i = 0
    C = persona.batch_size

    def dispatch_ready(now: float) -> bool:
        if not queue:
            return False
        if len(queue) >= C:
            return True
        oldest = min(t.r for t in queue)
        if now - oldest >= xi:
            return True
        # nothing else will ever arrive -> flush
        return i >= n_total

    while len(done) < n_total:
        # admit arrivals up to `now`
        while i < n_total and pending[i].r <= now + 1e-12:
            if obs is not None:
                cls = _cls(pending[i])
                obs.event("enqueue", pending[i].r, _tid(pending[i]),
                          **({"cls": cls} if cls else {}))
            queue.append(pending[i])
            i += 1

        progressed = False
        if gpu.free_at <= now + 1e-12 and dispatch_ready(now):
            gpu_batch, off_batch, rest = policy.select(list(queue), now)
            queue = list(rest)
            cpu_queue.extend(off_batch)
            if gpu_batch:
                oh = per_task_overhead_s * len(gpu_batch)
                overhead_total += oh
                gpu.run_batch(gpu_batch, now + oh, persona, "gpu",
                              ttft_h, itl_h, qw_h, obs)
                done.extend(gpu_batch)
                dispatches += 1
                dispatch_trace.append(1)
                progressed = True
        if cpu.free_at <= now + 1e-12 and cpu_queue:
            batch, cpu_queue = cpu_queue[:C], cpu_queue[C:]
            cpu.run_batch(batch, now, persona, "cpu", ttft_h, itl_h,
                          qw_h, obs)
            done.extend(batch)
            dispatches += 1
            dispatch_trace.append(1)
            progressed = True

        if progressed:
            continue
        # advance the clock to the next *future* event
        candidates = []
        if i < n_total:
            candidates.append(pending[i].r)
        if queue:
            candidates.append(min(t.r for t in queue) + xi)
            candidates.append(gpu.free_at)
        if cpu_queue:
            candidates.append(cpu.free_at)
        future = [c for c in candidates if c > now + 1e-12]
        now = min(future) if future else now + xi

    makespan = max(t.finish for t in done) - min(t.r for t in done)
    return SimResult(tasks=done, makespan=makespan,
                     overhead_s=overhead_total,
                     ttft_p50=ttft_h.quantile(0.50),
                     ttft_p90=ttft_h.quantile(0.90),
                     ttft_p99=ttft_h.quantile(0.99),
                     itl_p50=itl_h.quantile(0.50),
                     itl_p90=itl_h.quantile(0.90),
                     itl_p99=itl_h.quantile(0.99),
                     queue_wait_p50=qw_h.quantile(0.50),
                     queue_wait_p90=qw_h.quantile(0.90),
                     queue_wait_p99=qw_h.quantile(0.99),
                     prefill_dispatches=dispatches,
                     prefill_dispatch_trace=dispatch_trace,
                     **_obs_result_fields(obs))


@dataclasses.dataclass
class PrefixState:
    """Prefix-cache state surviving across ``simulate_continuous``
    calls — the simulator mirror of
    ``ServingEngine(persist_prefix_cache=True)``, whose page pool,
    allocator and prefix index outlive a single ``serve()``.  Build one
    with ``make_prefix_state`` and pass it to successive calls; each
    call resets the per-run counters (``PrefixCache.reset_stats``)
    while the index and its block pins carry over."""

    alloc: BlockAllocator
    pc: PrefixCache


def make_prefix_state(kv_num_blocks: int,
                      kv_block_size: int) -> PrefixState:
    alloc = BlockAllocator(kv_num_blocks, kv_block_size)
    return PrefixState(alloc=alloc, pc=PrefixCache(alloc, kv_block_size))


class _ReplicaSim:
    """One continuous-batching replica: the engine step loop's state
    machine (slots, queues, KV reservations, chunk scheduler, clock)
    factored out of ``simulate_continuous`` so ``simulate_replicated``
    can advance R independent instances on a shared virtual clock.

    The three verbs mirror the driver loop's phases:

      * ``deliver(task)``      — an arrival reaches this replica's queue
        (the enqueue event fires here, stamped at the arrival time);
      * ``iterate()``          — one engine iteration: admissions (stall
        or chunked), a decode window, the CPU lane; returns whether any
        progress was made;
      * ``advance_idle(cands)``— nothing progressed: jump the clock to
        the next future candidate (caller adds the next arrival), else
        burn one xi batching window.

    ``simulate_continuous`` drives exactly one instance — bit-identical
    to the pre-factoring single loop; the replicated driver additionally
    reads ``load()`` (the router's view) and ``has_work()``.
    """

    def __init__(self, policy: sched_lib.Policy, *,
                 xi: float = 2.0,
                 per_task_overhead_s: float = 0.0,
                 num_slots: Optional[int] = None,
                 kv_block_size: Optional[int] = None,
                 kv_num_blocks: Optional[int] = None,
                 prompt_len: int = 0,
                 prefill: str = "stall",
                 chunk_size: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 prefix_cache: bool = False,
                 prompt_tokens=None,
                 decode_steps: int = 1,
                 prefix_state: Optional[PrefixState] = None,
                 faults=None,
                 obs=None) -> None:
        self.policy = policy
        self.persona = policy.persona
        self.xi = xi
        self.per_task_overhead_s = per_task_overhead_s
        self.obs = obs
        self.C = num_slots if num_slots is not None \
            else self.persona.batch_size
        self.kv_block_size = kv_block_size
        self.kv_num_blocks = kv_num_blocks
        self.kv_model = kv_block_size is not None \
            and kv_num_blocks is not None
        self.prompt_len = prompt_len
        self.prompt_tokens = prompt_tokens
        if prefill not in ("stall", "chunked"):
            raise ValueError(f"unknown prefill mode {prefill!r}")
        self.chunked = prefill == "chunked"
        self.sched: Optional[ChunkScheduler] = None
        if self.chunked:
            if prompt_len <= 0:
                raise ValueError('prefill="chunked" needs prompt_len > 0')
            if chunk_size is None or token_budget is None:
                raise ValueError('prefill="chunked" needs chunk_size and '
                                 'token_budget')
            self.sched = ChunkScheduler(
                chunk_size, token_budget,
                metrics=obs.metrics if obs is not None else None)
        if decode_steps < 1:
            raise ValueError(
                f"decode_steps must be >= 1, got {decode_steps}")
        self.decode_steps = decode_steps
        # failure-aware serving (serving.faults.ReplicaFaults): the
        # pre-admission shed pass + straggler slowdowns run inside
        # iterate(); crashes are driven from outside (the replicated
        # driver / fault coordinator).  Restricted to the stall prefill
        # path — the chunked packer has no engine-parity shed point.
        self.faults = faults
        if faults is not None and self.chunked:
            raise ValueError('faults require prefill="stall"')
        self.timed_out: List[SimTask] = []   # deadline-shed terminals
        self.shed_tasks: List[SimTask] = []  # pressure-shed terminals
        self.crashed = False
        self.pc: Optional[PrefixCache] = None
        self.alloc: Optional[BlockAllocator] = None
        if prefix_state is not None and not prefix_cache:
            raise ValueError("prefix_state requires prefix_cache=True")
        if prefix_cache:
            if not self.kv_model:
                raise ValueError('prefix_cache=True needs kv_block_size '
                                 'and kv_num_blocks (the block-budget '
                                 'model)')
            if prompt_len <= 0:
                raise ValueError('prefix_cache=True needs prompt_len > 0')
            if prompt_tokens is None:
                raise ValueError('prefix_cache=True needs a '
                                 'prompt_tokens callable (task -> '
                                 'padded token bucket)')
            if prefix_state is not None:
                self.alloc, self.pc = prefix_state.alloc, prefix_state.pc
                self.pc.reset_stats()
            else:
                self.alloc = BlockAllocator(kv_num_blocks, kv_block_size)
                self.pc = PrefixCache(self.alloc, kv_block_size)
            # same registry hookup the engine's _paged_setup makes, so
            # the "prefix.*" counters stream into the shared parity view
            self.pc.metrics = obs.metrics if obs is not None else None
        C = self.C
        self.slots: List[Optional[SimTask]] = [None] * C
        self.produced = [0] * C
        self.reserved = [0] * C
        self.slot_toks: Dict[int, tuple] = {}  # chunked+prefix: bucket
        self.queue: List[SimTask] = []
        self.cpu_queue: List[SimTask] = []
        self.done: List[SimTask] = []
        self.cpu = Lane(self.persona.cpu_slowdown)
        self.now = 0.0
        self.overhead_total = 0.0
        self.rejected_ids: set = set()  # distinct tasks deferred for mem
        self.kv_util: List[float] = []
        self.budget_trace: List = []
        self.dispatches = 0             # prefill launches (engine mirror)
        self.dispatch_trace: List[int] = []
        self.exec_keys: set = set()     # fused-executable key novelty
        self.exec_hits = 0
        self.exec_misses = 0
        self.dispatches_dec = 0         # decode windows (engine mirror)
        self.steps_dec = 0              # decode steps across all windows
        self.dec_trace: List[int] = []  # steps per window
        self.ttft_h = Histogram()
        self.itl_h = Histogram()
        self.qw_h = Histogram()
        self.last_tok = [0.0] * C       # last token emission per slot
        self.peak_conc = 0
        self.delivered = 0
        self.step = 0                   # decode steps executed so far —
        # the engine's iteration coordinate; stamped on every event so
        # engine and sim streams line up position for position

    # ------------------------------------------------------------------
    def check_fits(self, tasks: Sequence[SimTask]) -> None:
        """The upfront deadlock guard: the largest task's worst-case
        reservation must fit an EMPTY pool or admission can never
        succeed (same check for every replica — pools are equal)."""
        if not self.kv_model:
            return
        worst = max((blocks_for_tokens(
            self.prompt_len + max(1, t.true_out_len) - 1,
            self.kv_block_size) for t in tasks), default=0)
        if worst > self.kv_num_blocks:
            raise ValueError(
                f"kv_num_blocks={self.kv_num_blocks} cannot hold the "
                f"largest task ({worst} blocks) — admission would "
                f"deadlock")

    def terminal_count(self) -> int:
        """Requests that reached ANY terminal outcome here: completed,
        deadline-timed-out or shed.  Crash survivors are subtracted
        from ``delivered`` instead — they terminate elsewhere."""
        return (len(self.done) + len(self.timed_out)
                + len(self.shed_tasks))

    def has_work(self) -> bool:
        """Delivered-but-unfinished work exists on this replica."""
        return self.delivered > self.terminal_count()

    def load(self) -> Dict:
        """The router's live view of this replica: placed-but-unfinished
        work (queue + CPU lane + chunked prefill jobs + active slots),
        occupied decode slots, and KV-pool headroom.  Keys match the
        ``repro.serving.router.ReplicaView`` fields; the engine
        front-end builds the same view from its placement bookkeeping,
        so routing decisions parity-match bit for bit on all-at-t0
        traces (where every placement precedes any engine work)."""
        active = [t for t in self.slots if t is not None]
        inflight = list(active)
        if self.chunked:
            inflight += [j.task for j in self.sched.jobs]
        tasks = list(self.queue) + list(self.cpu_queue) + inflight
        return {
            "queued": len(tasks),
            "active": len(active),
            "free_blocks": (self.kv_num_blocks - sum(self.reserved)
                            if self.kv_model else 0),
            "num_blocks": self.kv_num_blocks if self.kv_model else 0,
            "u_load": float(sum(t.u for t in tasks)),
        }

    def deliver(self, task: SimTask) -> None:
        """An arrival reaches this replica's queue (enqueue event at the
        arrival timestamp, as the engine's serve prologue stamps it)."""
        if self.obs is not None:
            cls = _cls(task)
            self.obs.event("enqueue", task.r, _tid(task), self.step,
                           **({"cls": cls} if cls else {}))
        self.queue.append(task)
        self.delivered += 1

    def advance_idle(self, candidates: Sequence[float] = ()) -> None:
        """Nothing progressed: jump to the next future event (callers
        append the next arrival time), else burn one xi window."""
        cands = list(candidates)
        if self.cpu_queue:
            cands.append(self.cpu.free_at)
        future = [c for c in cands if c > self.now + 1e-12]
        self.now = min(future) if future else self.now + self.xi

    def crash(self) -> List[SimTask]:
        """Replica death (serving.faults.CrashFault): every active slot
        is evicted in slot order (its KV blocks freed — the engine does
        the same, so allocator free-list state stays bit-identical),
        and every unfinished request — active, queued, CPU-lane — is
        returned as a survivor for the fault coordinator to
        re-dispatch; ``delivered`` drops by the survivor count so the
        replica reads as drained.  Progress (``produced`` tokens) is
        lost: failover restarts a request from scratch on its target,
        the standard no-KV-migration semantics."""
        obs = self.obs
        survivors: List[SimTask] = []
        for s in range(self.C):
            t = self.slots[s]
            if t is None:
                continue
            if obs is not None:
                obs.event("evict", self.now, _tid(t), self.step, slot=s)
            if self.pc is not None:
                self.alloc.free_sequence(id(t))
            self.slots[s] = None
            self.reserved[s] = 0
            self.produced[s] = 0
            survivors.append(t)
        survivors += list(self.queue) + list(self.cpu_queue)
        self.queue = []
        self.cpu_queue = []
        self.delivered -= len(survivors)
        self.crashed = True
        if obs is not None:
            obs.event("replica_down", self.now, None, self.step,
                      reason="crash", survivors=len(survivors))
            obs.inc("faults.replica_down")
        return survivors

    # ------------------------------------------------------------------
    def _admit_one(self, running):
        """Shared admission prologue: one ``policy.admit`` consultation
        plus the block-reservation gate, overhead / setup charges and
        the CPU-lane fork — identical for the stall and chunked
        branches (the engine mirrors it bit for bit).  Returns
        ("stop", None, 0) to end the admission loop, ("cpu", None, 0)
        when the task was offloaded, or ("gpu", task, need)."""
        obs = self.obs
        prev_queue = list(self.queue)
        task, lane, rest = self.policy.admit(list(self.queue), self.now,
                                             running)
        if task is None:
            return "stop", None, 0
        self.queue = list(rest)
        need = 0
        if self.kv_model and lane != "cpu":
            need = blocks_for_tokens(
                self.prompt_len + max(1, task.true_out_len) - 1,
                self.kv_block_size)
            if need > self.kv_num_blocks - sum(self.reserved):
                self.queue = prev_queue        # leave it queued
                self.rejected_ids.add(id(task))
                if obs is not None:
                    obs.event("reject", self.now, _tid(task), self.step,
                              kv_blocks=need)
                    obs.inc("sched.rejections")
                return "stop", None, 0
        self.overhead_total += self.per_task_overhead_s
        self.now += self.per_task_overhead_s
        if lane == "cpu":
            if obs is not None:
                obs.event("offload", self.now, _tid(task), self.step)
                obs.inc("sched.offloads")
            self.cpu_queue.append(task)
            return "cpu", None, 0
        if not running:
            self.now += self.persona.setup_time  # restart from idle
        return "gpu", task, need

    # ------------------------------------------------------------------
    def iterate(self) -> bool:
        """One engine iteration (admissions, decode window, CPU lane);
        returns whether any progress was made."""
        obs, persona, C = self.obs, self.persona, self.C
        pc, alloc = self.pc, self.alloc
        slots, produced = self.slots, self.produced
        reserved, slot_toks = self.reserved, self.slot_toks
        last_tok, done = self.last_tok, self.done
        kv_util = self.kv_util
        ttft_h, itl_h, qw_h = self.ttft_h, self.itl_h, self.qw_h
        prompt_len, decode_steps = self.prompt_len, self.decode_steps
        kv_model, chunked = self.kv_model, self.chunked

        progressed = False
        if chunked:
            sched = self.sched
            # admissions enqueue a chunk job; the slot is held by the
            # job (not decoding yet) until its last chunk completes
            in_prefill = set(sched.slots_in_prefill())
            free = [s for s in range(C)
                    if slots[s] is None and s not in in_prefill]
            while self.queue and free:
                running = ([t for t in slots if t is not None]
                           + [j.task for j in sorted(sched.jobs,
                                                     key=lambda j: j.seq)])
                status, task, need = self._admit_one(running)
                if status == "stop":
                    break
                if status == "cpu":
                    continue
                s = free.pop(0)
                if kv_model:
                    reserved[s] = need
                qw_h.record(self.now - task.r)
                if obs is not None:
                    obs.event("admit", self.now, _tid(task), self.step,
                              slot=s, u=task.u, kv_blocks=need)
                    obs.inc("sched.admissions")
                    obs.observe("queue_wait_s", self.now - task.r)
                    obs.slo_observe("queue_wait", _cls(task), self.now,
                                    self.now - task.r)
                total = prompt_len
                if pc is not None:
                    # matched prefix blocks shared at admission (same
                    # call the engine makes); the chunk job covers only
                    # the uncached suffix
                    toks = tuple(self.prompt_tokens(task))
                    adm = pc.admit(id(task), toks)
                    if obs is not None and adm.matched_blocks:
                        obs.event("prefix_hit", self.now, _tid(task),
                                  self.step, cached_tokens=adm.start,
                                  matched_blocks=adm.matched_blocks,
                                  cow=len(adm.cow))
                    slot_toks[s] = toks
                    total = prompt_len - adm.start
                sched.add(task, s, total,
                          self.policy.assign_priority(task))
                progressed = True

            # chunk phase: pack the budget, decode tokens first.  The
            # engine executes the whole plan as ONE fused ragged launch
            # (pack_plans -> ChunkBatch); mirror its dispatch count and
            # executable-cache shape-key novelty from the same call —
            # the latency model still charges per-chunk token cost.
            active0 = [s for s in range(C) if slots[s] is not None]
            plans = sched.schedule(len(active0)) if sched.has_jobs else []
            chunk_batch = pack_plans(plans)
            if chunk_batch is not None:
                self.dispatches += 1
                hit = chunk_batch.shape_key in self.exec_keys
                if hit:
                    self.exec_hits += 1
                else:
                    self.exec_keys.add(chunk_batch.shape_key)
                    self.exec_misses += 1
                if obs is not None:
                    # mirror of the engine's fused-launch emission: one
                    # exec_cache probe then one prefill_chunk per MERGED
                    # chunk (the ragged batch the engine launches), all
                    # before any finishing first_token — identical
                    # stream order, from the same pack_plans result
                    obs.event("exec_cache", self.now, None, self.step,
                              hit=hit,
                              shape_key=str(chunk_batch.shape_key))
                    obs.inc("exec_cache.hits" if hit
                            else "exec_cache.misses")
                    obs.inc("prefill.dispatches")
                    pf_cost = (persona.item_time
                               * chunk_batch.total_tokens / prompt_len)
                    obs.span("prefill.ragged", self.now, pf_cost,
                             chunks=len(chunk_batch.chunks),
                             tokens=chunk_batch.total_tokens)
                    for ch in chunk_batch.chunks:
                        obs.event("prefill_chunk", self.now,
                                  _tid(ch.job.task), self.step,
                                  slot=ch.slot, start=ch.start,
                                  length=ch.length, finishes=ch.finishes,
                                  shape_key=str(chunk_batch.shape_key))
            for plan in plans:
                self.now += persona.item_time * plan.length / prompt_len
                if plan.finishes:
                    task, s = plan.job.task, plan.job.slot
                    if pc is not None:
                        pc.commit(id(task), slot_toks.pop(s))
                    task.start, task.lane = self.now, "gpu"
                    ttft_h.record(self.now - task.r)
                    if obs is not None:
                        obs.event("first_token", self.now, _tid(task),
                                  self.step, slot=s)
                        obs.slo_observe("ttft", _cls(task), self.now,
                                        self.now - task.r)
                    if task.true_out_len <= 1:  # first token already EOS
                        task.finish = self.now
                        done.append(task)
                        reserved[s] = 0
                        if pc is not None:
                            alloc.free_sequence(id(task))
                        if obs is not None:
                            obs.event("complete", self.now, _tid(task),
                                      self.step, lane="gpu", out_len=1)
                            obs.event("evict", self.now, _tid(task),
                                      self.step, slot=s)
                            obs.inc("sched.completions")
                            obs.complete_request(
                                _cls(task), self.now, u=task.u,
                                out_len=1,
                                latency_s=self.now - task.r)
                    else:
                        slots[s] = task         # joins THIS step's decode
                        produced[s] = 1         # prefill emits token 1
                        last_tok[s] = self.now
            if plans:
                progressed = True
            if plans or any(t is not None for t in slots):
                self.budget_trace.append(
                    (len(active0), sum(p.length for p in plans)))
                self.dispatch_trace.append(1 if plans else 0)
                # aligned with budget_trace, as in the engine: steps
                # launched this iteration (0 = prefill-only iteration)
                self.dec_trace.append(decode_steps
                                      if any(t is not None
                                             for t in slots)
                                      else 0)
        else:
            if self.faults is not None and self.queue:
                # failure-aware pre-admission pass (serving.faults):
                # doomed-request timeouts + pressure shedding — the
                # same shed_pass call the engine's stall loop makes at
                # the same point, so events/counters parity-match
                from repro.serving.faults import shed_pass
                kept, timed, dropped = shed_pass(
                    self.queue, now=self.now, step=self.step,
                    rf=self.faults,
                    slo=obs.slo if obs is not None else None, obs=obs)
                if timed or dropped:
                    self.queue = kept
                    self.timed_out += timed
                    self.shed_tasks += dropped
                    progressed = True
            # admissions into freed slots (uncertainty-aware, stalling
            # the loop for one amortized prefill per admission — and
            # one prefill LAUNCH per admission, the burst the fused
            # chunked path collapses to one per iteration)
            iter_launches = 0
            while self.queue and None in slots:
                running = [t for t in slots if t is not None]
                status, task, need = self._admit_one(running)
                if status == "stop":
                    break
                if status == "cpu":
                    continue
                self.dispatches += 1
                iter_launches += 1
                # slot chosen BEFORE prefill (as the engine does): the
                # admit event carries it even for an immediate finish
                s = slots.index(None)
                tid = _tid(task)
                qw_h.record(self.now - task.r)
                if obs is not None:
                    obs.event("admit", self.now, tid, self.step, slot=s,
                              u=task.u, kv_blocks=need)
                    obs.inc("sched.admissions")
                    obs.observe("queue_wait_s", self.now - task.r)
                    obs.slo_observe("queue_wait", _cls(task), self.now,
                                    self.now - task.r)
                pf_t0 = self.now
                pf_start, pf_key, pf_hit = 0, "admit", False
                if pc is not None:
                    # prefill cost scales with the uncached suffix —
                    # the same admit/commit calls the engine's stall
                    # path makes, so counters match bit for bit
                    toks = tuple(self.prompt_tokens(task))
                    adm = pc.admit(id(task), toks)
                    if obs is not None and adm.matched_blocks:
                        obs.event("prefix_hit", self.now, tid, self.step,
                                  cached_tokens=adm.start,
                                  matched_blocks=adm.matched_blocks,
                                  cow=len(adm.cow))
                    if adm.start > 0:
                        # the engine routes the uncached suffix through
                        # the fused ragged executable as a single-chunk
                        # launch; mirror its shape-key novelty
                        key = suffix_shape_key(prompt_len - adm.start)
                        pf_hit = key in self.exec_keys
                        if pf_hit:
                            self.exec_hits += 1
                        else:
                            self.exec_keys.add(key)
                            self.exec_misses += 1
                        pf_start, pf_key = adm.start, str(key)
                    self.now += (persona.item_time
                                 * (prompt_len - adm.start) / prompt_len)
                    pc.commit(id(task), toks)
                else:
                    self.now += persona.item_time  # per-member bandwidth
                task.start, task.lane = self.now, "gpu"
                ttft_h.record(self.now - task.r)
                if obs is not None:
                    # same post-launch emission the engine's stall path
                    # makes (exec_cache only on the prefix-suffix path)
                    if pf_key != "admit":
                        obs.event("exec_cache", self.now, tid, self.step,
                                  hit=pf_hit, shape_key=pf_key)
                        obs.inc("exec_cache.hits" if pf_hit
                                else "exec_cache.misses")
                    obs.inc("prefill.dispatches")
                    obs.span("prefill.admit", pf_t0, self.now - pf_t0,
                             task=tid, slot=s)
                    obs.event("prefill_chunk", self.now, tid, self.step,
                              slot=s, start=pf_start,
                              length=prompt_len - pf_start,
                              finishes=True, shape_key=pf_key)
                    obs.event("first_token", self.now, tid, self.step,
                              slot=s)
                    obs.slo_observe("ttft", _cls(task), self.now,
                                    self.now - task.r)
                if task.true_out_len <= 1:     # first token already EOS
                    task.finish = self.now
                    done.append(task)
                    if pc is not None:
                        alloc.free_sequence(id(task))
                    if obs is not None:
                        obs.event("complete", self.now, tid, self.step,
                                  lane="gpu", out_len=1)
                        obs.event("evict", self.now, tid, self.step,
                                  slot=s)
                        obs.inc("sched.completions")
                        obs.complete_request(
                            _cls(task), self.now, u=task.u, out_len=1,
                            latency_s=self.now - task.r)
                else:
                    slots[s] = task
                    produced[s] = 1            # prefill emits token 1
                    last_tok[s] = self.now
                    if kv_model:
                        reserved[s] = need
                progressed = True
            if iter_launches:
                self.dispatch_trace.append(iter_launches)

        if any(t is not None for t in slots):
            active = [s for s in range(C) if slots[s] is not None]
            self.peak_conc = max(self.peak_conc, len(active))
            nsteps = decode_steps
            if kv_model and pc is not None:
                # real-allocator model (prefix mode): mirror the
                # engine's pre-window extension host-side (every useful
                # write of the next nsteps launches, clamped at the
                # reservation — kvcache.window_target_tokens), then
                # sample the allocator directly — shared prefix blocks
                # and cached-but-unreferenced blocks count once,
                # exactly as in the engine's utilization samples
                for s in active:
                    key = id(slots[s])
                    target = blocks_for_tokens(window_target_tokens(
                        prompt_len, produced[s],
                        max(1, slots[s].true_out_len), nsteps),
                        self.kv_block_size)
                    while target > len(alloc.table(key)):
                        alloc.allocate(key)
                kv_util.append(alloc.utilization())
            elif kv_model:
                # lazy-allocation model: the window writes logical
                # positions up to the window target (clamped at the
                # sequence's reservation), so each slot holds
                # blocks_for(window_target) physical blocks; slots
                # mid-chunked-prefill hold their whole prompt's blocks
                # (allocated at admission, as in the engine)
                held = sum(blocks_for_tokens(window_target_tokens(
                    prompt_len, produced[s],
                    max(1, slots[s].true_out_len), nsteps),
                    self.kv_block_size)
                    for s in active)
                if chunked:
                    held += (len(self.sched.slots_in_prefill())
                             * blocks_for_tokens(prompt_len,
                                                 self.kv_block_size))
                kv_util.append(held / self.kv_num_blocks)
            else:
                kv_util.append(len(active) / C)
            self.dispatches_dec += 1
            self.steps_dec += nsteps
            self.step += nsteps
            if not chunked:
                # stall mode: one trace entry per executed window (the
                # chunked entry was appended with budget_trace above)
                self.dec_trace.append(nsteps)
            if obs is not None:
                # mirror of the engine's per-window emission (the
                # engine stamps the step coordinate AFTER advancing it,
                # as here; event timestamps are model time)
                obs.inc("decode.dispatches")
                obs.inc("decode.steps", nsteps)
                obs.gauge("kv.util", kv_util[-1])
                obs.counter_sample("kv.util", self.now, kv_util[-1])
                obs.span("decode.window", self.now,
                         nsteps * persona.eta,
                         steps=nsteps, active=len(active))
                obs.event("decode_window", self.now, None, self.step,
                          steps=nsteps, active=len(active),
                          dur=nsteps * persona.eta)
            # N-step window, consumed step-major; a sequence finishing
            # at window step j stops producing but keeps its slot and
            # blocks until window end (eviction in arrears — the
            # engine's eviction-lag invariant)
            finished: List[int] = []
            base_step = self.step - nsteps
            for j in range(nsteps):
                # one decode step, all slots; a straggler fault
                # (serving.faults.SlowFault) stretches the step's model
                # time — wall fields are parity-stripped, so only the
                # virtual clock bends
                eta = persona.eta
                if self.faults is not None:
                    eta *= self.faults.slow_factor(base_step + j)
                self.now += eta
                for s in active:
                    if s in finished:
                        continue
                    produced[s] += 1
                    gap = self.now - last_tok[s]
                    itl_h.record(gap)
                    last_tok[s] = self.now
                    if obs is not None:
                        obs.event("token", self.now, _tid(slots[s]),
                                  self.step, slot=s, idx=produced[s])
                        obs.slo_observe("itl", _cls(slots[s]), self.now,
                                        gap)
                    if produced[s] >= slots[s].true_out_len:
                        slots[s].finish = self.now
                        done.append(slots[s])
                        finished.append(s)
                        if obs is not None:
                            obs.event("complete", self.now,
                                      _tid(slots[s]), self.step,
                                      lane="gpu", out_len=produced[s])
                            obs.inc("sched.completions")
                            obs.complete_request(
                                _cls(slots[s]), self.now, u=slots[s].u,
                                out_len=produced[s],
                                latency_s=self.now - slots[s].r)
                            # eviction lag: window steps this slot's
                            # blocks stay held past its logical end
                            obs.observe("decode.eviction_lag_steps",
                                        nsteps - 1 - j)
            # window-end frees in slot order (matches the engine, so
            # allocator free-list state stays bit-identical)
            for s in active:
                if s not in finished:
                    continue
                if obs is not None:
                    obs.event("evict", self.now, _tid(slots[s]),
                              self.step, slot=s)
                if pc is not None:
                    alloc.free_sequence(id(slots[s]))
                slots[s] = None
                reserved[s] = 0
            if obs is not None:
                # same post-window snapshot point as the engine's serve
                # loops: after window bookkeeping and eviction, keyed
                # off the shared ``step`` coordinate
                obs.maybe_snapshot(
                    self.now, self.step, queue_depth=len(self.queue),
                    active=sum(t is not None for t in slots),
                    kv_util=kv_util[-1])
            progressed = True

        if self.cpu.free_at <= self.now + 1e-12 and self.cpu_queue:
            batch = self.cpu_queue[:C]
            self.cpu_queue = self.cpu_queue[C:]
            self.cpu.run_batch(batch, self.now, persona, "cpu", ttft_h,
                               itl_h, qw_h, obs)
            done.extend(batch)
            # bulk-lane launches count in the total only: the trace is
            # the decode loop's per-iteration launch profile (engine
            # mirror — _run_batch does the same in continuous modes)
            self.dispatches += 1
            progressed = True

        return progressed

    # ------------------------------------------------------------------
    def result(self) -> SimResult:
        """The completion-ordered ``SimResult`` epilogue (a replica that
        received no work reports an empty, zeroed result)."""
        done = self.done
        makespan = (max(t.finish for t in done)
                    - min(t.r for t in done)) if done else 0.0
        util = np.array(self.kv_util) if self.kv_util else np.zeros(1)
        pstats = self.pc.stats() if self.pc is not None else {}
        return SimResult(tasks=done, makespan=makespan,
                         overhead_s=self.overhead_total,
                         kv_rejected=len(self.rejected_ids),
                         kv_util_peak=float(util.max()),
                         kv_util_mean=float(util.mean()),
                         peak_concurrency=self.peak_conc,
                         ttft_p50=self.ttft_h.quantile(0.50),
                         ttft_p90=self.ttft_h.quantile(0.90),
                         ttft_p99=self.ttft_h.quantile(0.99),
                         itl_p50=self.itl_h.quantile(0.50),
                         itl_p90=self.itl_h.quantile(0.90),
                         itl_p99=self.itl_h.quantile(0.99),
                         queue_wait_p50=self.qw_h.quantile(0.50),
                         queue_wait_p90=self.qw_h.quantile(0.90),
                         queue_wait_p99=self.qw_h.quantile(0.99),
                         budget_trace=self.budget_trace,
                         prefill_dispatches=self.dispatches,
                         prefill_dispatch_trace=self.dispatch_trace,
                         exec_cache_hits=self.exec_hits,
                         exec_cache_misses=self.exec_misses,
                         decode_dispatches=self.dispatches_dec,
                         decode_steps_executed=self.steps_dec,
                         decode_dispatch_trace=self.dec_trace,
                         prefix_hit_rate=pstats.get(
                             "prefix_hit_rate", 0.0),
                         cached_tokens_reused=pstats.get(
                             "cached_tokens_reused", 0),
                         cow_copies=pstats.get("cow_copies", 0),
                         prefix_evictions=pstats.get(
                             "prefix_evictions", 0),
                         timed_out=len(self.timed_out),
                         shed=len(self.shed_tasks),
                         crashed=self.crashed,
                         kv_blocks_in_use=(sum(self.reserved)
                                           if self.kv_model else 0),
                         **_obs_result_fields(self.obs))


def simulate_continuous(tasks: Sequence[SimTask],
                        policy: sched_lib.Policy, *,
                        xi: float = 2.0,
                        per_task_overhead_s: float = 0.0,
                        num_slots: Optional[int] = None,
                        kv_block_size: Optional[int] = None,
                        kv_num_blocks: Optional[int] = None,
                        prompt_len: int = 0,
                        prefill: str = "stall",
                        chunk_size: Optional[int] = None,
                        token_budget: Optional[int] = None,
                        prefix_cache: bool = False,
                        prompt_tokens=None,
                        decode_steps: int = 1,
                        prefix_state: Optional[PrefixState] = None,
                        faults=None,
                        obs=None) -> SimResult:
    """Iteration-level (continuous) batching over C decode slots.

    Mirrors the real engine's step loop exactly (serving/engine.py
    ``_serve_continuous``): each iteration admits queued tasks into free
    slots in ascending slot order (policy.admit per slot), then advances
    every active slot by one decode step; slots whose sequence finished
    are evicted the same step.  SimResult.tasks is completion-ordered —
    the engine-vs-sim parity tests compare exactly that order.

    Block-budget admission (the paged-KV memory model): when
    ``kv_block_size``/``kv_num_blocks`` are given, admitting a task
    additionally requires its worst-case block reservation
    ``blocks_for_tokens(prompt_len + true_out_len - 1, block_size)`` to
    fit in ``kv_num_blocks`` minus the reservations of every running
    slot — the same gate the paged engine applies (it uses the request
    cap where the sim uses true_out_len; the parity traces make them
    equal).  A non-fitting front-runner is left queued; ``kv_rejected``
    counts DISTINCT tasks deferred at least once (a blocked task is
    retried every step); allocation is modeled lazily (blocks cover written
    positions) for the utilization metrics.  ``num_slots`` decouples
    decode width from the persona batch size, as the paged engine does.

    Chunked prefill (``prefill="chunked"`` — the cost model of the
    engine's chunked mode): admission enqueues the padded prompt into a
    ``repro.prefill.ChunkScheduler`` — the SAME packer the real engine
    drives — instead of materializing the first token at admission.
    Each iteration packs the token budget with decode tokens first plus
    prefill chunks in the policy's priority order; a chunk of T tokens
    costs ``item_time * T / prompt_len`` (a whole prompt still totals
    the stall model's amortized ``item_time``), and the first token
    materializes when the last chunk completes.  ``budget_trace``
    records the engine-identical per-iteration (decode_tokens,
    prefill_tokens) pairs the parity tests compare entry for entry.

    Prefix caching (``prefix_cache=True`` — the cache model of the
    engine's ``prefix_cache=True``): requires the block-budget model
    plus ``prompt_tokens``, a callable mapping a task to its PADDED
    prompt token bucket (the parity tests pass the engine's exact
    ``_tokenize_padded`` recipe).  The simulator then drives a real
    host-side ``BlockAllocator`` + ``PrefixCache`` through the same
    admit/commit/extend/free call sequence as the engine, so hit
    counts, CoW copies, LRU evictions and the per-step utilization
    trace agree bit-for-bit.  Prefill cost scales with the UNCACHED
    suffix: stall admission charges ``item_time * suffix / prompt_len``
    and chunk jobs cover only the suffix — cache hits shorten TTFT.

    Multi-step decode windows (``decode_steps=N`` — the cost model of
    the engine's async host pipeline): each decode iteration advances
    every active slot by N steps in one modeled launch, block tables
    are pre-extended to ``kvcache.window_target_tokens`` (clamped at
    the admission reservation, so rejection decisions are independent
    of N), tokens are consumed step-major, and EVICTION IS IN ARREARS:
    a sequence finishing at window step j frees its blocks — and its
    slot — only at window end, exactly as the engine does.  Admissions
    therefore happen only at window boundaries, one utilization sample
    is taken per window, and ``decode_dispatch_trace`` records steps
    per window; ``decode_steps=1`` reduces bit-for-bit to the
    synchronous per-step model.  ``prefix_state``
    (``make_prefix_state``) carries the allocator + prefix index across
    calls — the mirror of ``persist_prefix_cache=True``.

    Observability (``obs`` — a ``repro.obs.Observability``): the
    simulator emits the SAME request-lifecycle event stream as the
    engine's serve loops, from the same decision points, with the same
    non-wall fields (slot, step, uncertainty score, kv blocks, dispatch
    shape key, ...) — ``TraceRecorder.parity_events()`` of an engine
    run and a sim run of the same trace compare EQUAL, and every
    counter both sides emit (``MetricsRegistry.counters()``) matches
    bit-for-bit (tests/test_obs.py::test_engine_vs_sim_event_parity).
    Only wall-clock fields (event timestamps, span durations) differ:
    the sim stamps model time, the engine stamps its virtual clock.

    Failure-aware serving (``faults`` — a
    ``repro.serving.faults.ReplicaFaults``): per-request deadlines and
    uncertainty-aware load shedding run as a pre-admission pass, and
    straggler slowdowns stretch decode-step model time — mirroring
    ``ServingEngine(faults=...)`` call for call.  Crash faults need the
    replicated driver (failover has nowhere to go at R=1) and raise
    here.  Timed-out/shed requests are terminal: counted in
    ``SimResult.timed_out``/``shed``, never in ``tasks``.
    """
    pending = sorted(tasks, key=lambda t: t.r)
    n_total = len(pending)
    if faults is not None and faults.crash_at_step is not None:
        raise ValueError("crash faults need the replicated driver "
                         "(simulate_replicated / ReplicatedEngine) — "
                         "failover has nowhere to go at R=1")
    rep = _ReplicaSim(policy, xi=xi,
                      per_task_overhead_s=per_task_overhead_s,
                      num_slots=num_slots, kv_block_size=kv_block_size,
                      kv_num_blocks=kv_num_blocks, prompt_len=prompt_len,
                      prefill=prefill, chunk_size=chunk_size,
                      token_budget=token_budget,
                      prefix_cache=prefix_cache,
                      prompt_tokens=prompt_tokens,
                      decode_steps=decode_steps,
                      prefix_state=prefix_state, faults=faults, obs=obs)
    rep.check_fits(pending)
    i = 0
    while rep.terminal_count() < n_total:
        while i < n_total and pending[i].r <= rep.now + 1e-12:
            rep.deliver(pending[i])
            i += 1
        if rep.iterate():
            continue
        rep.advance_idle([pending[i].r] if i < n_total else [])
    return rep.result()


@dataclasses.dataclass
class ReplicatedSimResult:
    """R per-replica ``SimResult``s plus the router's placement record
    and pool-level latency percentiles (merged from every replica's
    streaming histograms — the same substrate the per-replica
    percentiles use, so pooled == merged, not averaged)."""

    replicas: List[SimResult]
    placements: List[int]            # arrival-order replica choice
    router_policy: str
    n_tasks: int
    makespan: float
    ttft_p50: float = 0.0
    ttft_p90: float = 0.0
    ttft_p99: float = 0.0
    itl_p50: float = 0.0
    itl_p90: float = 0.0
    itl_p99: float = 0.0
    queue_wait_p50: float = 0.0
    queue_wait_p90: float = 0.0
    queue_wait_p99: float = 0.0
    # failure-aware serving (serving.faults; all zero/empty without a
    # fault plan — unfaulted results stay field-for-field identical):
    # pool-level terminal + recovery accounting.  A dead-lettered
    # arrival records placement -1.
    timed_out: int = 0
    shed: int = 0
    retries: int = 0
    failovers: int = 0
    dead_lettered: int = 0
    failover_placements: List = dataclasses.field(default_factory=list)

    @property
    def tasks(self) -> List[SimTask]:
        """All completed tasks, ordered by finish time (per-replica
        completion order is in ``replicas[r].tasks``)."""
        out = [t for r in self.replicas for t in r.tasks]
        out.sort(key=lambda t: t.finish)
        return out

    def placement_counts(self) -> List[int]:
        return [self.placements.count(r)
                for r in range(len(self.replicas))]

    def summary(self) -> Dict:
        return {
            "n_tasks": self.n_tasks,
            "replicas": len(self.replicas),
            "router_policy": self.router_policy,
            "makespan_s": self.makespan,
            "placement_counts": self.placement_counts(),
            "kv_rejected": sum(r.kv_rejected for r in self.replicas),
            "ttft_p50": self.ttft_p50,
            "ttft_p90": self.ttft_p90,
            "ttft_p99": self.ttft_p99,
            "itl_p50": self.itl_p50,
            "itl_p90": self.itl_p90,
            "itl_p99": self.itl_p99,
            "queue_wait_p50": self.queue_wait_p50,
            "queue_wait_p90": self.queue_wait_p90,
            "queue_wait_p99": self.queue_wait_p99,
        }


def simulate_replicated(tasks: Sequence[SimTask],
                        policy: sched_lib.Policy, *,
                        R: int = 1,
                        router=None,
                        xi: float = 2.0,
                        per_task_overhead_s: float = 0.0,
                        num_slots: Optional[int] = None,
                        kv_block_size: Optional[int] = None,
                        kv_num_blocks: Optional[int] = None,
                        prompt_len: int = 0,
                        prefill: str = "stall",
                        chunk_size: Optional[int] = None,
                        token_budget: Optional[int] = None,
                        prefix_cache: bool = False,
                        prompt_tokens=None,
                        decode_steps: int = 1,
                        faults=None,
                        obs=None) -> ReplicatedSimResult:
    """R independent continuous-batching replicas behind a front-end
    ``repro.serving.router.Router`` — the simulator twin of
    ``repro.serving.replica.ReplicatedEngine``.

    Every replica is a full ``_ReplicaSim`` with its OWN slot array, KV
    block budget (``kv_num_blocks`` is per replica), chunk scheduler
    and step clock; the driver advances them on a shared virtual clock:
    each turn either PLACES the next arrival (once every working
    replica's clock has reached the arrival time, so the router sees a
    causally consistent view) or ITERATES the furthest-behind working
    replica (ties broken by lowest replica id — the round-robin
    discipline that keeps replica clocks within one iteration of each
    other).  The SAME ``Router`` object the engine front-end drives
    scores ``ReplicaView``s built from live replica state, so placement
    decisions parity-match the engine bit for bit on all-at-t0 traces.

    Observability: the shared ``obs`` bundle is labeled with the active
    replica id around every delivery and iteration (R > 1 only — at
    R=1 the stream stays byte-identical to ``simulate_continuous``);
    a ``route`` event carrying ``{replica, score, policy}`` fires per
    placement.  ``TraceRecorder.parity_events(replica=r)`` recovers one
    replica's stream for per-replica parity assertions.

    Failure-aware serving (``faults`` — a
    ``repro.serving.faults.FaultPlan``): a ``FaultCoordinator`` gates
    every placement through the circuit breaker (with transient
    dispatch faults and half-open probes), each replica runs its
    ``ReplicaFaults`` slice (deadline timeouts, uncertainty-aware
    shedding, straggler slowdowns), and when a replica's local step
    counter reaches its crash point the driver evicts it, collects the
    unfinished requests and re-dispatches them through the coordinator
    (retry/backoff, failover or dead-letter).  ``ReplicatedEngine``
    drives the IDENTICAL coordinator call sequence, so every fault
    decision, counter and trace event parity-matches.  With
    ``faults=None`` no coordinator exists and this function is
    byte-identical to its pre-fault behavior.

    Returns a ``ReplicatedSimResult``: per-replica ``SimResult``s, the
    arrival-ordered placement list, and pool-level latency percentiles
    merged from the per-replica histograms.
    """
    from repro.serving.router import ReplicaView, Router

    if R < 1:
        raise ValueError(f"R must be >= 1, got {R}")
    if router is None:
        router = Router(R)
    if router.R != R:
        raise ValueError(f"router expects R={router.R}, got R={R}")
    pending = sorted(tasks, key=lambda t: t.r)
    n_total = len(pending)
    kv_model = kv_block_size is not None and kv_num_blocks is not None
    coord = None
    if faults is not None:
        from repro.serving.faults import FaultCoordinator
        if prefill != "stall":
            raise ValueError('faults require prefill="stall"')
        coord = FaultCoordinator(
            faults, R, router, obs,
            kv_num_blocks=kv_num_blocks if kv_model else 0)
    reps = [_ReplicaSim(policy, xi=xi,
                        per_task_overhead_s=per_task_overhead_s,
                        num_slots=num_slots,
                        kv_block_size=kv_block_size,
                        kv_num_blocks=kv_num_blocks,
                        prompt_len=prompt_len, prefill=prefill,
                        chunk_size=chunk_size, token_budget=token_budget,
                        prefix_cache=prefix_cache,
                        prompt_tokens=prompt_tokens,
                        decode_steps=decode_steps,
                        faults=(None if faults is None
                                else faults.for_replica(r)), obs=obs)
            for r in range(R)]
    reps[0].check_fits(pending)
    placements: List[int] = []
    label = obs is not None and R > 1
    i = 0

    def _terminals() -> int:
        return (sum(rep.terminal_count() for rep in reps)
                + (coord.dead_lettered if coord is not None else 0))

    while _terminals() < n_total:
        if coord is not None:
            for r in range(R):
                if not coord.should_crash(r, reps[r].step):
                    continue
                # the crash point: evict + collect survivors on the
                # dead replica, then re-dispatch them through the
                # coordinator (retry/backoff + health-gated failover,
                # dead-letter on budget exhaustion / no target)
                if label:
                    obs.replica_label = r
                try:
                    survivors = reps[r].crash()
                finally:
                    if label:
                        obs.replica_label = None
                coord.note_crash(r)
                descs = [coord.survivor(
                    task_id=_tid(t), u=t.u, cls=_cls(t), arrival=t.r,
                    need=(blocks_for_tokens(
                        prompt_len + max(1, t.true_out_len) - 1,
                        kv_block_size) if kv_model else 0),
                    payload=t) for t in survivors]
                for payload, tgt in coord.redispatch(
                        descs, from_replica=r):
                    tgt_rep = reps[tgt]
                    # causality: a failover delivery cannot precede
                    # the crash it recovers from
                    tgt_rep.now = max(tgt_rep.now, reps[r].now)
                    if label:
                        obs.replica_label = tgt
                    try:
                        tgt_rep.deliver(payload)
                    finally:
                        if label:
                            obs.replica_label = None
        workers = [r for r in range(R) if reps[r].has_work()]
        if i < n_total and all(reps[r].now + 1e-12 >= pending[i].r
                               for r in workers):
            # place the next arrival: every working replica's clock has
            # reached it, so the router's view is causally consistent
            t = pending[i]
            i += 1
            need = blocks_for_tokens(
                prompt_len + max(1, t.true_out_len) - 1,
                kv_block_size) if kv_model else 0
            views = [ReplicaView(replica=r,
                                 is_bulk=router.is_bulk(r),
                                 **reps[r].load())
                     for r in range(R)]
            if coord is not None:
                # health-gated placement; the coordinator emits the
                # route event itself and dead-letters (placement -1)
                # when gating empties the eligible set
                chosen = coord.place(views, task_id=_tid(t), u=t.u,
                                     cls=_cls(t), arrival=t.r,
                                     need=need)
                placements.append(-1 if chosen is None else chosen)
                if chosen is None:
                    continue
            else:
                d = router.place(views, u=t.u, cls=_cls(t), need=need)
                chosen = d.replica
                placements.append(chosen)
                if label:
                    obs.event("route", t.r, _tid(t), None,
                              replica=chosen, score=d.score,
                              policy=d.policy)
            rep = reps[chosen]
            rep.now = max(rep.now, t.r)
            if label:
                obs.replica_label = chosen
            try:
                rep.deliver(t)
            finally:
                if label:
                    obs.replica_label = None
            continue
        if not workers:
            # every replica is down and no arrival is placeable: the
            # crash block above dead-lettered the remaining work, so
            # the terminal count has already reached n_total
            break
        # iterate the furthest-behind working replica (lowest id wins
        # ties) — the shared-clock round-robin discipline
        r = min(workers, key=lambda k: (reps[k].now, k))
        rep = reps[r]
        if label:
            obs.replica_label = r
        try:
            if not rep.iterate():
                rep.advance_idle([pending[i].r] if i < n_total else [])
        finally:
            if label:
                obs.replica_label = None

    ttft_h, itl_h, qw_h = Histogram(), Histogram(), Histogram()
    for rep in reps:
        ttft_h.merge(rep.ttft_h)
        itl_h.merge(rep.itl_h)
        qw_h.merge(rep.qw_h)
    alldone = [t for rep in reps for t in rep.done]
    makespan = (max(t.finish for t in alldone)
                - min(t.r for t in alldone)) if alldone else 0.0
    return ReplicatedSimResult(
        replicas=[rep.result() for rep in reps],
        placements=placements,
        router_policy=router.policy,
        n_tasks=n_total,
        makespan=makespan,
        ttft_p50=ttft_h.quantile(0.50),
        ttft_p90=ttft_h.quantile(0.90),
        ttft_p99=ttft_h.quantile(0.99),
        itl_p50=itl_h.quantile(0.50),
        itl_p90=itl_h.quantile(0.90),
        itl_p99=itl_h.quantile(0.99),
        queue_wait_p50=qw_h.quantile(0.50),
        queue_wait_p90=qw_h.quantile(0.90),
        queue_wait_p99=qw_h.quantile(0.99),
        timed_out=sum(len(rep.timed_out) for rep in reps),
        shed=sum(len(rep.shed_tasks) for rep in reps),
        retries=coord.retries if coord is not None else 0,
        failovers=coord.failovers if coord is not None else 0,
        dead_lettered=coord.dead_lettered if coord is not None else 0,
        failover_placements=(list(coord.failover_placements)
                             if coord is not None else []))


# ---------------------------------------------------------------------------
# one-call experiment helper
# ---------------------------------------------------------------------------


def run_policy(tasks: Sequence[SimTask], policy_name: str,
               persona: Persona, pcfg: sched_lib.PolicyConfig, *,
               xi: float = 2.0, per_task_overhead_s: float = 0.0,
               mode: str = "batch", **continuous_kwargs) -> SimResult:
    """``continuous_kwargs`` (num_slots / kv_block_size / kv_num_blocks /
    prompt_len / prefill / chunk_size / token_budget) forward to
    ``simulate_continuous`` — the block-budget admission model of the
    paged KV cache and the chunked-prefill cost model."""
    import copy
    policy = sched_lib.POLICIES[policy_name](persona, pcfg)
    tasks = [copy.copy(t) for t in tasks]    # fresh timing fields
    if mode != "continuous":
        if continuous_kwargs:
            raise ValueError("kv/slot options only apply to continuous "
                             "mode")
        return simulate(tasks, policy, xi=xi,
                        per_task_overhead_s=per_task_overhead_s)
    return simulate_continuous(tasks, policy, xi=xi,
                               per_task_overhead_s=per_task_overhead_s,
                               **continuous_kwargs)
