"""Streaming metrics: counters, gauges, log-bucketed histograms.

The registry is the engine/simulator-shared half of the observability
substrate (``repro.obs``): both sides drive the same classes with the
same deterministic quantities (admission counts, chunk budget fills,
eviction-lag depths), so a counter — and even a histogram fed
bit-identical samples — compares bit-for-bit in the engine-vs-sim
parity tests, exactly like the dispatch counters in
``ServingEngine._result`` / ``SimResult``.

``Histogram`` is the replacement for the pooled-list percentile math
that used to live in ``_result``/``SimResult``: samples land in
log-spaced buckets (relative width ``growth - 1``), state is a sparse
``bucket index -> count`` dict that merges associatively, and
``quantile`` returns a deterministic estimate — the geometric midpoint
of the bucket holding the target order statistic, clamped to the exact
observed ``[min, max]`` — so a million-request simulation keeps O(num
buckets) state instead of every inter-token latency, while any
percentile stays within one bucket's relative width of the exact order
statistic (tests/test_obs.py pins the bound).
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, Optional


class Counter:
    """Monotonic event count."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def merge(self, other: "Counter") -> "Counter":
        self.value += other.value
        return self

    def snapshot(self):
        return self.value


class Gauge:
    """Last-written value plus running max/mean of every ``set``."""

    __slots__ = ("value", "max", "total", "n")

    def __init__(self) -> None:
        self.value = 0.0
        self.max = 0.0
        self.total = 0.0
        self.n = 0

    def set(self, v: float) -> None:
        v = float(v)
        self.value = v
        self.max = v if self.n == 0 else max(self.max, v)
        self.total += v
        self.n += 1

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def merge(self, other: "Gauge") -> "Gauge":
        if other.n:
            self.value = other.value          # other wrote last
            self.max = other.max if self.n == 0 else max(self.max,
                                                         other.max)
            self.total += other.total
            self.n += other.n
        return self

    def snapshot(self):
        return {"last": self.value, "max": self.max, "mean": self.mean}


class Histogram:
    """Log-bucketed streaming histogram with deterministic quantiles.

    Bucket ``k`` covers ``[growth**k, growth**(k+1))``; non-positive
    samples land in a dedicated zero bucket (latency metrics may
    legitimately record 0.0 — e.g. two tokens stamped at the same
    virtual-clock instant).  State is mergeable and associative:
    ``merge`` adds bucket counts, takes min/max of extremes, and the
    resulting quantiles are identical whichever way a set of shards is
    folded together (tests/test_obs.py::test_histogram_merge_*).

    ``quantile(q)`` locates the bucket containing order statistic
    ``ceil(q * (count - 1))`` (0-indexed) and returns its geometric
    midpoint clamped to the observed ``[min, max]`` — within a factor
    ``sqrt(growth)`` of that order statistic, i.e. a relative error of
    at most ``sqrt(growth) - 1`` (~2.5% at the default growth).
    """

    __slots__ = ("growth", "_log_g", "buckets", "zero_count", "count",
                 "total", "min", "max")

    #: default bucket growth: 5% relative bucket width
    GROWTH = 1.05

    #: bucket index for infinite observations — timed-out / shed
    #: requests record an ``inf`` e2e latency (a deadline miss never
    #: resolves), which must land in a dedicated overflow bucket
    #: rather than overflow the log-bucket index
    OVERFLOW_BUCKET = 1 << 62

    def __init__(self, growth: float = GROWTH) -> None:
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        self.growth = float(growth)
        self._log_g = math.log(self.growth)
        self.buckets: Dict[int, int] = {}
        self.zero_count = 0
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    # ------------------------------------------------------------------
    def _index(self, v: float) -> int:
        if math.isinf(v):
            return self.OVERFLOW_BUCKET
        return int(math.floor(math.log(v) / self._log_g))

    def record(self, v: float, n: int = 1) -> None:
        v = float(v)
        if n < 1:
            return
        if v > 0.0:
            k = self._index(v)
            self.buckets[k] = self.buckets.get(k, 0) + n
        else:
            self.zero_count += n
        self.count += n
        self.total += v * n
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def record_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.record(v)

    # ------------------------------------------------------------------
    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Deterministic quantile estimate (0.0 on an empty histogram).

        Rank rule: the 0-indexed order statistic ``ceil(q * (n - 1))``
        — the upper neighbour of numpy's linear-interpolation pair, so
        the estimate brackets ``np.quantile`` from above within one
        bucket's width.

        Edge cases are pinned by tests/test_slo.py: an out-of-range
        ``q`` raises even on an empty histogram, an empty histogram
        returns exactly 0.0 (never NaN — idle SLO windows rotate
        through here), and a single-observation histogram returns that
        observation exactly (the ``[min, max]`` clamp collapses).
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = math.ceil(q * (self.count - 1))
        if rank < self.zero_count:
            return max(0.0, self.min)
        seen = self.zero_count
        for k in sorted(self.buckets):
            seen += self.buckets[k]
            if rank < seen:
                rep = (math.inf if k >= self.OVERFLOW_BUCKET
                       else math.exp((k + 0.5) * self._log_g))
                return min(max(rep, self.min), self.max)
        return self.max                      # pragma: no cover - guard

    def quantiles(self, qs: Iterable[float]) -> Dict[float, float]:
        return {q: self.quantile(q) for q in qs}

    # ------------------------------------------------------------------
    def merge(self, other: "Histogram") -> "Histogram":
        if abs(other.growth - self.growth) > 1e-12:
            raise ValueError("cannot merge histograms with different "
                             f"growth ({self.growth} vs {other.growth})")
        for k, n in other.buckets.items():
            self.buckets[k] = self.buckets.get(k, 0) + n
        self.zero_count += other.zero_count
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def snapshot(self):
        if self.count == 0:
            return {"count": 0}
        return {"count": self.count, "mean": self.mean,
                "min": self.min, "max": self.max,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Name -> instrument map with get-or-create accessors.

    One registry per serve/simulation run.  ``merge`` folds another
    run's registry in (same-name instruments must be the same kind) —
    the fan-in primitive for sharded or repeated runs.
    """

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    # ------------------------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str,
                  growth: float = Histogram.GROWTH) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(growth)
        return h

    # ------------------------------------------------------------------
    def counters(self) -> Dict[str, int]:
        """Counter name -> value (the engine-vs-sim parity view: every
        counter both sides emit is fed deterministic quantities, so
        this dict compares with ``==``)."""
        return {k: c.value for k, c in sorted(self._counters.items())}

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        for k, c in other._counters.items():
            self.counter(k).merge(c)
        for k, g in other._gauges.items():
            self.gauge(k).merge(g)
        for k, h in other._hists.items():
            self.histogram(k, h.growth).merge(h)
        return self

    def snapshot(self) -> Dict[str, dict]:
        out: Dict[str, dict] = {}
        for k, c in sorted(self._counters.items()):
            out[k] = {"type": "counter", "value": c.snapshot()}
        for k, g in sorted(self._gauges.items()):
            out[k] = {"type": "gauge", **g.snapshot()}
        for k, h in sorted(self._hists.items()):
            out[k] = {"type": "histogram", **h.snapshot()}
        return out


def percentiles(values, registry: Optional[MetricsRegistry] = None,
                name: str = "", growth: float = Histogram.GROWTH
                ) -> Histogram:
    """Fold ``values`` into a (possibly registry-owned) histogram —
    the one-liner ``_result``/``SimResult`` use to rebase their
    percentile fields onto bucketed state."""
    h = registry.histogram(name, growth) if registry is not None \
        else Histogram(growth)
    h.record_many(values)
    return h
