"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 [arXiv:2501.kimi2].

Assignment row: [moe] 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8.  Per the K2 model card the first layer is
dense (d_ff 18432) and one shared expert accompanies the routed ones; the
assigned d_ff=2048 is the per-expert (moe) intermediate size.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    vocab_size=163840,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=18432,              # dense prefix layer
    mlp_act="swiglu",
    num_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    num_shared_experts=1,
    num_dense_layers=1,
    rope_theta=50_000.0,
    source="arXiv:2501.kimi2 (Kimi K2 tech report / model card)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b-smoke", family="moe", num_layers=2,
        d_model=256, vocab_size=2048, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, mlp_act="swiglu", num_experts=4,
        experts_per_token=2, moe_d_ff=128, num_shared_experts=1,
        num_dense_layers=1, source=CONFIG.source)
