"""Ambient sharding-policy context.

Model code is written once, device-layout-free; when a
:class:`repro.sharding.policy.ShardingPolicy` is active (``use_policy``),
``constrain(x, axes)`` lowers to ``jax.lax.with_sharding_constraint`` with
the policy's resolution of *logical* axis names to mesh axes; with no policy
active (single-device smoke tests) it is the identity.  This mirrors the
logical-axis-rules pattern of production JAX frameworks without threading a
mesh argument through every layer.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

_state = threading.local()


def current():
    return getattr(_state, "policy", None)


@contextlib.contextmanager
def use_policy(policy):
    prev = getattr(_state, "policy", None)
    _state.policy = policy
    try:
        yield policy
    finally:
        _state.policy = prev


def constrain(x, axes: Sequence[Optional[str]]):
    """Constrain array ``x`` with per-dim *logical* axis names (or None)."""
    policy = current()
    if policy is None:
        return x
    return policy.constrain(x, axes)
