"""Chunked-prefill scheduling (token-budgeted prefill/decode interleave).

Entry points:

  * ``ChunkScheduler`` — the host-side packer: per-iteration token
    budget filled with decode tokens first, then whole prefill chunks
    in the policy's uncertainty-priority order (FIFO tie-break).  Pure
    Python, JAX-free, and shared VERBATIM by the real serving engine
    (``ServingEngine(prefill="chunked")``) and the simulator
    (``simulate_continuous(prefill="chunked")``) — which is what makes
    their per-iteration budget traces comparable bit for bit.
  * ``ChunkJob`` / ``ChunkPlan`` — one admitted prompt's remaining
    work, and one scheduled chunk (start offset, length, finishes).
    With the prefix cache on, a job covers only the UNCACHED suffix of
    the prompt; the engine shifts plan offsets by the cached-prefix
    length.

Invariants (property-tested in tests/test_properties.py): scheduled
chunk tokens never exceed ``max(0, token_budget - decode_tokens)``;
each job's chunks cover ``[0, total)`` in order exactly once; whenever
jobs pend and a whole chunk fits, at least one chunk is scheduled (no
starvation — FIFO ties drain in admission order).

Kernel dispatch: each scheduled chunk executes through
``model.prefill_chunk`` → ``transformer.prefill_chunk_paged``, which
scatters the chunk's K/V into the paged pool at its exact position
offset (``kvcache.paged.scatter_chunk``) and attends
full-over-prefix / causal-in-chunk — on TPU via the Pallas
``kernels/chunked_prefill_attention.py`` kernel (block-table
scalar-prefetch), elsewhere via the exact jnp gather path
(``layers.chunked_attention`` over the gathered view), selected by
``use_pallas``.  Both are bit-identical to the stall prefill, so
chunking never changes greedy output.
"""

from .scheduler import ChunkJob, ChunkPlan, ChunkScheduler

__all__ = ["ChunkJob", "ChunkPlan", "ChunkScheduler"]
