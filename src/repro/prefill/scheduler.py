"""Chunked-prefill scheduler: token-budgeted prefill/decode interleaving.

The stall-admission continuous engine (serving/engine.py) blocks the
ENTIRE decode loop for a full ``(1, input_bucket)`` prefill on every
admission — a head-of-line source of inter-token-latency jitter that
grows with the admission burst size (C back-to-back prefills when C
slots free together).  Sarathi-style chunked prefill removes the stall:
each admitted request's (padded) prompt is split into fixed-size
chunks, and every engine iteration packs a TOKEN BUDGET with

    decode tokens first  (one per active decode slot — decode is never
                          skipped; it is the latency-critical work)
  + prefill-chunk tokens (as many whole chunks as fit in the remainder)

so per-iteration prefill work — and therefore the ITL of every in-flight
request — is bounded by ``token_budget`` instead of by the admission
burst.

Chunk ordering is the RT-LM twist: pending jobs are ranked by the
scheduling policy's uncertainty priority (``Policy.assign_priority``,
higher first; admission order breaks ties FIFO), so low-uncertainty
(short-output-predicted) requests reach their first token sooner — the
same signal that orders admission also orders time-to-first-token.

This module is pure host-side Python, deliberately free of JAX: the
real engine (``ServingEngine(prefill="chunked")``) and the simulator
(``simulate_continuous(prefill="chunked")``) drive the SAME scheduler,
which is what makes their per-iteration budget traces and completion
orders comparable bit-for-bit in the parity tests.

``pack_plans`` turns one iteration's plan list into a ``ChunkBatch`` —
the packed, padded layout the FUSED ragged prefill executable consumes
(one launch per iteration instead of one per chunk): adjacent plans of
the same job merge into one contiguous ragged chunk (so every chunk in
a launch belongs to a distinct sequence and the in-kernel K/V scatter
never races), and the batch's ``shape_key`` (padded total tokens,
padded chunk count, padded max chunk length — power-of-two buckets) is
the traced-executable memo key.  Both loops call it: the engine to
build the launch, the simulator to mirror the dispatch count and the
executable-cache hit/miss counters bit for bit.

Invariants (property-tested in tests/test_properties.py):

  * per-iteration budget: scheduled chunk tokens never exceed
    ``max(0, token_budget - decode_tokens)``;
  * in-order chunks: a job's chunks are scheduled at strictly
    increasing offsets covering ``[0, total)`` exactly once;
  * work conservation (no starvation): whenever jobs are pending and
    the budget remainder covers a whole chunk, at least one chunk is
    scheduled — under FIFO tie-break jobs therefore finish prefill in
    admission order and every job's wait is bounded by the backlog
    ahead of it.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class ChunkJob:
    """One admitted request's prefill work (the padded prompt bucket)."""

    task: object                 # prio.SimTask (engine) or SimTask (sim)
    slot: int                    # decode slot reserved for this request
    total: int                   # prompt tokens to prefill (input bucket)
    priority: float              # Policy.assign_priority at admission
    seq: int                     # admission order (FIFO tie-break)
    done: int = 0                # tokens prefetched so far
    added_at_call: int = 0       # scheduler call index at admission

    @property
    def remaining(self) -> int:
        return self.total - self.done

    def next_chunk_len(self, chunk_size: int) -> int:
        """Whole chunks of ``chunk_size``; the tail chunk is smaller."""
        return min(chunk_size, self.remaining)


@dataclasses.dataclass
class ChunkPlan:
    """One chunk to execute this iteration."""

    job: ChunkJob
    start: int                   # position offset of the chunk
    length: int
    finishes: bool               # True -> this chunk completes the prompt


def _pow2(n: int) -> int:
    """Smallest power of two >= n (bucketing for executable shapes)."""
    p = 1
    while p < n:
        p *= 2
    return p


def pow2_bucket(n: int) -> int:
    """Public alias of the shape-bucketing rule.  The stall-mode
    prefix-suffix launch (a single-chunk ``ChunkBatch`` equivalent)
    must compute the SAME shape key as ``pack_plans`` would — engine
    and simulator both derive ``(pow2(L), 1, pow2(L))`` for a suffix of
    L tokens from this function, keeping their executable-cache
    counters parity-comparable."""
    return _pow2(n)


def suffix_shape_key(suffix_len: int) -> tuple:
    """``ChunkBatch.shape_key`` of a single-chunk launch of
    ``suffix_len`` tokens — what ``pack_plans`` yields for one plan
    covering the whole suffix (the stall-mode prefix-cached admission
    path)."""
    p = _pow2(suffix_len)
    return (p, 1, p)


@dataclasses.dataclass
class PackedChunk:
    """One merged, contiguous ragged chunk of a ``ChunkBatch``.

    Adjacent same-job plans of one iteration merge into one chunk, so
    a batch never holds two chunks of the same sequence (the fused
    kernel's no-write-race invariant) and ``finishes`` is simply the
    last constituent plan's flag (a job's final chunk is always the
    last plan the scheduler emitted for it)."""

    job: ChunkJob
    start: int                   # job-relative offset of the merged run
    length: int
    finishes: bool

    @property
    def slot(self) -> int:
        return self.job.slot


@dataclasses.dataclass
class ChunkBatch:
    """One iteration's plans packed for a single fused launch.

    The padded sizes are power-of-two buckets so the engine's ragged
    prefill executable retraces once per ``shape_key`` instead of once
    per ``(chunk_len, offset)`` pair; the simulator computes the same
    keys from the same plans, which is what makes the executable-cache
    hit/miss counters engine-vs-sim comparable."""

    chunks: List[PackedChunk]
    total_tokens: int            # sum of merged chunk lengths
    padded_tokens: int           # total_tokens -> power-of-two bucket
    padded_chunks: int           # len(chunks) -> power-of-two bucket
    padded_chunk_len: int        # max chunk length -> power-of-two bucket

    @property
    def shape_key(self) -> tuple:
        return (self.padded_tokens, self.padded_chunks,
                self.padded_chunk_len)


def pack_plans(plans: List[ChunkPlan]) -> Optional[ChunkBatch]:
    """Merge one iteration's plans into the fused-launch batch.

    Returns None for an empty plan list.  Plan order is preserved
    (completion order of finishing chunks must match the per-chunk
    execution the parity tests compare against); merging only fuses
    ADJACENT plans of the same job, which the scheduler guarantees are
    contiguous (each job's chunks are emitted back to back within one
    ``schedule`` call)."""
    if not plans:
        return None
    chunks: List[PackedChunk] = []
    for plan in plans:
        if (chunks and chunks[-1].job is plan.job
                and chunks[-1].start + chunks[-1].length == plan.start):
            chunks[-1].length += plan.length
            chunks[-1].finishes = plan.finishes
        else:
            chunks.append(PackedChunk(job=plan.job, start=plan.start,
                                      length=plan.length,
                                      finishes=plan.finishes))
    total = sum(c.length for c in chunks)
    return ChunkBatch(
        chunks=chunks,
        total_tokens=total,
        padded_tokens=_pow2(total),
        padded_chunks=_pow2(len(chunks)),
        padded_chunk_len=_pow2(max(c.length for c in chunks)))


def build_packed_arrays(key: tuple,
                        entries: Sequence[Tuple[int, int, Sequence[int],
                                                Sequence[int]]],
                        *, pad_slot: int, table_width: int,
                        trash_block: int):
    """Build the fused executable's host arrays for one launch.

    The single authoritative encoding of the packed layout (the engine
    and the tests both call it): ``key`` is ``ChunkBatch.shape_key``;
    ``entries`` holds one ``(slot, ctx_len, tokens, table_row)`` tuple
    per merged chunk IN BATCH ORDER — ``tokens`` the chunk's 1-D token
    ids (length == chunk length), ``table_row`` its block table (at
    most ``table_width`` entries, missing tail filled with
    ``trash_block``).

    Returns int32 arrays ``(tokens (1, TTp), token_chunk (TTp,),
    meta (Cp, 4), tables (Cp, table_width))``: chunk ``ci`` owns packed
    columns ``off .. off+len-1`` with meta row
    ``[slot, ctx_len, chunk_len, q_offset]``; padding COLUMNS map to
    the last chunk row past its length (their scatter rows are dropped
    as invalid); padding CHUNK rows carry ``[pad_slot, 0, 0, off]``
    (``pad_slot`` out of range so their ``pos`` update drops) and
    all-trash tables (a scattered page is never revisited — the fused
    kernel's no-write-race contract).
    """
    TTp, Cp, _ = key
    tokens = np.zeros((1, TTp), np.int32)
    token_chunk = np.full((TTp,), Cp - 1, np.int32)
    meta = np.zeros((Cp, 4), np.int32)
    tables = np.full((Cp, table_width), trash_block, np.int32)
    off = 0
    for ci, (slot, ctx_len, toks, table_row) in enumerate(entries):
        ln = len(toks)
        tokens[0, off:off + ln] = toks
        token_chunk[off:off + ln] = ci
        meta[ci] = (slot, ctx_len, ln, off)
        tables[ci, :len(table_row)] = table_row
        off += ln
    for ci in range(len(entries), Cp):
        meta[ci] = (pad_slot, 0, 0, off)
    return tokens, token_chunk, meta, tables


class ChunkScheduler:
    """Token-budgeted chunk packer shared by engine and simulator.

    When a ``repro.obs`` ``MetricsRegistry`` is supplied, each
    ``schedule`` call with pending jobs records the iteration's budget
    utilization ((decode + scheduled chunk tokens) / token_budget) into
    the ``prefill.budget_fill`` histogram, and each job COMPLETING
    prefill records how many ``schedule`` calls it spent in the queue
    into ``prefill.queue_age_iters``.  Both quantities are functions of
    the scheduling decisions alone — the engine and the simulator drive
    the same scheduler, so these histograms compare bit-for-bit in the
    parity tests.
    """

    def __init__(self, chunk_size: int, token_budget: int, metrics=None):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        if token_budget < chunk_size:
            raise ValueError(
                f"token_budget={token_budget} < chunk_size={chunk_size}: "
                "an idle iteration could never fit one chunk and prefill "
                "would live-lock")
        self.chunk_size = chunk_size
        self.token_budget = token_budget
        self.jobs: List[ChunkJob] = []
        self._seq = 0
        self.metrics = metrics
        self._calls = 0

    # ------------------------------------------------------------------
    @property
    def has_jobs(self) -> bool:
        return bool(self.jobs)

    def slots_in_prefill(self) -> List[int]:
        return [j.slot for j in self.jobs]

    def add(self, task, slot: int, total: int, priority: float) -> ChunkJob:
        if total < 1:
            raise ValueError(f"total must be >= 1, got {total}")
        job = ChunkJob(task=task, slot=slot, total=total,
                       priority=priority, seq=self._seq,
                       added_at_call=self._calls)
        self._seq += 1
        self.jobs.append(job)
        return job

    def schedule(self, decode_tokens: int) -> List[ChunkPlan]:
        """Pack this iteration's budget; advances job progress.

        Decode tokens are charged first (decode always runs); the
        remainder is filled greedily in (priority desc, admission asc)
        order — a job may get several chunks in one iteration, and a
        lower-priority job's smaller tail chunk may ride along when the
        front-runner's next chunk no longer fits.  Completed jobs are
        removed; the caller executes the returned plans in order.
        """
        had_jobs = bool(self.jobs)
        rem = max(0, self.token_budget - decode_tokens)
        plans: List[ChunkPlan] = []
        for job in sorted(self.jobs, key=lambda j: (-j.priority, j.seq)):
            while job.remaining:
                length = job.next_chunk_len(self.chunk_size)
                if length > rem:
                    break
                plans.append(ChunkPlan(
                    job=job, start=job.done, length=length,
                    finishes=(job.remaining == length)))
                job.done += length
                rem -= length
        self.jobs = [j for j in self.jobs if j.remaining]
        if self.metrics is not None:
            if had_jobs:
                chunk_tokens = sum(p.length for p in plans)
                self.metrics.histogram("prefill.budget_fill").record(
                    (decode_tokens + chunk_tokens) / self.token_budget)
            for p in plans:
                if p.finishes:
                    self.metrics.histogram(
                        "prefill.queue_age_iters").record(
                            self._calls - p.job.added_at_call)
        self._calls += 1
        return plans
