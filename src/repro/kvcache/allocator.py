"""Host-side block allocator: free list + per-sequence block tables.

The allocator is deliberately dumb and exact — a list of free physical
block ids and a ``seq_id -> [block ids]`` table map.  All policy
(reservation-based admission, lazy boundary-crossing allocation) lives
in the serving engine / simulator; the allocator only enforces the two
hard invariants the property tests pin down:

  * a live block is owned by exactly one sequence (never double
    allocated until freed);
  * ``free_sequence`` returns every block of the sequence to the free
    list (no leaks — after a full ``serve()`` the pool is whole again).
"""

from __future__ import annotations

from typing import Dict, List


def blocks_for_tokens(num_tokens: int, block_size: int) -> int:
    """Memory formula: blocks needed to hold ``num_tokens`` KV entries.

    Shared by the engine's admission gate and the simulator's
    block-budget model — both must compute reservations identically or
    engine-vs-sim parity breaks.
    """
    if num_tokens <= 0:
        return 0
    return -(-num_tokens // block_size)


class OutOfBlocksError(RuntimeError):
    """Raised when an allocation is requested from an empty free list.

    With reservation-based admission this is a bug, not backpressure:
    the engine reserves a sequence's worst case up front, so a boundary
    crossing must never find the pool empty.
    """


class BlockAllocator:
    """Free-list allocator over ``num_blocks`` physical KV blocks."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # popped from the end so blocks hand out in ascending id order
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}
        self._owner: Dict[int, int] = {}

    # -- accounting ----------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def live_sequences(self) -> int:
        return len(self._tables)

    def utilization(self) -> float:
        return self.num_used / self.num_blocks

    def blocks_for(self, num_tokens: int) -> int:
        return blocks_for_tokens(num_tokens, self.block_size)

    # -- alloc / free --------------------------------------------------
    def allocate(self, seq_id: int) -> int:
        """Append one block to ``seq_id``'s table; returns the block id."""
        if not self._free:
            raise OutOfBlocksError(
                f"no free KV blocks (all {self.num_blocks} in use)")
        blk = self._free.pop()
        assert blk not in self._owner, f"block {blk} double-allocated"
        self._owner[blk] = seq_id
        self._tables.setdefault(seq_id, []).append(blk)
        return blk

    def allocate_n(self, seq_id: int, n: int) -> List[int]:
        if n > self.num_free:
            raise OutOfBlocksError(
                f"need {n} KV blocks, only {self.num_free} free")
        return [self.allocate(seq_id) for _ in range(n)]

    def table(self, seq_id: int) -> List[int]:
        """The sequence's block table (copy), empty if unknown."""
        return list(self._tables.get(seq_id, ()))

    def free_sequence(self, seq_id: int) -> int:
        """Return ALL of ``seq_id``'s blocks to the pool; returns count.

        Idempotent: freeing an unknown (or already-freed) sequence is a
        no-op — eviction paths need not track whether a sequence ever
        received blocks.
        """
        blocks = self._tables.pop(seq_id, None)
        if not blocks:
            return 0
        for blk in blocks:
            assert self._owner.pop(blk) == seq_id
            self._free.append(blk)
        return len(blocks)

    def check_no_leaks(self) -> None:
        """Assert the pool is whole (used by tests after a full serve)."""
        assert not self._tables and not self._owner, (
            f"leaked {self.num_used} blocks across "
            f"{self.live_sequences} sequences")
        assert sorted(self._free) == list(range(self.num_blocks))
