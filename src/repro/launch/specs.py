"""ShapeDtypeStruct input specs + step functions for the dry-run.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable
stand-ins for every model input of the given assigned input shape — no
device allocation ever happens; the dry-run lowers and compiles against
these specs only.

Step selection per shape.kind:
    train    -> train_step(params, opt_state, batch)
    prefill  -> prefill(params, batch)          (build cache + last logits)
    decode   -> decode_step(params, cache, tok) (ONE token, cache = seq_len)
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as model_lib, transformer
from repro.training import optimizer as opt_lib, train_step as ts_lib

SDS = jax.ShapeDtypeStruct


def _sds(shape, dtype):
    return SDS(tuple(shape), jnp.dtype(dtype))


def text_len(cfg: ModelConfig, shape: InputShape) -> int:
    """Token positions available for text after modality prefix tokens."""
    if cfg.frontend == "vision":
        return shape.seq_len - cfg.num_patch_tokens
    return shape.seq_len


def batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Train/prefill batch pytree of ShapeDtypeStructs."""
    B = shape.global_batch
    S = text_len(cfg, shape)
    batch = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32)
    if cfg.frontend == "vision":
        batch["patches"] = _sds((B, cfg.num_patch_tokens, cfg.d_model),
                                jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, cfg.encoder_seq_len, cfg.d_model),
                               jnp.bfloat16)
    return batch


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(model_lib.init_params, cfg=cfg),
        jax.random.PRNGKey(0))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, max_len, jnp.bfloat16))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Tuple[tuple, dict]:
    """Returns (args, meta) where args are the positional SDS arguments of
    the step function produced by ``make_step_fn``."""
    params = params_specs(cfg)
    if shape.kind == "train":
        opt = opt_lib.make_optimizer(
            opt_lib.default_optimizer_name(cfg), 3e-4)
        opt_state = jax.eval_shape(opt.init, params)
        return (params, opt_state, batch_specs(cfg, shape)), {}
    if shape.kind == "prefill":
        return (params, batch_specs(cfg, shape)), {}
    # decode: ONE new token against a cache of seq_len
    B = shape.global_batch
    cache = cache_specs(cfg, B, shape.seq_len)
    token = _sds((B, 1), jnp.int32)
    return (params, cache, token), {}


def make_step_fn(cfg: ModelConfig, shape: InputShape):
    """The function the dry-run lowers, matching input_specs' args."""
    if shape.kind == "train":
        opt = opt_lib.make_optimizer(
            opt_lib.default_optimizer_name(cfg), 3e-4)
        return ts_lib.make_train_step(cfg, opt, remat=True)
    if shape.kind == "prefill":
        S = text_len(cfg, shape) + (cfg.num_patch_tokens
                                    if cfg.frontend == "vision" else 0)

        def prefill_fn(params, batch):
            return model_lib.prefill(params, cfg, batch, max_len=S)

        return prefill_fn

    def decode_fn(params, cache, token):
        return model_lib.decode_step(params, cfg, cache, token)

    return decode_fn


def step_shardings(cfg: ModelConfig, shape: InputShape, policy):
    """(in_shardings, out_shardings, donate_argnums) for jit."""
    params = params_specs(cfg)
    p_sh = policy.param_shardings(params)
    if shape.kind == "train":
        opt = opt_lib.make_optimizer(
            opt_lib.default_optimizer_name(cfg), 3e-4)
        opt_state = jax.eval_shape(opt.init, params)
        o_sh = policy.opt_shardings(opt_state)
        b_sh = policy.batch_shardings(batch_specs(cfg, shape))
        metrics = {k: policy.replicated() for k in
                   ("loss", "xent", "tokens", "moe_aux_loss",
                    "moe_drop_frac", "grad_norm")}
        return ((p_sh, o_sh, b_sh), (p_sh, o_sh, metrics), (0, 1))
    if shape.kind == "prefill":
        b_sh = policy.batch_shardings(batch_specs(cfg, shape))
        # out = (cache, last_logits)
        cache = cache_specs(cfg, shape.global_batch,
                            text_len(cfg, shape)
                            + (cfg.num_patch_tokens
                               if cfg.frontend == "vision" else 0))
        c_sh = policy.cache_shardings(cache)
        lg_sh = policy.named(
            (shape.global_batch, cfg.padded_vocab), ("batch", "vocab"))
        return ((p_sh, b_sh), (c_sh, lg_sh), ())
    # decode
    cache = cache_specs(cfg, shape.global_batch, shape.seq_len)
    c_sh = policy.cache_shardings(cache)
    t_sh = policy.named((shape.global_batch, 1), ("batch", None))
    lg_sh = policy.named(
        (shape.global_batch, cfg.padded_vocab), ("batch", "vocab"))
    return ((p_sh, c_sh, t_sh), (t_sh, lg_sh, c_sh), (1,))
