"""SeamlessM4T-large-v2 — encoder-decoder, multimodal [arXiv:2308.11596].

Assignment row: [audio] 24L d_model=1024 16H (GQA kv=16 = MHA) d_ff=8192
vocab=256206.  Only the TRANSFORMER BACKBONE is implemented: the
mel-spectrogram + conformer feature extractor is a stub — input_specs()
provides precomputed frame embeddings (encoder_seq_len=4096) consumed by
a 24-layer bidirectional encoder; the 24-layer decoder cross-attends to
the encoder memory.  Full attention: long_500k skipped (DESIGN.md §4).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    d_model=1024,
    vocab_size=256206,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    mlp_act="gelu",
    num_encoder_layers=24,
    encoder_seq_len=4096,
    frontend="audio",
    tie_embeddings=False,
    source="arXiv:2308.11596 (SeamlessM4T)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2-smoke", family="encdec", num_layers=2,
        d_model=256, vocab_size=2048, num_heads=8, num_kv_heads=8,
        head_dim=32, d_ff=512, mlp_act="gelu", num_encoder_layers=2,
        encoder_seq_len=32, frontend="audio", tie_embeddings=False,
        source=CONFIG.source)
