"""EXPERIMENTS.md §Roofline table builder: reads the dry-run JSONs
(experiments/dryrun/*.json) and renders the per-(arch x shape x mesh)
three-term roofline with dominant-bottleneck calls."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.environ.get("RTLM_DRYRUN_OUT", "experiments/dryrun")


def load(dirname: str = DRYRUN_DIR) -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(rows: List[dict], *, multi_pod=False, fsdp=True,
          seq_parallel=False, serving=False) -> List[dict]:
    out = []
    for r in rows:
        if r.get("multi_pod") != multi_pod or r.get("fsdp", True) != fsdp:
            continue
        if r["status"] == "ok" and (
                bool(r.get("seq_parallel")) != seq_parallel
                or bool(r.get("serving")) != serving):
            continue
        if r["status"] != "ok":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "status": r["status"],
                        "reason": r.get("reason", r.get("error", ""))})
            continue
        roof = r["roofline"]
        mem = r["memory"]
        out.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_ms": round(roof["compute_s"] * 1e3, 1),
            "memory_ms": round(roof["memory_s"] * 1e3, 1),
            "collective_ms": round(roof["collective_s"] * 1e3, 1),
            "dominant": roof["dominant"],
            "useful_flops_ratio": round(roof["useful_flops_ratio"], 3),
            "GiB_per_dev": round(
                mem["resident_bytes_per_device"] / 2 ** 30, 1),
            "fits_16GiB": mem["resident_bytes_per_device"] <= 16 * 2 ** 30,
        })
    return out


def render_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute ms | memory ms | collective ms | "
           "dominant | useful-FLOPs | GiB/dev | fits |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']}: {r.get('reason','')[:60]} | — | "
                         f"— | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_ms']} | "
            f"{r['memory_ms']} | {r['collective_ms']} | {r['dominant']} | "
            f"{r['useful_flops_ratio']} | {r['GiB_per_dev']} | "
            f"{'✓' if r['fits_16GiB'] else '✗'} |")
    return hdr + "\n".join(lines)


def summary(rows: List[dict]) -> Dict[str, int]:
    ok = [r for r in rows if r["status"] == "ok"]
    return {
        "ok": len(ok),
        "skipped": sum(r["status"] == "skipped" for r in rows),
        "error": sum(r["status"] == "error" for r in rows),
        "compute_bound": sum(r["dominant"] == "compute" for r in ok),
        "memory_bound": sum(r["dominant"] == "memory" for r in ok),
        "collective_bound": sum(
            r["dominant"] == "collective" for r in ok),
        "fits": sum(r["fits_16GiB"] for r in ok),
    }
