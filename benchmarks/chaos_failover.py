"""Chaos benchmark: replica crash at the flash-crowd peak (PR 10).

A 4k-request flash-crowd trace (``workload.flash_crowd_trace``) is
served by an R=4 simulated pool under the rtlm router twice, with the
SAME seeded ``FaultPlan`` crashing replica 0 mid-burst:

  * **gated** — the full failure-aware stack: health-gated placement
    (the circuit breaker takes the dead replica out of the eligible
    set), retry/backoff + failover for its in-flight requests,
    per-request deadlines from the SLO e2e target, and
    uncertainty-aware load shedding under queue pressure;
  * **ungated** — the naive baseline: no health gating (the router
    keeps scoring the dead replica, and every dispatch to it burns the
    request), no failover (the crash's survivors dead-letter).

Both arms dead-letter loudly, never silently: the benchmark asserts
request conservation — completed + timed_out + shed + dead_lettered
== N — in each arm, so a lost request is an accounting bug, not noise.

The headline claim is asserted IN-benchmark at the pinned default
seed: the gated arm must beat the ungated arm on interactive e2e SLO
attainment AND lose strictly fewer requests to the crash.

    PYTHONPATH=src python -m benchmarks.chaos_failover [--seed N]
"""

from __future__ import annotations

import argparse
import time
import types

import numpy as np

from repro.core import (personas, priority as prio, scheduler as sched,
                        simulator, workload)
from repro.obs import Observability
from repro.serving.faults import (CrashFault, FaultPlan, RetryPolicy,
                                  ShedPolicy)
from repro.serving.router import Router

from . import common

SEED = 0
N_TASKS = 4_000
R = 4
SLOTS = 2                      # per replica
KV_BS = 16
KV_BLOCKS = 32                 # per replica
PROMPT = 16
XI = 0.1
OUT_MEAN = 24.0                # heavy-tailed output lengths, exp(mean)
OUT_CAP = 128
U_NOISE = 2.0                  # predictor noise (tokens, sigma)
BASE_BETA = 120.0              # queries/min
PEAK_BETA = 240.0
CRASH_STEP = 12_000            # replica-0 local decode step, mid-burst
PERSONA = "bart"

CLASS_SPEC = {
    "interactive": {"slo": {"ttft_s": 2.0, "e2e_s": 10.0}},
}


def _plan(gated: bool) -> FaultPlan:
    crash = CrashFault(0, CRASH_STEP)
    if gated:
        return FaultPlan(
            crashes=(crash,), retry=RetryPolicy(budget=3),
            shed=ShedPolicy(queue_depth=64), deadlines=True,
            failover=True, health_gating=True)
    return FaultPlan(crashes=(crash,), failover=False,
                     health_gating=False)


def _mk_tasks(n, arrivals, seed):
    rng = np.random.default_rng(seed)
    tasks = []
    for i in range(n):
        true = min(OUT_CAP, 1 + int(rng.exponential(OUT_MEAN)))
        u = max(0.5, true + float(rng.normal(0.0, U_NOISE)))
        tasks.append(prio.SimTask(
            task=types.SimpleNamespace(task_id=i,
                                       traffic_class="interactive"),
            u=u, r=float(arrivals[i]), d=float(arrivals[i]) + 4.0,
            input_len=float(PROMPT), true_out_len=true))
    return tasks


def _run_arm(gated, arrivals, targets, seed):
    persona = personas.get_persona(PERSONA)
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=35.0)
    obs = Observability(trace=False, metrics=True, slo=dict(targets))
    t0 = time.time()
    res = simulator.simulate_replicated(
        _mk_tasks(len(arrivals), arrivals, seed + 1),
        sched.POLICIES["rt-lm"](persona, pcfg), R=R,
        router=Router(R, "rtlm"), faults=_plan(gated), obs=obs,
        num_slots=SLOTS, kv_block_size=KV_BS, kv_num_blocks=KV_BLOCKS,
        prompt_len=PROMPT, xi=XI)
    completed = sum(len(rep.tasks) for rep in res.replicas)
    lost = res.timed_out + res.shed + res.dead_lettered
    # zero silent drops: every request reaches a counted terminal
    assert completed + lost == len(arrivals), \
        (completed, res.timed_out, res.shed, res.dead_lettered)
    assert res.replicas[0].crashed, "the chaos crash never fired"
    att = obs.slo.attainment()
    return {
        "gated": gated,
        "completed": completed,
        "timed_out": res.timed_out,
        "shed": res.shed,
        "dead_lettered": res.dead_lettered,
        "retries": res.retries,
        "failovers": res.failovers,
        "placement_counts": res.placement_counts(),
        "makespan_s": res.makespan,
        "interactive_e2e_attainment": att["interactive"]["e2e"]["frac"],
        "interactive_ttft_attainment": att["interactive"]["ttft"][
            "frac"],
        "windowed_attainment": obs.slo.windowed_attainment(),
        "fault_counters": {
            k: v for k, v in obs.metrics.counters().items()
            if k.startswith("faults.")},
        "wall_s": time.time() - t0,
    }


def main(seed=SEED):
    t0 = time.time()
    classes_decl = workload.make_traffic_classes(CLASS_SPEC)
    targets = workload.slo_targets(classes_decl)
    arrivals = workload.flash_crowd_trace(
        N_TASKS, base_beta=BASE_BETA, peak_beta=PEAK_BETA, seed=seed)

    gated = _run_arm(True, arrivals, targets, seed)
    ungated = _run_arm(False, arrivals, targets, seed)

    claim = {
        "gated_e2e_att": gated["interactive_e2e_attainment"],
        "ungated_e2e_att": ungated["interactive_e2e_attainment"],
        "gated_lost": (gated["timed_out"] + gated["shed"]
                       + gated["dead_lettered"]),
        "ungated_lost": (ungated["timed_out"] + ungated["shed"]
                         + ungated["dead_lettered"]),
        "asserted": seed == SEED,
    }
    if seed == SEED:
        # the acceptance claim, seed-pinned: health-gated failover
        # beats the no-gating baseline on the interactive SLO through
        # the same crash, and loses strictly fewer requests to it
        assert claim["gated_e2e_att"] > claim["ungated_e2e_att"], claim
        assert claim["gated_lost"] < claim["ungated_lost"], claim

    payload = {
        "seed": seed,
        "n_tasks": N_TASKS,
        "replicas": R,
        "num_slots": SLOTS,
        "kv": {"block_size": KV_BS, "num_blocks": KV_BLOCKS,
               "prompt_len": PROMPT},
        "trace": {"kind": "flash_crowd", "base_beta": BASE_BETA,
                  "peak_beta": PEAK_BETA},
        "workload": {"out_mean": OUT_MEAN, "out_cap": OUT_CAP,
                     "u_noise": U_NOISE},
        "crash": {"replica": 0, "at_step": CRASH_STEP},
        "classes": CLASS_SPEC,
        "arms": {"gated": gated, "ungated": ungated},
        "claim": claim,
    }
    common.save("chaos_failover", payload)
    common.emit(
        "chaos_failover", time.time() - t0,
        f"gated_att={claim['gated_e2e_att']:.4f},"
        f"ungated_att={claim['ungated_e2e_att']:.4f},"
        f"gated_lost={claim['gated_lost']},"
        f"ungated_lost={claim['ungated_lost']}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=SEED)
    main(seed=ap.parse_args().seed)
