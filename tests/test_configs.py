"""Config registry: every assigned arch, exact assignment rows, param
counts near the published sizes, smoke variants within the reduced caps."""

import pytest

from repro import configs

EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff-ish, vocab, ~params B, ~active B)
    "kimi-k2-1t-a32b": dict(L=61, d=7168, H=64, KV=8, V=163840,
                            N=(950e9, 1.1e12), A=(30e9, 36e9)),
    "minitron-4b": dict(L=32, d=3072, H=24, KV=8, V=256000,
                        N=(3.5e9, 5e9), A=None),
    "yi-6b": dict(L=32, d=4096, H=32, KV=4, V=64000,
                  N=(5.5e9, 6.5e9), A=None),
    "mixtral-8x22b": dict(L=56, d=6144, H=48, KV=8, V=32768,
                          N=(130e9, 145e9), A=(36e9, 42e9)),
    "h2o-danube-3-4b": dict(L=24, d=3840, H=32, KV=8, V=32000,
                            N=(3.3e9, 4.3e9), A=None),
    "starcoder2-3b": dict(L=30, d=3072, H=24, KV=2, V=49152,
                          N=(2.7e9, 3.4e9), A=None),
    "llava-next-mistral-7b": dict(L=32, d=4096, H=32, KV=8, V=32000,
                                  N=(6.5e9, 7.6e9), A=None),
    "mamba2-1.3b": dict(L=48, d=2048, H=None, KV=None, V=50280,
                        N=(1.2e9, 1.5e9), A=None),
    "seamless-m4t-large-v2": dict(L=24, d=1024, H=16, KV=16, V=256206,
                                  N=(1.2e9, 2.4e9), A=None),
    "recurrentgemma-9b": dict(L=38, d=4096, H=16, KV=1, V=256000,
                              N=(8e9, 10e9), A=None),
}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_assignment_row(arch):
    cfg = configs.get_config(arch)
    e = EXPECTED[arch]
    assert cfg.num_layers == e["L"]
    assert cfg.d_model == e["d"]
    assert cfg.vocab_size == e["V"]
    if e["H"] is not None:
        assert cfg.num_heads == e["H"]
        assert cfg.num_kv_heads == e["KV"]
    lo, hi = e["N"]
    assert lo <= cfg.param_count() <= hi, cfg.param_count()
    if e["A"]:
        lo, hi = e["A"]
        assert lo <= cfg.active_param_count() <= hi
    assert cfg.source, "every config must cite its source"


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_config_caps(arch):
    s = configs.get_smoke_config(arch)
    assert s.num_layers <= 3
    assert s.d_model <= 512
    assert s.num_experts <= 4
    assert s.family == configs.get_config(arch).family


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_padded_vocab_shardable(arch):
    cfg = configs.get_config(arch)
    assert cfg.padded_vocab % 2048 == 0
    assert cfg.padded_vocab % 32 == 0          # 16-way model x 2 pods
    assert cfg.padded_vocab >= cfg.vocab_size


def test_long_500k_policy():
    """long_500k runs iff decode state is bounded (DESIGN.md §4)."""
    shape = configs.INPUT_SHAPES["long_500k"]
    eligible = {a for a in configs.ARCH_IDS
                if configs.shape_applicable(configs.get_config(a), shape)[0]}
    assert eligible == {"mamba2-1.3b", "recurrentgemma-9b",
                        "mixtral-8x22b", "h2o-danube-3-4b"}


def test_input_shapes_exact():
    s = configs.INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len,
            s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len,
            s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len,
            s["long_500k"].global_batch) == (524288, 1)
