"""Minitron-4B — width/depth-pruned Nemotron-4 [arXiv:2407.14679].

Assignment row: [dense] 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000.  Nemotron uses squared-ReLU MLPs (approximated here by
relu; mlp_mult=2) and untied embeddings.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    vocab_size=256000,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    mlp_act="relu",
    tie_embeddings=False,
    source="arXiv:2407.14679 (Minitron / Compact LMs via pruning+distill)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b-smoke", family="dense", num_layers=2, d_model=256,
        vocab_size=2048, num_heads=8, num_kv_heads=2, head_dim=32, d_ff=512,
        mlp_act="relu", tie_embeddings=False, source=CONFIG.source)
