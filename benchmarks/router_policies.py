"""Multi-replica router policy sweep (PR 9 tentpole benchmark).

A 10k-request flash-crowd trace (``workload.flash_crowd_trace``: a
baseline Poisson stream whose middle quarter arrives at ~2.2x the pool
knee) is placed over R=4 simulated replicas by each router policy —
``rtlm`` vs ``least_queue`` vs ``round_robin`` — and judged on the
interactive-class tail: p99 TTFT (``SLOMonitor.lifetime_quantile``)
and windowed-SLO attainment fractions.

The regime is chosen where placement actually matters: few decode
slots per replica (2) and heavy-tailed output lengths (exp(24) capped
at 128 tokens), so one long request ties up half a replica — the
classic join-shortest-queue setting where a load-oblivious router
keeps hashing the burst uniformly while queue/uncertainty-aware
placement drains it around the backlog.  The uncertainty predictions
fed to ``rtlm`` carry realistic noise (sigma=2 tokens).

The headline claim is asserted IN-benchmark at the pinned default
seed: rtlm must beat round_robin on BOTH interactive p99 TTFT and
TTFT SLO attainment at R=4.  A secondary ``bulk_isolation`` record
demonstrates the bulk replica slice on a mixed interactive+batch
trace: batch-class requests confined to the designated replica,
interactive traffic never placed there.

    PYTHONPATH=src python -m benchmarks.router_policies [--seed N]
"""

from __future__ import annotations

import argparse
import time
import types

import numpy as np

from repro.core import (personas, priority as prio, scheduler as sched,
                        simulator, workload)
from repro.obs import Observability
from repro.serving.router import Router

from . import common

SEED = 0
N_TASKS = 10_000
R = 4
SLOTS = 2                      # per replica: the JSQ-sensitive regime
KV_BS = 16
KV_BLOCKS = 32                 # per replica
PROMPT = 16
XI = 0.1
OUT_MEAN = 24.0                # heavy-tailed output lengths, exp(mean)
OUT_CAP = 128
U_NOISE = 2.0                  # predictor noise (tokens, sigma)
BASE_BETA = 150.0              # queries/min; pool knee is ~330/min
PEAK_BETA = 330.0
PERSONA = "bart"

CLASS_SPEC = {
    "interactive": {"slo": {"ttft_s": 2.0, "e2e_s": 10.0}},
}
MIXED_SPEC = {
    "interactive": {"slo": {"ttft_s": 2.0, "e2e_s": 10.0},
                    "weight": 3.0},
    "batch": {"slo": {"e2e_s": 60.0}, "bulk": True},
}

POLICIES = ("round_robin", "least_queue", "rtlm")


def _mk_tasks(n, arrivals, classes, seed):
    """Heavy-tailed synthetic workload: true output lengths exp(24)
    capped at 128, predictions = truth + N(0, 2) noise (the router
    never sees the ground truth)."""
    rng = np.random.default_rng(seed)
    tasks = []
    for i in range(n):
        true = min(OUT_CAP, 1 + int(rng.exponential(OUT_MEAN)))
        u = max(0.5, true + float(rng.normal(0.0, U_NOISE)))
        tasks.append(prio.SimTask(
            task=types.SimpleNamespace(task_id=i,
                                       traffic_class=classes[i]),
            u=u, r=float(arrivals[i]), d=float(arrivals[i]) + 4.0,
            input_len=float(PROMPT), true_out_len=true))
    return tasks


def _run_arm(router, arrivals, classes, targets, seed):
    persona = personas.get_persona(PERSONA)
    pcfg = sched.PolicyConfig(u_scale=30.0, tau=35.0)
    obs = Observability(trace=False, metrics=False, slo=dict(targets))
    t0 = time.time()
    res = simulator.simulate_replicated(
        _mk_tasks(len(arrivals), arrivals, classes, seed + 1),
        sched.POLICIES["rt-lm"](persona, pcfg), R=R, router=router,
        obs=obs, num_slots=SLOTS, kv_block_size=KV_BS,
        kv_num_blocks=KV_BLOCKS, prompt_len=PROMPT, xi=XI)
    att = obs.slo.attainment()
    return {
        "policy": router.policy,
        "bulk_replicas": list(router.bulk_replicas),
        "placement_counts": res.placement_counts(),
        "makespan_s": res.makespan,
        "kv_rejected": sum(r.kv_rejected for r in res.replicas),
        "interactive_ttft_p50": obs.slo.lifetime_quantile(
            "interactive", "ttft", 0.50),
        "interactive_ttft_p99": obs.slo.lifetime_quantile(
            "interactive", "ttft", 0.99),
        "attainment": att,
        "pool_ttft_p99": res.ttft_p99,
        "pool_queue_wait_p99": res.queue_wait_p99,
        "wall_s": time.time() - t0,
    }, res


def run_sweep(seed=SEED):
    classes_decl = workload.make_traffic_classes(CLASS_SPEC)
    targets = workload.slo_targets(classes_decl)
    arrivals = workload.flash_crowd_trace(
        N_TASKS, base_beta=BASE_BETA, peak_beta=PEAK_BETA, seed=seed)
    cls = ["interactive"] * N_TASKS
    arms = {}
    for rp in POLICIES:
        arms[rp], _ = _run_arm(Router(R, rp), arrivals, cls, targets,
                               seed)
    return arms


def run_bulk_isolation(seed=SEED):
    """The bulk replica slice on a mixed trace: batch confined to
    replica R-1, interactive never placed there."""
    classes_decl = workload.make_traffic_classes(MIXED_SPEC)
    targets = workload.slo_targets(classes_decl)
    n = N_TASKS // 4
    cls = workload.assign_classes(n, classes_decl, seed=seed)
    arrivals = workload.flash_crowd_trace(
        n, base_beta=BASE_BETA, peak_beta=PEAK_BETA, seed=seed + 2)
    router = Router(R, "rtlm", bulk_replicas=(R - 1,),
                    bulk_classes=tuple(
                        workload.bulk_class_names(classes_decl)))
    arm, res = _run_arm(router, arrivals, cls, targets, seed)
    bulk_ok = all((res.placements[i] == R - 1) == (cls[i] == "batch")
                  for i in range(n))
    assert bulk_ok, "bulk-slice isolation violated"
    return {
        "n_tasks": n,
        "class_counts": {c: cls.count(c) for c in ("interactive",
                                                   "batch")},
        "isolation_holds": bulk_ok,
        **arm,
    }


def main(seed=SEED):
    t0 = time.time()
    arms = run_sweep(seed=seed)
    bulk = run_bulk_isolation(seed=seed)

    rtlm, rr = arms["rtlm"], arms["round_robin"]
    claim = {
        "rtlm_ttft_p99": rtlm["interactive_ttft_p99"],
        "round_robin_ttft_p99": rr["interactive_ttft_p99"],
        "rtlm_att_ttft": rtlm["attainment"]["interactive"]["ttft"][
            "frac"],
        "round_robin_att_ttft": rr["attainment"]["interactive"]["ttft"][
            "frac"],
        "asserted": seed == SEED,
    }
    if seed == SEED:
        # the acceptance claim, seed-pinned: uncertainty-aware routing
        # beats load-oblivious round-robin on the interactive tail
        assert claim["rtlm_ttft_p99"] < claim["round_robin_ttft_p99"], \
            claim
        assert claim["rtlm_att_ttft"] > claim["round_robin_att_ttft"], \
            claim

    payload = {
        "seed": seed,
        "n_tasks": N_TASKS,
        "replicas": R,
        "num_slots": SLOTS,
        "kv": {"block_size": KV_BS, "num_blocks": KV_BLOCKS,
               "prompt_len": PROMPT},
        "trace": {"kind": "flash_crowd", "base_beta": BASE_BETA,
                  "peak_beta": PEAK_BETA},
        "workload": {"out_mean": OUT_MEAN, "out_cap": OUT_CAP,
                     "u_noise": U_NOISE},
        "classes": CLASS_SPEC,
        "arms": arms,
        "bulk_isolation": bulk,
        "claim": claim,
    }
    common.save("router_policies", payload)
    common.emit(
        "router_policies", time.time() - t0,
        f"rtlm_p99={claim['rtlm_ttft_p99']:.3f}s,"
        f"rr_p99={claim['round_robin_ttft_p99']:.3f}s,"
        f"rtlm_att={claim['rtlm_att_ttft']:.4f},"
        f"rr_att={claim['round_robin_att_ttft']:.4f},"
        f"bulk_isolation={bulk['isolation_holds']}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=SEED)
    main(seed=ap.parse_args().seed)
