"""Kernel microbenchmarks: chunked-jnp substrate path wall-clock on CPU
(the Pallas kernels themselves are TPU artifacts; interpret mode is a
correctness harness, not a performance proxy — see EXPERIMENTS.md).

``ragged_prefill_bench`` measures the DISPATCH-count lever directly:
one fused ragged launch per iteration versus the pre-fused engine's
per-chunk loop (one jnp scatter + one attention call per chunk), both
on the exact jnp substrate paths — the regime where the real engine on
a CPU host pays O(#chunks) dispatch overhead per iteration."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kvcache import paged as paged_lib


def _time(fn, *args, reps=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def attention_bench():
    key = jax.random.PRNGKey(0)
    rows = {}
    for (B, S, H, KV, D) in [(1, 512, 8, 2, 64), (1, 1024, 8, 2, 64),
                             (2, 2048, 8, 8, 128)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
        t_sub = _time(ops.flash_attention, q, k, v, use_pallas=False)
        t_ref = _time(jax.jit(lambda a, b, c: ref.attention_ref(a, b, c)),
                      q, k, v)
        flops = 2 * 2 * B * H * S * S * D * 0.5
        rows[f"B{B}_S{S}_H{H}kv{KV}_D{D}"] = {
            "chunked_ms": round(t_sub * 1e3, 2),
            "naive_ms": round(t_ref * 1e3, 2),
            "chunked_gflops": round(flops / t_sub / 1e9, 1),
        }
    return rows


def _ragged_case(lens, *, H, KV, D, bs, nb, seed=0):
    """One iteration's worth of ragged chunks (mixed lengths, own block
    tables, ragged prior context) in both layouts: the fused padded
    batch and the per-chunk list."""
    C = len(lens)
    Tp = 1
    while Tp < max(lens):
        Tp *= 2
    N = C * nb + 1
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (C, Tp, H, D), jnp.float32)
    kn = jax.random.normal(ks[1], (C, Tp, KV, D), jnp.float32)
    vn = jax.random.normal(ks[2], (C, Tp, KV, D), jnp.float32)
    kp = jax.random.normal(ks[3], (N, bs, KV, D), jnp.float32)
    vp = jax.random.normal(ks[4], (N, bs, KV, D), jnp.float32)
    tables = jnp.arange(C * nb, dtype=jnp.int32).reshape(C, nb)
    rng = np.random.default_rng(seed + C)
    meta, off = [], 0
    for c, ln in enumerate(lens):
        ctx = int(rng.integers(0, nb * bs - ln + 1))
        meta.append([c, ctx, ln, off])
        off += ln
    return q, kn, vn, kp, vp, tables, jnp.asarray(meta, jnp.int32)


def ragged_prefill_bench(reps=20):
    """Fused one-launch ragged prefill vs the per-chunk loop the engine
    used to run (one ``scatter_chunk`` + one ``chunked_prefill_attention``
    call per chunk), at mixed chunk sizes and growing chunk counts.
    Both columns use the exact jnp substrate paths (``use_pallas=False``)
    — on this dispatch-bound CPU host the per-chunk column pays
    2 * #chunks jitted dispatches per iteration where the fused column
    pays one."""
    H, KV, D, bs, nb = 4, 2, 32, 16, 10
    sizes = [16, 64, 128]

    import functools

    # one executable per chunk LENGTH, as the pre-fused engine traced
    @functools.partial(jax.jit, static_argnames=("ln",))
    def per_chunk_once(q, kn, vn, kp, vp, table_row, ctx, *, ln):
        nk = paged_lib.scatter_chunk(kp, kn[:ln], table_row, ctx)
        nv = paged_lib.scatter_chunk(vp, vn[:ln], table_row, ctx)
        out = ops.chunked_prefill_attention(
            q[None, :ln], nk, nv, table_row[None], ctx[None],
            use_pallas=False)
        return out, nk, nv

    rows = {}
    for C in (1, 2, 4, 8, 16):
        lens = [sizes[i % len(sizes)] for i in range(C)]
        q, kn, vn, kp, vp, tables, meta = _ragged_case(
            lens, H=H, KV=KV, D=D, bs=bs, nb=nb, seed=C)

        def fused():
            return ops.ragged_chunked_prefill(
                q, kn, vn, kp, vp, tables, meta, use_pallas=False)

        def loop():
            nk, nv = kp, vp
            outs = []
            for c, ln in enumerate(lens):
                out, nk, nv = per_chunk_once(
                    q[c], kn[c], vn[c], nk, nv, tables[c],
                    meta[c, 1], ln=ln)
                outs.append(out)
            return outs, nk, nv

        t_fused = _time(fused, reps=reps)
        t_loop = _time(loop, reps=reps)
        rows[f"C{C}_mixed{min(lens)}-{max(lens)}"] = {
            "num_chunks": C,
            "chunk_lens": lens,
            "fused_ms": round(t_fused * 1e3, 3),
            "per_chunk_ms": round(t_loop * 1e3, 3),
            "fused_dispatches": 1,
            "per_chunk_dispatches": 2 * C,
            "speedup": round(t_loop / t_fused, 2),
        }
    return rows


def rmsnorm_bench():
    key = jax.random.PRNGKey(1)
    rows = {}
    for (N, D) in [(4096, 1024), (16384, 4096)]:
        x = jax.random.normal(key, (N, D), jnp.float32)
        w = jnp.zeros(D)
        t = _time(ops.rms_norm, x, w, use_pallas=False)
        gbps = 2 * x.nbytes / t / 1e9
        rows[f"N{N}_D{D}"] = {"ms": round(t * 1e3, 3),
                              "effective_GBps": round(gbps, 1)}
    return rows
