"""Decode-path consistency: incremental decode == full forward pass.

The strongest correctness property of the serving substrate: greedy
decoding one token at a time against the KV/recurrent cache must produce
the same logits as re-running the full sequence through the train-mode
forward pass.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers, model as model_lib, transformer
from repro.serving import generate
from repro.training import data as data_lib

ARCHS = ["yi-6b", "h2o-danube-3-4b", "mixtral-8x22b", "mamba2-1.3b",
         "recurrentgemma-9b", "seamless-m4t-large-v2"]


def full_logits(params, cfg, batch):
    """Train-mode forward, returning per-position logits (B, S, V)."""
    x, ctx, n_prefix = model_lib._decoder_inputs(params, cfg, batch)
    x, _, _ = transformer.apply_stack(params["stack"], x, ctx, cfg,
                                      None, "train")
    x = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:]
    return layers.logits(params["embed"], x, cfg).astype(jnp.float32)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch, rng_key):
    cfg = configs.get_smoke_config(arch)
    params = model_lib.init_params(rng_key, cfg)
    B, S, T = 2, 12, 5
    tokens = jax.random.randint(rng_key, (B, S + T), 0, cfg.vocab_size)
    prompt = {"tokens": tokens[:, :S]}
    prompt = data_lib.add_modality_stub(prompt, cfg)

    cache, last_logits = model_lib.prefill(params, cfg, prompt,
                                           max_len=S + T + 1)
    dec_logits = [last_logits]
    for t in range(T):
        tok = tokens[:, S + t:S + t + 1]
        _, lg, cache = model_lib.decode_step(params, cfg, cache, tok)
        dec_logits.append(lg)
    dec_logits = jnp.stack(dec_logits, axis=1)       # (B, T+1, V)

    full_batch = dict(prompt, tokens=tokens)
    want = full_logits(params, cfg, full_batch)[:, S - 1:S + T]
    np.testing.assert_allclose(
        dec_logits[..., :cfg.vocab_size], want[..., :cfg.vocab_size],
        atol=0.15, rtol=0.05)  # bf16 params, f32 logits


def test_generate_eos_early_exit(rng_key):
    cfg = configs.get_smoke_config("yi-6b")
    params = model_lib.init_params(rng_key, cfg)
    tokens = jax.random.randint(rng_key, (3, 8), 0, cfg.vocab_size)
    out, lengths = generate.generate(params, cfg, {"tokens": tokens},
                                     max_new_tokens=12, eos_id=1)
    assert out.shape[0] == 3 and out.shape[1] <= 12
    assert (lengths >= 1).all() and (lengths <= 12).all()


def test_generate_scan_matches_generate(rng_key):
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(rng_key, cfg)
    tokens = jax.random.randint(rng_key, (2, 10), 2, cfg.vocab_size)
    T = 6
    scan_toks = generate.generate_scan(params, cfg, {"tokens": tokens},
                                       max_new_tokens=T)
    loop_toks, _ = generate.generate(
        params, cfg, {"tokens": tokens}, max_new_tokens=T,
        eos_id=-1)  # no eos -> full length
    np.testing.assert_array_equal(np.asarray(scan_toks)[:, :T],
                                  np.asarray(loop_toks)[:, :T])
