"""Top-level model API: config -> init / train-loss / prefill / decode.

This is the single entry point the training loop, the serving engine and the
multi-pod dry-run all share:

  * ``init_params(key, cfg)``                 parameter pytree
  * ``lm_loss(params, cfg, batch)``           causal-LM loss for train_step
  * ``prefill(params, cfg, batch, max_len)``  build KV/recurrent cache
  * ``decode_step(params, cfg, cache, tok)``  one greedy decode step

Batch layout per family:
  dense / moe / ssm / hybrid :  {"tokens": (B, S) i32, "labels": (B, S) i32}
  vlm   : + {"patches": (B, num_patch_tokens, D) bf16} (stub ViT frontend);
          tokens/labels cover the S - num_patch_tokens text positions.
  encdec: + {"frames": (B, encoder_seq_len, D) bf16} (stub audio frontend);
          tokens/labels are the decoder sequence.

Labels < 0 are ignored in the loss.  Logit positions >= cfg.vocab_size
(vocab padding for shardability) are masked to -inf.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding import context as shctx

from . import layers, multimodal, transformer

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _param_dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def init_encoder(key: Array, cfg, dtype) -> dict:
    """Bidirectional encoder stack (seamless audio backbone)."""
    n = cfg.num_encoder_layers
    keys = jax.random.split(key, n)
    blocks = jax.vmap(
        lambda k: transformer.init_attn_mlp_block(k, cfg, dtype))(keys)
    return {"blocks": blocks, "final_ln": jnp.zeros((cfg.d_model,), dtype)}


def init_params(key: Array, cfg) -> dict:
    dtype = _param_dtype(cfg)
    k_embed, k_stack, k_enc, k_front = jax.random.split(key, 4)
    params = {
        "embed": layers.init_embedding(k_embed, cfg, dtype),
        "stack": transformer.init_stack(k_stack, cfg, dtype),
        "final_ln": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.family == "encdec":
        params["encoder"] = init_encoder(k_enc, cfg, dtype)
    if cfg.frontend or cfg.family == "encdec":
        params["frontend_proj"] = multimodal.init_projector(
            k_front, cfg, dtype)
    return params


# ---------------------------------------------------------------------------
# encoder forward (bidirectional, scanned)
# ---------------------------------------------------------------------------


def encode(params: dict, cfg, frames: Array, *, remat: bool = False) -> Array:
    """frames: (B, Te, D) stub frontend embeddings -> encoder memory."""
    x = multimodal.apply_projector(params["frontend_proj"], frames)
    x = shctx.constrain(x, ("batch", None, None))
    Te = x.shape[1]
    positions = jnp.arange(Te)

    def body(x, p):
        x = shctx.constrain(x, ("batch", "seq", None))
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = layers.attention_qkv(p["attn"], h, positions,
                                       cfg.rope_theta)
        attn = layers.chunked_attention(
            q, k, v, q_positions=positions, kv_positions=positions,
            causal=False)
        x = x + layers.attention_out(p["attn"], attn)
        x = x + layers.apply_mlp(
            p["mlp"], layers.rms_norm(x, p["ln2"], cfg.norm_eps),
            cfg.mlp_act)
        return x, None

    # without remat the encoder scan saves every per-layer attention
    # intermediate for the backward pass — tens of GiB at train_4k
    x, _ = lax.scan(jax.checkpoint(body) if remat else body, x,
                    params["encoder"]["blocks"])
    return layers.rms_norm(x, params["encoder"]["final_ln"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder input assembly
# ---------------------------------------------------------------------------


def _decoder_inputs(params: dict, cfg, batch: dict, *, remat: bool = False):
    """Returns (x, ctx, num_prefix) where num_prefix is the count of
    non-text positions (VLM patches) prepended before the tokens."""
    tokens = batch["tokens"]
    x = layers.embed(params["embed"], tokens, cfg)
    num_prefix = 0
    if cfg.frontend == "vision":
        patches = multimodal.apply_projector(
            params["frontend_proj"], batch["patches"]).astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        num_prefix = patches.shape[1]
    x = shctx.constrain(x, ("batch", None, None))
    S = x.shape[1]
    ctx = {"positions": jnp.arange(S), "enc_out": None}
    if cfg.family == "encdec":
        ctx["enc_out"] = encode(params, cfg, batch["frames"], remat=remat)
    return x, ctx, num_prefix


# ---------------------------------------------------------------------------
# loss (chunked over sequence so (B, S, V) logits never materialize)
# ---------------------------------------------------------------------------


def chunked_xent(params: dict, cfg, x: Array, labels: Array,
                 chunk: int = 1024):
    """x: (B, S, D) final hidden states; labels: (B, S) (<0 = ignore).

    Computes sum of per-token NLL and the token count, scanning over
    sequence chunks: peak logits memory is (B, chunk, V) instead of
    (B, S, V) — for the 1T MoE at train_4k that is the difference between
    ~343 MB/device and ~2.7 GB/device.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xs = x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        nll_sum, count = carry
        xc, lc = inp
        logits = layers.logits(params["embed"], xc, cfg)     # (B, c, V) f32
        logits = shctx.constrain(logits, ("batch", None, "vocab"))
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        nll = (logz - ll) * mask
        return (nll_sum + nll.sum(), count + mask.sum()), None

    (nll_sum, count), _ = lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (xs, ls))
    return nll_sum, count


def lm_loss(params: dict, cfg, batch: dict, *, remat: bool = False):
    """Causal-LM loss. Returns (loss, metrics)."""
    x, ctx, num_prefix = _decoder_inputs(params, cfg, batch, remat=remat)
    x, _, aux = transformer.apply_stack(
        params["stack"], x, ctx, cfg, cache=None, mode="train", remat=remat)
    x = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    if num_prefix:
        x = x[:, num_prefix:]
    # gather the (possibly sequence-sharded) final hiddens: chunked_xent
    # scans over sequence chunks, and scanning over a sharded dim would
    # force GSPMD reshards inside the loop.
    x = shctx.constrain(x, ("batch", None, None))
    # next-token prediction: hidden state at position t predicts labels[t]
    nll_sum, count = chunked_xent(params, cfg, x, batch["labels"])
    xent = nll_sum / jnp.maximum(count, 1.0)
    loss = xent + aux["moe_aux_loss"]
    metrics = {
        "loss": loss,
        "xent": xent,
        "tokens": count,
        "moe_aux_loss": aux["moe_aux_loss"],
        "moe_drop_frac": aux["moe_drop_frac"],
    }
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: prefill + single-step decode
# ---------------------------------------------------------------------------


def prefill(params: dict, cfg, batch: dict, max_len: int,
            cache_dtype=jnp.bfloat16):
    """Run the prompt through the stack, building the decode cache.

    Returns (cache, last_logits) where last_logits: (B, V) are the logits
    at the final prompt position (the sampler consumes them).
    """
    x, ctx, _ = _decoder_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    cache = transformer.init_cache(cfg, B, max_len, cache_dtype)
    x, new_cache, _ = transformer.apply_stack(
        params["stack"], x, ctx, cfg, cache=cache, mode="prefill")
    x = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    last = x[:, -1]
    last_logits = layers.logits(params["embed"], last[:, None], cfg)[:, 0]
    # global decode bookkeeping
    cap = cache["slot_pos"].shape[0]
    new_cache["pos"] = jnp.asarray(S, jnp.int32)
    new_cache["slot_pos"] = transformer.prefill_slot_pos(cap, S)
    return new_cache, last_logits.astype(jnp.float32)


def decode_step(params: dict, cfg, cache: dict, token: Array):
    """One greedy decode step.

    token: (B, 1) i32 — the token sampled from the previous step's logits.
    Returns (next_token (B, 1) i32, logits (B, V) f32, new_cache).

    Works on both cache layouts: batch-mode (scalar ``pos``, (W,)
    ``slot_pos`` — every row at the same position) and per-slot
    continuous-batching caches from ``transformer.init_slot_cache``
    (``pos`` (B,), ``slot_pos`` (B, W) — independent sequences).
    """
    x = layers.embed(params["embed"], token, cfg)
    x = shctx.constrain(x, ("batch", None, None))
    ctx = {"pos": cache["pos"], "slot_pos": cache["slot_pos"]}
    x, new_cache, _ = transformer.apply_stack(
        params["stack"], x, ctx, cfg, cache=cache, mode="decode")
    x = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = layers.logits(params["embed"], x, cfg)[:, 0]
    logits = logits.astype(jnp.float32)
    next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    # global bookkeeping (per-layer caches already updated in the stack)
    cap = cache["slot_pos"].shape[-1]
    pos = cache["pos"]
    new_cache["pos"] = pos + 1
    if pos.ndim:
        rows = jnp.arange(pos.shape[0])
        new_cache["slot_pos"] = cache["slot_pos"].at[rows, pos % cap].set(pos)
    else:
        new_cache["slot_pos"] = cache["slot_pos"].at[pos % cap].set(pos)
    return next_token, logits, new_cache


def decode_step_paged(params: dict, cfg, cache: dict, token: Array,
                      tables: Array, *, use_pallas: bool = False):
    """One greedy decode step against a paged KV cache.

    cache: from ``transformer.init_paged_cache`` (per-layer page pools
    + per-slot ``pos`` (B,)); tables: (B, nb) i32 block tables (host
    state of the engine's allocator, passed per step so boundary
    crossings need no cache rebuild).  Same contract as ``decode_step``:
    returns (next_token (B, 1) i32, logits (B, V) f32, new_cache).

    ``use_pallas`` (static) routes each layer's attention through the
    Pallas ``paged_decode_attention`` kernel instead of the transient
    contiguous gather — the production TPU path (interpret-mode
    emulation elsewhere); outputs match the gather path.
    """
    x = layers.embed(params["embed"], token, cfg)
    x = shctx.constrain(x, ("batch", None, None))
    ctx = {"pos": cache["pos"], "tables": tables, "use_pallas": use_pallas}
    x, new_cache, _ = transformer.apply_stack(
        params["stack"], x, ctx, cfg, cache=cache, mode="decode")
    x = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = layers.logits(params["embed"], x, cfg)[:, 0]
    logits = logits.astype(jnp.float32)
    next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    new_cache["pos"] = cache["pos"] + 1
    return next_token, logits, new_cache


def decode_steps(params: dict, cfg, cache: dict, token: Array, *,
                 num_steps: int):
    """``num_steps`` greedy decode steps as ONE ``lax.scan`` launch (the
    async host pipeline's multi-step decode window).

    token: (B, 1) i32 — the previous step's sampled token for every row.
    Returns (tokens (B, num_steps) i32, new_cache): column j holds the
    token emitted by window step j; the last column is the next window's
    input.  The scan body is exactly ``decode_step``, so ``num_steps=1``
    is bit-identical to a single step — the engine's N=1 parity default.

    EOS/cap handling stays on the HOST at window end (in arrears): every
    row is stepped all ``num_steps`` times, and the caller discards the
    columns past a sequence's logical end.  The overhang writes are
    harmless by the eviction-lag invariant (``kvcache.allocator.
    window_target_tokens``) — the contiguous ring confines them to the
    dead row, the paged scatter clamps them onto the trash page.
    """
    def body(carry, _):
        tok, c = carry
        nt, _, c = decode_step(params, cfg, c, tok)
        return (nt, c), nt[:, 0]

    (_, new_cache), toks = lax.scan(
        body, (token, cache), None, length=num_steps)
    return toks.T, new_cache                          # (B, num_steps)


def decode_steps_paged(params: dict, cfg, cache: dict, token: Array,
                       tables: Array, *, num_steps: int,
                       use_pallas: bool = False):
    """Paged twin of ``decode_steps``: ``num_steps`` ``decode_step_paged``
    iterations in one ``lax.scan`` launch against the page pool.

    The block tables are fixed for the WHOLE window — the engine extends
    every active slot's table to ``window_target_tokens`` before the
    launch, so each step's scatter lands in a pre-backed (or trash)
    block and no host round-trip interrupts the scan.
    """
    def body(carry, _):
        tok, c = carry
        nt, _, c = decode_step_paged(params, cfg, c, tok, tables,
                                     use_pallas=use_pallas)
        return (nt, c), nt[:, 0]

    (_, new_cache), toks = lax.scan(
        body, (token, cache), None, length=num_steps)
    return toks.T, new_cache                          # (B, num_steps)


def prefill_into_paged(params: dict, cfg, cache: dict, batch: dict, slot,
                       table_row, max_len: int, cache_dtype=jnp.bfloat16):
    """Prefill ONE request (batch dim 1) and scatter its KV into the
    paged cache's blocks ``table_row`` (nb,) i32, marking ``slot``'s
    position (paged continuous-batching admission).  Returns
    (new_cache, last_logits (V,)).  Requires ``paged_supported(cfg)``
    (full attention, positions 0..S-1 land at prefill rows 0..S-1).
    """
    one, last_logits = prefill(params, cfg, batch, max_len, cache_dtype)
    S = batch["tokens"].shape[1]
    new_cache = transformer.write_paged(cache, one, slot, table_row, S)
    return new_cache, last_logits[0]


def prefill_chunk(params: dict, cfg, cache: dict, batch: dict, slot,
                  table_row, ctx_len, *, use_pallas: bool = False):
    """Run ONE chunk of a request's prompt against the paged cache.

    batch: {"tokens": (1, T)} — the chunk's token slice; ctx_len: traced
    i32 scalar, how many prompt tokens were already prefilled (the chunk
    occupies absolute positions ``ctx_len .. ctx_len + T - 1``);
    table_row: (nb,) i32 the sequence's block table (all of the prompt's
    blocks are allocated at admission, so every chunk position is
    backed).  Each attention layer scatters the chunk's K/V into the
    page pool at the correct position offset and attends full over the
    already-written prefix, causal within the chunk — per-position
    numerics match the stall-admission full prefill, so the final
    chunk's ``last_logits`` produce the identical first token.

    Returns (new_cache, last_logits (V,) f32) with ``pos[slot]`` set to
    ``ctx_len + T``; only the FINAL chunk's logits are meaningful to
    the sampler (they sit at the prompt's last position).  Requires
    ``transformer.paged_supported(cfg)``.
    """
    tokens = batch["tokens"]
    T = tokens.shape[1]
    x = layers.embed(params["embed"], tokens, cfg)
    x = shctx.constrain(x, ("batch", None, None))
    positions = (jnp.asarray(ctx_len, jnp.int32)
                 + jnp.arange(T, dtype=jnp.int32))
    x, new_cache, _ = transformer.prefill_chunk_paged(
        params["stack"], x, positions, table_row, cfg, cache,
        use_pallas=use_pallas)
    x = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    last = x[:, -1]
    last_logits = layers.logits(params["embed"], last[:, None], cfg)[:, 0]
    new_cache["pos"] = cache["pos"].at[slot].set(positions[-1] + 1)
    return new_cache, last_logits[0].astype(jnp.float32)


def prefill_chunks(params: dict, cfg, cache: dict, batch: dict,
                   token_chunk, meta, tables, *, chunk_pad: int,
                   use_pallas: bool = False):
    """Run EVERY scheduled prefill chunk of one engine iteration at
    once against the paged cache — the fused replacement for a loop of
    ``prefill_chunk`` calls (one launch per iteration, O(1) host
    dispatches instead of O(#chunks)).

    batch: {"tokens": (1, TT)} — the iteration's chunks PACKED back to
    back (chunk ``c`` owns columns ``q_off[c] .. q_off[c]+len[c]-1``)
    and padded to the executable's token bucket; token_chunk: (TT,)
    i32 mapping each packed column to its chunk row; meta: (C, 4) i32
    rows ``[slot, ctx_len, chunk_len, q_offset]`` (padding chunks:
    ``chunk_len == 0`` and ``slot`` out of range so their ``pos``
    update drops); tables: (C, nb) i32 per-chunk block tables;
    chunk_pad: STATIC padded max chunk length (the per-chunk view
    width).  Per-position numerics match sequential ``prefill_chunk``
    calls bit for bit (tests/test_chunked_prefill.py), so fusing never
    changes greedy output.

    Returns (new_cache, last_logits (C, V) f32): row ``c`` holds the
    logits at chunk ``c``'s LAST position — meaningful to the sampler
    only for chunks that finish their prompt.  ``pos[slot]`` is set to
    ``ctx_len + chunk_len`` for every real chunk.  Requires
    ``transformer.paged_supported(cfg)``.
    """
    tokens = batch["tokens"]
    TT = tokens.shape[1]
    token_chunk = jnp.asarray(token_chunk, jnp.int32)
    meta = jnp.asarray(meta, jnp.int32)
    slots, ctx_lens, lens, q_off = (meta[:, 0], meta[:, 1], meta[:, 2],
                                    meta[:, 3])
    local = jnp.arange(TT, dtype=jnp.int32) - q_off[token_chunk]
    positions = ctx_lens[token_chunk] + local
    valid = local < lens[token_chunk]
    x = layers.embed(params["embed"], tokens, cfg)
    x = shctx.constrain(x, ("batch", None, None))
    ctx = {"positions": positions, "token_chunk": token_chunk,
           "local": local, "valid": valid, "meta": meta,
           "table_rows": jnp.asarray(tables, jnp.int32),
           "chunk_pad": chunk_pad, "use_pallas": use_pallas}
    x, new_cache, _ = transformer.prefill_chunks_paged_batched(
        params["stack"], x, ctx, cfg, cache)
    x = layers.rms_norm(x, params["final_ln"], cfg.norm_eps)
    last_idx = jnp.clip(q_off + jnp.maximum(lens, 1) - 1, 0, TT - 1)
    last = jnp.take(x[0], last_idx, axis=0)            # (C, D)
    last_logits = layers.logits(params["embed"], last[None], cfg)[0]
    new_cache["pos"] = cache["pos"].at[slots].set(
        (ctx_lens + lens).astype(cache["pos"].dtype), mode="drop")
    return new_cache, last_logits.astype(jnp.float32)


def prefill_into_slot(params: dict, cfg, cache: dict, batch: dict, slot,
                      max_len: int, cache_dtype=jnp.bfloat16):
    """Prefill ONE request (batch dim 1) and write its state into row
    ``slot`` of a per-slot decode cache (continuous-batching admission).

    The evicted slot's KV/recurrent state is fully replaced.  Returns
    (new_cache, last_logits (V,)).  ``max_len`` must match the max_len
    the slot cache was built with so the ring capacities line up.
    """
    one, last_logits = prefill(params, cfg, batch, max_len, cache_dtype)
    return transformer.write_slot(cache, one, slot), last_logits[0]
