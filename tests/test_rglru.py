"""RG-LRU: associative scan vs sequential loop; decode continuation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import rglru


def test_scan_matches_loop():
    key = jax.random.PRNGKey(0)
    B, S, W = 2, 12, 8
    a = jax.nn.sigmoid(jax.random.normal(key, (B, S, W)))
    b = jax.random.normal(jax.random.PRNGKey(1), (B, S, W))
    h, final = rglru.rglru_scan(a, b)
    ht = jnp.zeros((B, W))
    outs = []
    for t in range(S):
        ht = a[:, t] * ht + b[:, t]
        outs.append(ht)
    want = jnp.stack(outs, 1)
    np.testing.assert_allclose(h, want, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(final, want[:, -1], atol=1e-5, rtol=1e-5)


def test_scan_with_initial_state():
    key = jax.random.PRNGKey(2)
    B, S, W = 1, 6, 4
    a = jax.nn.sigmoid(jax.random.normal(key, (B, S, W)))
    b = jax.random.normal(jax.random.PRNGKey(3), (B, S, W))
    h0 = jax.random.normal(jax.random.PRNGKey(4), (B, W))
    h, _ = rglru.rglru_scan(a, b, h0)
    ht = h0
    for t in range(S):
        ht = a[:, t] * ht + b[:, t]
    np.testing.assert_allclose(h[:, -1], ht, atol=1e-5, rtol=1e-5)


def test_recurrent_block_decode_matches_prefill():
    cfg = configs.get_smoke_config("recurrentgemma-9b")
    key = jax.random.PRNGKey(5)
    params = rglru.init_rglru_block(key, cfg, jnp.float32)
    B, S = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(6), (B, S, cfg.d_model)) * 0.3
    y_seq, st_seq = rglru.apply_recurrent_block(params, x, cfg, None)
    lw = cfg.lru_width or cfg.d_model
    state = {"conv": jnp.zeros((B, cfg.ssm_conv_width - 1, lw)),
             "h": jnp.zeros((B, lw))}
    ys = []
    for t in range(S):
        y_t, state = rglru.decode_recurrent_block(
            params, x[:, t:t + 1], cfg, state)
        ys.append(y_t)
    np.testing.assert_allclose(jnp.concatenate(ys, 1), y_seq,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(state["h"], st_seq["h"],
                               atol=1e-4, rtol=1e-4)
