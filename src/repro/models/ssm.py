"""Mamba-2 (SSD — state-space duality) blocks, pure JAX.

Implements the chunked SSD algorithm (Dao & Gu 2024, arXiv:2405.21060):
intra-chunk quadratic attention-like term + inter-chunk linear recurrence on
the (heads, head_dim, state) tensor, carried with lax.scan.  Decode is an
O(1) single-token state update — this is what makes mamba2 long_500k
eligible.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding import context as shctx

from . import layers

Array = jax.Array


def _segsum(a: Array) -> Array:
    """a: (..., T) -> (..., T, T) with out[i,j] = sum(a[j+1..i]), -inf above diag."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, d, -jnp.inf)


def init_mamba2(key: Array, cfg, dtype) -> dict:
    D = cfg.d_model
    di, nh, ns = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state
    g, cw = cfg.ssm_groups, cfg.ssm_conv_width
    conv_dim = di + 2 * g * ns
    ks = jax.random.split(key, 6)
    a = jax.random.uniform(ks[3], (nh,), jnp.float32, 1.0, 16.0)
    # dt bias: softplus^-1 of dt sampled in [1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(ks[4], (nh,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": layers.dense_init(
            ks[0], (D, 2 * di + 2 * g * ns + nh), dtype),
        "conv_w": layers.dense_init(ks[1], (cw, conv_dim), dtype, scale=0.1),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(a),
        "dt_bias": dt_bias,
        "D": jnp.ones((nh,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": layers.dense_init(ks[2], (di, D), dtype),
    }


def _split_proj(proj: Array, cfg):
    di, nh, ns, g = (cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state,
                     cfg.ssm_groups)
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * g * ns]
    dt = proj[..., -nh:]
    return z, xBC, dt


def _causal_conv(xBC: Array, w: Array, b: Array,
                 state: Optional[Array] = None):
    """Depthwise causal conv over time. xBC: (B, S, C); w: (W, C).

    Returns (out, new_state) where state is the last W-1 inputs.
    """
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[-1]), xBC.dtype)
    xp = jnp.concatenate([state, xBC], axis=1)              # (B, S+W-1, C)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i] for i in range(W)) + b
    new_state = xp[:, -(W - 1):]
    return jax.nn.silu(out), new_state


def ssd_chunked(x: Array, a: Array, B_: Array, C_: Array, chunk: int,
                init_state: Optional[Array] = None):
    """Chunked SSD scan.

    x: (b, s, h, p)  — already multiplied by dt (discrete input)
    a: (b, s, h)     — dt * A  (negative)
    B_, C_: (b, s, g, n); heads h are grouped into g groups.
    Returns (y: (b, s, h, p), final_state: (b, h, p, n)).
    """
    b, s, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    rep = h // g
    Q = min(chunk, s)
    nc = -(-s // Q)
    pad = nc * Q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xc = x.reshape(b, nc, Q, h, p)
    ac = a.reshape(b, nc, Q, h).transpose(0, 3, 1, 2)        # (b,h,nc,Q)
    Bh = jnp.repeat(B_.reshape(b, nc, Q, g, n), rep, axis=3)  # (b,nc,Q,h,n)
    Ch = jnp.repeat(C_.reshape(b, nc, Q, g, n), rep, axis=3)

    acs = jnp.cumsum(ac, axis=-1)                            # (b,h,nc,Q)
    L = jnp.exp(_segsum(ac))                                 # (b,h,nc,Q,Q)
    y_diag = jnp.einsum("bcqhn,bckhn,bhcqk,bckhp->bcqhp",
                        Ch, Bh, L.astype(Ch.dtype), xc,
                        preferred_element_type=jnp.float32)

    decay_states = jnp.exp(acs[..., -1:] - acs)              # (b,h,nc,Q)
    states = jnp.einsum("bckhn,bhck,bckhp->bchpn",
                        Bh, decay_states.astype(Bh.dtype), xc,
                        preferred_element_type=jnp.float32)   # (b,c,h,p,n)
    chunk_decay = jnp.exp(acs[..., -1])                      # (b,h,nc)

    def scan_fn(carry, inp):
        st, dec = inp                                        # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry

    st0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
           else init_state.astype(jnp.float32))
    final, prev = lax.scan(
        scan_fn, st0,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(2, 0, 1)))
    prev = prev.transpose(1, 0, 2, 3, 4)                     # (b,nc,h,p,n)

    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp",
                       Ch, prev.astype(Ch.dtype),
                       jnp.exp(acs).astype(Ch.dtype),
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(b, nc * Q, h, p)[:, :s]
    return y.astype(x.dtype), final


def apply_mamba2(params: dict, x: Array, cfg,
                 state: Optional[dict] = None):
    """Full Mamba-2 mixer. x: (B, S, D).

    state: None for training/prefill-from-scratch, else
    {"conv": (B, W-1, convdim), "ssd": (B, H, P, N)} for chunk-wise
    continuation.  Returns (out, new_state).
    """
    B, S, D = x.shape
    nh, ns, g = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    p = cfg.ssm_head_dim
    proj = x @ params["in_proj"]
    z, xBC, dt = _split_proj(proj, cfg)
    conv_in_state = None if state is None else state["conv"]
    xBC, conv_state = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                                   conv_in_state)
    di = cfg.ssm_d_inner
    xs = xBC[..., :di].reshape(B, S, nh, p)
    xs = shctx.constrain(xs, ("batch", None, "heads", None))
    B_ = xBC[..., di:di + g * ns].reshape(B, S, g, ns)
    C_ = xBC[..., di + g * ns:].reshape(B, S, g, ns)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                            # (nh,)
    a = dt * A                                               # (B,S,nh)
    x_in = xs * dt[..., None].astype(xs.dtype)
    ssd_in_state = None if state is None else state["ssd"]
    y, final = ssd_chunked(x_in, a, B_, C_, cfg.ssm_chunk, ssd_in_state)
    y = y + xs * params["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, S, di)
    y = layers.gated_rms_norm(y, z, params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, {"conv": conv_state, "ssd": final}


def decode_mamba2(params: dict, x: Array, cfg, state: dict):
    """O(1) single-token step. x: (B, 1, D)."""
    B = x.shape[0]
    nh, ns, g, p = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups, \
        cfg.ssm_head_dim
    di = cfg.ssm_d_inner
    proj = x[:, 0] @ params["in_proj"]                      # (B, ...)
    z, xBC, dt = _split_proj(proj, cfg)
    # conv: append token to state buffer
    conv_state = state["conv"]                               # (B, W-1, C)
    xp = jnp.concatenate([conv_state, xBC[:, None]], axis=1)  # (B, W, C)
    w = params["conv_w"]
    out = jnp.einsum("bwc,wc->bc", xp, w) + params["conv_b"]
    xBC = jax.nn.silu(out)
    new_conv = xp[:, 1:]
    xs = xBC[..., :di].reshape(B, nh, p)
    B_ = xBC[..., di:di + g * ns].reshape(B, g, ns)
    C_ = xBC[..., di + g * ns:].reshape(B, g, ns)
    rep = nh // g
    Bh = jnp.repeat(B_, rep, axis=1)                         # (B, nh, ns)
    Ch = jnp.repeat(C_, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,nh)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)                                  # (B, nh)
    h = state["ssd"]                                         # (B,nh,p,ns)
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh.astype(jnp.float32),
                     xs.astype(jnp.float32))
    h_new = h * decay[..., None, None] + dBx
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), h_new)
    y = y.astype(xs.dtype) + xs * params["D"][None, :, None].astype(xs.dtype)
    y = y.reshape(B, di)
    y = layers.gated_rms_norm(y, z, params["norm"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None]
    return out, {"conv": new_conv, "ssd": h_new}
