"""Sharding policy: divisibility fallbacks, spec validity, opt mirroring.

Runs in a subprocess-free way: a host mesh needs multiple devices, so
these tests build meshes from however many CPU devices exist (1 is fine —
resolve() then degenerates to replication, which is also asserted)."""

import math

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import specs
from repro.sharding import policy as policy_lib


class FakeMesh:
    """Shape-only stand-in (policy.resolve/spec never touch devices)."""

    def __init__(self, shape_dict):
        self._shape = dict(shape_dict)

    @property
    def shape(self):
        return self._shape

    @property
    def axis_names(self):
        return tuple(self._shape)


def make_policy(shape_dict, fsdp=True):
    return policy_lib.ShardingPolicy(mesh=FakeMesh(shape_dict), fsdp=fsdp)


POD = {"data": 16, "model": 16}
MULTIPOD = {"pod": 2, "data": 16, "model": 16}


def test_resolve_divisibility_fallback():
    p = make_policy(POD)
    assert p.resolve(64, "heads") == "model"       # 64 % 16 == 0
    assert p.resolve(24, "heads") is None          # minitron heads
    assert p.resolve(8, "kv_heads") is None        # kv=8 vs 16
    assert p.resolve(384, "experts") == "model"    # kimi
    assert p.resolve(8, "experts") is None         # mixtral -> F fallback


def test_resolve_batch_greedy_multipod():
    p = make_policy(MULTIPOD)
    assert p.resolve(256, "batch") == ("pod", "data")
    assert p.resolve(32, "batch") == ("pod", "data")
    assert p.resolve(1, "batch") is None
    # batch=8 divides pod(2) but not pod*data(32) -> pod only
    assert p.resolve(8, "batch") == "pod"


def test_spec_dedups_mesh_axes():
    p = make_policy(POD)
    # experts takes "model"; mlp then cannot reuse it
    spec = p.spec((384, 7168, 2048), ("experts", "fsdp", "mlp"))
    assert spec == P("model", "data", None)
    # mixtral: experts unresolvable -> mlp gets "model"
    spec = p.spec((8, 6144, 16384), ("experts", "fsdp", "mlp"))
    assert spec == P(None, "data", "model")


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
@pytest.mark.parametrize("mesh_shape", [POD, MULTIPOD])
def test_param_shardings_cover_all_leaves(arch, mesh_shape):
    cfg = configs.get_config(arch)
    params = specs.params_specs(cfg)
    p = make_policy(mesh_shape)

    # NamedSharding needs a real mesh; validate the raw specs instead
    def one(path, leaf):
        names = tuple(q.key for q in path
                      if isinstance(q, jax.tree_util.DictKey))
        scanned = any(n.startswith("scan") for n in names) or \
            "blocks" in names
        trailing = leaf.ndim - 1 if scanned else leaf.ndim
        axes = p._param_axes(names, trailing)
        if len(axes) != trailing:
            axes = (None,) * trailing
        if scanned:
            axes = (None,) + tuple(axes)
        spec = p.spec(leaf.shape, axes)
        # every sharded dim must divide by the axis product
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axt = (ax,) if isinstance(ax, str) else ax
            prod = math.prod(mesh_shape[a] for a in axt)
            assert dim % prod == 0, (arch, names, leaf.shape, spec)
        return spec

    jax.tree_util.tree_map_with_path(one, params)


def test_big_params_are_sharded_on_pod_mesh():
    """The 1T-model expert weights must not be replicated."""
    cfg = configs.get_config("kimi-k2-1t-a32b")
    params = specs.params_specs(cfg)
    p = make_policy(MULTIPOD)
    wg = params["stack"]["scan0"]["moe"]["w_gate"]     # (60,384,7168,2048)
    axes = p._param_axes(("stack", "scan0", "moe", "w_gate"), 3)
    spec = p.spec(wg.shape, (None,) + axes)
    shards = 1
    for ax in spec:
        if ax:
            axt = (ax,) if isinstance(ax, str) else ax
            shards *= math.prod(MULTIPOD[a] for a in axt)
    per_dev = np.prod(wg.shape) * 2 / shards
    assert per_dev < 16e9 / 4, f"expert weights {per_dev/2**30:.1f} GiB/dev"


def test_single_device_policy_replicates():
    p = make_policy({"data": 1, "model": 1})
    assert p.spec((64, 64), ("batch", "heads")) == P(None, None)
