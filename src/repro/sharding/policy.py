"""Logical-axis sharding policy with divisibility fallbacks.

Mesh layout (launch/mesh.py):
    single pod : (16, 16)      axes ("data", "model")
    multi-pod  : (2, 16, 16)   axes ("pod", "data", "model")

Logical axes used by the models:
    batch      -> sharded over ("pod", "data") greedily (B=1 stays replicated)
    fsdp       -> parameter d_model/reduction dims over ("data", "pod")
                  (ZeRO-3 style: GSPMD all-gathers per layer inside the scan)
    heads      -> q heads over "model" (falls back to replicate: 24-head
                  archs like minitron/starcoder2 do not divide 16)
    kv_heads   -> kv heads over "model" (kv=8 archs fall back to replicate;
                  the KV *cache* instead shards its sequence dim, below)
    vocab      -> padded vocab over "model" (always divisible: padding to a
                  2048 multiple, see ModelConfig.padded_vocab)
    experts    -> MoE expert dim over "model" (mixtral's 8 experts fall back
                  to sharding the expert d_ff instead)
    mlp        -> d_ff over "model"
    model      -> generic model-parallel dim (ssm heads, lru width, ...)

KV caches prefer kv_heads -> "model"; when kv does not divide the axis they
shard the *sequence/window* dim over "model" instead — decode attention over
a sequence-sharded cache costs one small all-reduce of (B, H, 1, d) partial
numerators/denominators, which GSPMD derives from the softmax reductions.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# preference lists: logical axis -> candidate mesh axes (greedy prefix)
_PREFS = {
    "batch": ("pod", "data"),
    "fsdp": ("data", "pod"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "mlp": ("model",),
    "model": ("model",),
    "seq": ("model",),          # sequence parallelism (opt-in flag)
    "expert_ff": ("data", "pod"),  # serving layout: expert d_ff over data
    "kv_seq": ("model",),       # decode-cache sequence dim (ungated: the
                                # cache itself is stored this way whenever
                                # kv heads don't divide the model axis)
}


@dataclasses.dataclass
class ShardingPolicy:
    mesh: Mesh
    # fsdp=False turns off parameter sharding over the data axes (pure DP +
    # TP) — used as a perf-iteration knob for small models where per-layer
    # FSDP all-gathers dominate the collective term.
    fsdp: bool = True
    # §Perf iteration knobs (see EXPERIMENTS.md):
    # seq_parallel: shard the residual stream's sequence dim over "model"
    # between blocks (Korthikanti-style) — 16x less saved-activation
    # memory; also enables sequence-sharded attention for archs whose
    # head count does not divide the model axis (minitron/starcoder2).
    seq_parallel: bool = False
    # serving: weight layout for prefill/decode — expert d_ff sharded over
    # the data axes instead of ZeRO-style d_model sharding, so decode
    # never all-gathers expert weights (it token-replicates instead);
    # combine with fsdp=False for dense params.
    serving: bool = False

    # ------------------------------------------------------------------
    def resolve(self, dim: int, logical: Optional[str]):
        """Greedy prefix of the preference list whose product divides dim."""
        if logical is None:
            return None
        if not self.fsdp and logical == "fsdp":
            return None
        if not self.seq_parallel and logical == "seq":
            return None
        chosen = []
        prod = 1
        for ax in _PREFS[logical]:
            if ax not in self.mesh.axis_names:
                continue
            size = self.mesh.shape[ax]
            if size == 1:
                continue  # size-1 axes add nothing; keep specs clean
            if dim % (prod * size) == 0:
                chosen.append(ax)
                prod *= size
        if not chosen:
            return None
        return chosen[0] if len(chosen) == 1 else tuple(chosen)

    def spec(self, shape: Sequence[int],
             axes: Sequence[Optional[str]]) -> P:
        assert len(shape) == len(axes), (shape, axes)
        resolved = [self.resolve(d, a) for d, a in zip(shape, axes)]
        # drop duplicate mesh-axis usage (a mesh axis may shard one dim only)
        used = set()
        out = []
        for r in resolved:
            if r is None:
                out.append(None)
                continue
            rt = (r,) if isinstance(r, str) else tuple(r)
            rt = tuple(a for a in rt if a not in used)
            used.update(rt)
            if not rt:
                out.append(None)
            elif len(rt) == 1:
                out.append(rt[0])
            else:
                out.append(rt)
        return P(*out)

    def named(self, shape, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, axes))

    def constrain(self, x, axes):
        return lax.with_sharding_constraint(x, self.named(x.shape, axes))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # ------------------------------------------------------------------
    # perf-iteration layout helpers
    # ------------------------------------------------------------------
    def moe_axes(self, which: str):
        """Expert-weight logical axes. which: 'gate_up' | 'down'.

        Train layout: ZeRO-style d_model sharding over data ("fsdp").
        Serving layout: d_ff over data ("expert_ff") so decode can
        token-replicate instead of all-gathering weights per layer.
        """
        if self.serving:
            return (("experts", None, "expert_ff") if which == "gate_up"
                    else ("experts", "expert_ff", None))
        return (("experts", "fsdp", "mlp") if which == "gate_up"
                else ("experts", "mlp", "fsdp"))

    def attn_q_axes(self, seq_len: int, num_heads: int):
        """Query activation sharding: heads when divisible; else the
        sequence dim under seq_parallel (minitron/starcoder2's 24 heads
        do not divide the 16-wide model axis — without this fallback
        their attention runs fully replicated over "model")."""
        if self.resolve(num_heads, "heads") is not None:
            return ("batch", None, "heads", None)
        if self.seq_parallel and self.resolve(seq_len, "seq") is not None:
            return ("batch", "seq", None, None)
        return ("batch", None, None, None)

    def use_seq_attention(self, seq_len: int, num_heads: int) -> bool:
        return (self.resolve(num_heads, "heads") is None
                and self.seq_parallel
                and self.resolve(seq_len, "seq") is not None)

    # ------------------------------------------------------------------
    # parameter shardings (path-pattern rules over the params pytree)
    # ------------------------------------------------------------------
    def _param_axes(self, path: Tuple[str, ...],
                    ndim: int) -> Tuple[Optional[str], ...]:
        """Logical axes for a parameter leaf, by its pytree path."""
        name = path[-1]
        under_moe = "moe" in path
        under_shared = "shared" in path

        if name == "embedding":
            return ("vocab", None)
        if name == "lm_head":
            return (None, "vocab")
        if name in ("wq",):
            return ("fsdp", "heads", None)
        if name in ("wk", "wv"):
            return ("fsdp", "kv_heads", None)
        if name == "wo":
            return ("heads", None, "fsdp")
        if name == "router":
            return (None, "experts")
        if under_moe and not under_shared:
            if name in ("w_gate", "w_up"):      # (E, D, F)
                return self.moe_axes("gate_up")
            if name == "w_down":                # (E, F, D)
                return self.moe_axes("down")
        if name in ("w_gate", "w_up"):           # dense mlp (D, F)
            return ("fsdp", "mlp")
        if name == "w_down":                     # (F, D)
            return ("mlp", "fsdp")
        # --- ssm (mamba2) ---
        if name == "in_proj":                    # (D, X) X has mixed slices
            return ("fsdp", None)
        if name == "out_proj":                   # (di, D)
            return ("model", "fsdp")
        if name == "conv_w":                     # (W, C)
            return (None, "model")
        if name in ("conv_b", "norm"):
            return ("model",)
        if name in ("A_log", "dt_bias", "D"):
            return ("model",)
        # --- rglru ---
        if name in ("w_x", "w_gate_branch"):     # (D, lw)
            return ("fsdp", "model")
        if name in ("w_a", "w_i"):               # (lw, lw)
            return (None, "model")
        if name in ("b_a", "b_i", "Lambda"):
            return ("model",)
        if name == "w_out":                      # (lw, D)
            return ("model", "fsdp")
        # --- projector / everything else (norms, scalars) ---
        if name in ("w1", "w2"):                 # (D, D)
            return ("fsdp", None)
        return (None,) * ndim

    def param_shardings(self, params):
        """NamedSharding pytree matching ``params``.

        Leaves under a ``scan*`` key carry a leading stacked-layer dim which
        is never sharded.
        """
        def one(path, leaf):
            names = tuple(
                p.key for p in path
                if isinstance(p, (jax.tree_util.DictKey,)))
            ndim = leaf.ndim
            scanned = any(n.startswith("scan") for n in names) or \
                names[-2:-1] == ("blocks",) or "blocks" in names
            trailing = ndim - 1 if scanned else ndim
            axes = self._param_axes(names, trailing)
            if len(axes) != trailing:
                axes = (None,) * trailing
            if scanned:
                axes = (None,) + tuple(axes)
            return self.named(leaf.shape, axes)

        return jax.tree_util.tree_map_with_path(one, params)

    # ------------------------------------------------------------------
    # input / cache shardings
    # ------------------------------------------------------------------
    def batch_shardings(self, batch_example):
        """Shardings for a train/prefill batch pytree: dim0 = global batch."""
        def one(leaf):
            axes = ("batch",) + (None,) * (leaf.ndim - 1)
            return self.named(leaf.shape, axes)
        return jax.tree_util.tree_map(one, batch_example)

    def _cache_leaf_axes(self, path, shape):
        name = path[-1]
        if name in ("pos",):
            return ()
        if name == "slot_pos":
            return (None,)
        # strip the stacked-layer dim for scanned caches
        scanned = any(n.startswith("scan") for n in path)
        core = shape[1:] if scanned else shape
        if name in ("k", "v", "enc_k", "enc_v"):
            # (B, S, KV, hd): kv heads if divisible, else sequence
            kv_ok = self.resolve(core[2], "kv_heads") is not None
            axes = ("batch", None, "kv_heads", None) if kv_ok else \
                ("batch", "model", None, None)
        elif name == "conv":
            axes = ("batch", None, "model")
        elif name == "ssd":
            axes = ("batch", "model", None, None)
        elif name == "h":
            axes = ("batch", "model")
        else:
            axes = (None,) * len(core)
        if scanned:
            axes = (None,) + tuple(axes)
        return axes

    def opt_shardings(self, opt_state_example):
        """Shardings for optimizer state pytrees.

        AdamW moments ("mu"/"nu" subtrees) mirror the parameter shardings
        (paths end with the same leaf names).  Adafactor factored stats
        ("stats"/.../{r,c,v}) derive from the parameter's axes: r drops
        the last dim, c drops the second-to-last, v mirrors.
        """
        def one(path, leaf):
            names = tuple(
                p.key for p in path
                if isinstance(p, (jax.tree_util.DictKey,)))
            if names[-1] in ("step",):
                return self.replicated()
            scanned = any(n.startswith("scan") for n in names) or \
                "blocks" in names
            if names[-1] in ("r", "c", "v"):
                pnames = names[1:-1]
                trailing = (leaf.ndim if names[-1] != "r" else leaf.ndim) \
                    - (1 if scanned else 0)
                # parameter trailing ndim: r -> +1, c -> +1, v -> +0
                p_nd = trailing + (1 if names[-1] in ("r", "c") else 0)
                axes = self._param_axes(pnames, p_nd)
                if len(axes) != p_nd:
                    axes = (None,) * p_nd
                if names[-1] == "r":
                    axes = axes[:-1]
                elif names[-1] == "c":
                    axes = axes[:-2] + axes[-1:]
            else:
                pnames = names[1:]
                trailing = leaf.ndim - (1 if scanned else 0)
                axes = self._param_axes(pnames, trailing)
                if len(axes) != trailing:
                    axes = (None,) * trailing
            if scanned:
                axes = (None,) + tuple(axes)
            return self.named(leaf.shape, axes)

        return jax.tree_util.tree_map_with_path(one, opt_state_example)

    def cache_shardings(self, cache_example):
        def one(path, leaf):
            names = tuple(
                p.key for p in path
                if isinstance(p, (jax.tree_util.DictKey,)))
            axes = self._cache_leaf_axes(names, leaf.shape)
            return self.named(leaf.shape, axes)
        return jax.tree_util.tree_map_with_path(one, cache_example)


def make_policy(mesh: Mesh, fsdp: bool = True) -> ShardingPolicy:
    return ShardingPolicy(mesh=mesh, fsdp=fsdp)
