"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

TPU-idiomatic design notes:
  * dispatch is sort-based (argsort by expert id + rank-within-expert
    capacity cut) rather than the classic (tokens, E, C) one-hot einsum —
    the one-hot dispatch tensor for the 1T Kimi-K2 config (65k tokens/device
    x 384 experts x ~1.7k capacity) would be ~4e13 elements; the sort-based
    path moves only (E*C, D) activations and lets GSPMD lower the
    expert-parallel exchange to all-to-all style collectives.
  * expert weights are stacked (E, D, F) and sharded on the expert axis
    ("model" mesh axis) + FSDP on "data" for the trillion-param config.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.sharding import context as shctx

from . import layers

Array = jax.Array


def init_moe(key: Array, cfg, dtype) -> dict:
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(ks[0], (D, E), jnp.float32),
        "w_gate": layers.dense_init(ks[1], (E, D, F), dtype),
        "w_up": layers.dense_init(ks[2], (E, D, F), dtype),
        "w_down": layers.dense_init(ks[3], (E, F, D), dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.init_mlp(
            ks[4], D, F * cfg.num_shared_experts, "swiglu", dtype)
    return p


def _capacity(num_tokens: int, cfg) -> int:
    cap = int(cfg.capacity_factor * num_tokens * cfg.experts_per_token
              / cfg.num_experts)
    return max(8, -(-cap // 8) * 8)


def apply_moe(params: dict, x: Array, cfg) -> tuple[Array, dict]:
    """Dispatcher: expert-parallel shard_map path when a sharding policy is
    active (distributed runs), single-device reference path otherwise."""
    policy = shctx.current()
    if policy is not None:
        return apply_moe_ep(params, x, cfg, policy)
    return apply_moe_local(params, x, cfg)


def apply_moe_local(params: dict, x: Array, cfg) -> tuple[Array, dict]:
    """x: (B, S, D) -> (out, aux_metrics).

    aux_metrics carries the load-balance and z losses (summed into the
    training loss) plus drop-fraction diagnostics.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = _capacity(T, cfg)
    xt = x.reshape(T, D)

    router_logits = xt.astype(jnp.float32) @ params["router"]       # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = lax.top_k(probs, K)                              # (T, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)          # renorm

    # ---- sort-based dispatch -------------------------------------------
    flat_e = top_e.reshape(-1)                                      # (T*K,)
    flat_w = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    sorted_w = flat_w[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))           # (E,)
    rank = jnp.arange(T * K) - seg_start[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)              # drop->OOB

    xe = jnp.zeros((E * C, D), x.dtype)
    xe = xe.at[slot].set(xt[sorted_tok] *
                         keep[:, None].astype(x.dtype), mode="drop")
    xe = xe.reshape(E, C, D)

    # ---- expert computation (batched over experts) ---------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(E * C, D)

    # ---- combine --------------------------------------------------------
    contrib = ye[jnp.where(keep, slot, 0)] * \
        (sorted_w * keep).astype(x.dtype)[:, None]
    out = jnp.zeros((T, D), x.dtype).at[sorted_tok].add(contrib)

    if cfg.num_shared_experts:
        out = out + layers.apply_mlp(params["shared"], xt, "swiglu")
    out = out.reshape(B, S, D)

    # ---- aux losses ------------------------------------------------------
    me = jnp.mean(probs, axis=0)                                     # (E,)
    ce = jnp.mean(
        (jax.nn.one_hot(top_e, E).sum(axis=1)).astype(jnp.float32), axis=0)
    load_balance = E * jnp.sum(me * ce) / K
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(router_logits, axis=-1)))
    dropped = 1.0 - jnp.sum(keep) / (T * K)
    aux = {
        "moe_aux_loss": cfg.router_aux_weight * load_balance
        + cfg.router_z_weight * z_loss,
        "moe_drop_frac": dropped,
    }
    return out, aux


# ---------------------------------------------------------------------------
# expert-parallel path (shard_map)
# ---------------------------------------------------------------------------
#
# Activation layout under the production mesh: x is sharded over the batch
# axes ("pod","data") and *replicated* over "model"; expert weights are
# sharded E -> "model" (kimi: 384/16 = 24 local experts) and FSDP-sharded
# over ("data","pod").  Because x is replicated over "model", each expert
# owner can gather its tokens locally — dispatch needs NO all-to-all; the
# only inter-device traffic is (a) the FSDP all-gather of the local expert
# weights and (b) one psum over "model" of the (T_loc, D) combined output,
# which is exactly the all-reduce a dense TP layer would pay anyway.
#
# When E does not divide the model axis (mixtral: 8 experts on a 16-wide
# axis) every model shard keeps all E experts but shards the expert d_ff
# ("mlp" -> "model"); the same closing psum then completes the partial
# w_down contraction instead.  Both cases are one code path below.


def _axes_tuple(r):
    if r is None:
        return ()
    return (r,) if isinstance(r, str) else tuple(r)


def _shard_map_fn():
    """Version shim: jax.shard_map on new releases; the experimental
    module (whose replication-check kwarg is `check_rep`, not
    `check_vma`) on older ones.  Local imports keep the module
    importable before jax backend init."""
    import functools
    try:
        from jax import shard_map as sm
        return functools.partial(sm, check_vma=False)
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
        return functools.partial(sm, check_rep=False)


def apply_moe_ep(params: dict, x: Array, cfg, policy) -> tuple[Array, dict]:
    shard_map = _shard_map_fn()  # local: keep module importable early

    mesh = policy.mesh
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    batch_axes = _axes_tuple(policy.resolve(B, "batch"))
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]
    model_sz = mesh.shape["model"] if "model" in mesh.axis_names else 1
    experts_sharded = E % model_sz == 0 and model_sz > 1
    E_loc = E // model_sz if experts_sharded else E
    T_loc = (B // n_batch_shards) * S
    serving = getattr(policy, "serving", False)
    # serving-layout decode: the whole token set is tiny, so replicate it
    # and never move weights (EXPERIMENTS.md §Perf pair B) — one psum of
    # (T, D) replaces the per-layer FSDP all-gather of expert weights.
    # (batch_axes may be empty — long_500k's B=1 is replicated already.)
    token_replicated = serving and B * S * K <= 32768
    C = _capacity(B * S if token_replicated else T_loc, cfg)

    x_spec = P(batch_axes if batch_axes else None, None, None)
    wg_spec = policy.spec(params["w_gate"].shape, policy.moe_axes("gate_up"))
    wd_spec = policy.spec(params["w_down"].shape, policy.moe_axes("down"))
    router_spec = P(None, None)
    # axes the weights are sharded over besides "experts" (gathered in the
    # big-token path; left in place in the token-replicated path)
    gath_axes_g = tuple(_axes_tuple(wg_spec[2 if serving else 1]))
    gath_axes_d = tuple(_axes_tuple(wd_spec[1 if serving else 2]))

    def f(xl, router, wg, wu, wd):
        # xl: (B_loc, S, D); router: (D, E) replicated
        if token_replicated:
            return _f_token_replicated(xl, router, wg, wu, wd)
        # train/prefill: gather the expert weights' non-expert shard axis
        # (ZeRO layout: d_model; serving layout: d_ff)
        if gath_axes_g:
            ax = 2 if serving else 1
            wg = lax.all_gather(wg, gath_axes_g, axis=ax, tiled=True)
            wu = lax.all_gather(wu, gath_axes_g, axis=ax, tiled=True)
        if gath_axes_d:
            ax = 1 if serving else 2
            wd = lax.all_gather(wd, gath_axes_d, axis=ax, tiled=True)
        xt = xl.reshape(T_loc, D)
        e0 = (lax.axis_index("model") * E_loc) if experts_sharded else 0

        router_logits = xt.astype(jnp.float32) @ router          # (T, E)
        probs = jax.nn.softmax(router_logits, axis=-1)
        top_p, top_e = lax.top_k(probs, K)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        flat_e = top_e.reshape(-1)
        flat_w = top_p.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(T_loc), K)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        sorted_tok = flat_tok[order]
        sorted_w = flat_w[order]
        local_e = sorted_e - e0
        valid = (local_e >= 0) & (local_e < E_loc)
        seg_start = jnp.searchsorted(sorted_e, e0 + jnp.arange(E_loc))
        rank = jnp.arange(T_loc * K) - \
            seg_start[jnp.clip(local_e, 0, E_loc - 1)]
        keep = valid & (rank < C)
        slot = jnp.where(keep, local_e * C + rank, E_loc * C)    # drop->OOB

        xe = jnp.zeros((E_loc * C, D), xl.dtype)
        xe = xe.at[slot].set(xt[sorted_tok] *
                             keep[:, None].astype(xl.dtype), mode="drop")
        xe = xe.reshape(E_loc, C, D)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
            jnp.einsum("ecd,edf->ecf", xe, wu)
        ye = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E_loc * C, D)

        contrib = ye[jnp.where(keep, slot, 0)] * \
            (sorted_w * keep).astype(xl.dtype)[:, None]
        out = jnp.zeros((T_loc, D), jnp.float32).at[sorted_tok].add(
            contrib.astype(jnp.float32))
        if model_sz > 1:
            out = lax.psum(out, "model")
        out = out.astype(xl.dtype).reshape(xl.shape)

        # aux losses: router tensors are replicated over "model", so the
        # load-balance statistics only need averaging over the batch axes.
        # The per-expert rates me/ce must be averaged BEFORE the product:
        # the loss is bilinear in the global rates, and a mean of
        # per-shard products picks up the across-shard covariance (~1%
        # off the single-device oracle on an E=64 smoke config).
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(
            (jax.nn.one_hot(top_e, E).sum(axis=1)).astype(jnp.float32),
            axis=0)
        if batch_axes:
            me = lax.pmean(me, batch_axes)
            ce = lax.pmean(ce, batch_axes)
        load_balance = E * jnp.sum(me * ce) / K
        z_loss = jnp.mean(
            jnp.square(jax.nn.logsumexp(router_logits, axis=-1)))
        n_drop = jnp.sum(valid & ~keep).astype(jnp.float32)
        if model_sz > 1 and experts_sharded:
            n_drop = lax.psum(n_drop, "model")
        elif model_sz > 1:
            n_drop = lax.pmean(n_drop, "model")
        dropped = n_drop / (T_loc * K)
        aux = {
            "moe_aux_loss": cfg.router_aux_weight * load_balance
            + cfg.router_z_weight * z_loss,
            "moe_drop_frac": dropped,
        }
        if batch_axes:
            aux = jax.tree.map(lambda v: lax.pmean(v, batch_axes), aux)
        return out, aux

    def _f_token_replicated(xl, router, wg, wu, wd):
        # wg/wu: (E_loc, D, F_loc); wd: (E_loc, F_loc, D) — weights stay
        # put; the (tiny) decode token set is gathered instead.
        T_all = B * S
        xt = xl.reshape(T_loc, D)
        if batch_axes:
            xt = lax.all_gather(xt, batch_axes, axis=0,
                                tiled=True)              # (T_all, D)
        e0 = (lax.axis_index("model") * E_loc) if experts_sharded else 0

        router_logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(router_logits, axis=-1)
        top_p, top_e = lax.top_k(probs, K)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        flat_e = top_e.reshape(-1)
        flat_w = top_p.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(T_all), K)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        sorted_tok = flat_tok[order]
        sorted_w = flat_w[order]
        local_e = sorted_e - e0
        valid = (local_e >= 0) & (local_e < E_loc)
        seg_start = jnp.searchsorted(sorted_e, e0 + jnp.arange(E_loc))
        rank = jnp.arange(T_all * K) - \
            seg_start[jnp.clip(local_e, 0, E_loc - 1)]
        keep = valid & (rank < C)
        slot = jnp.where(keep, local_e * C + rank, E_loc * C)

        xe = jnp.zeros((E_loc * C, D), xl.dtype)
        xe = xe.at[slot].set(xt[sorted_tok] *
                             keep[:, None].astype(xl.dtype), mode="drop")
        xe = xe.reshape(E_loc, C, D)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
            jnp.einsum("ecd,edf->ecf", xe, wu)           # (E_loc, C, F_loc)
        ye = jnp.einsum("ecf,efd->ecd", h, wd)           # partial over F
        ye = ye.reshape(E_loc * C, D)

        contrib = ye[jnp.where(keep, slot, 0)] * \
            (sorted_w * keep).astype(ye.dtype)[:, None]
        out_all = jnp.zeros((T_all, D), jnp.float32).at[sorted_tok].add(
            contrib.astype(jnp.float32))
        # One reduction closes BOTH partial sums — over "model" iff the
        # expert dim is actually partitioned there, and over exactly the
        # axes that shard d_ff (axes where computation was identical must
        # NOT be summed: they hold replicas, not partials).
        f_axes = tuple(_axes_tuple(wg_spec[2]))
        psum_axes = (("model",) if experts_sharded else ()) + f_axes
        if psum_axes:
            out_all = lax.psum(out_all, psum_axes)
        idx = jnp.int32(0)
        for a in batch_axes:
            idx = idx * mesh.shape[a] + lax.axis_index(a)
        out = lax.dynamic_slice_in_dim(out_all, idx * T_loc, T_loc, 0)
        out = out.astype(xl.dtype).reshape(xl.shape)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(
            (jax.nn.one_hot(top_e, E).sum(axis=1)).astype(jnp.float32),
            axis=0)
        load_balance = E * jnp.sum(me * ce) / K
        z_loss = jnp.mean(
            jnp.square(jax.nn.logsumexp(router_logits, axis=-1)))
        n_drop = jnp.sum(valid & ~keep).astype(jnp.float32)
        if model_sz > 1 and experts_sharded:
            n_drop = lax.psum(n_drop, "model")
        elif model_sz > 1:
            n_drop = lax.pmean(n_drop, "model")
        # F-sharding replicates the drop count across the batch axes
        if batch_axes:
            n_drop = lax.pmean(n_drop, batch_axes)
        dropped = n_drop / (T_all * K)
        aux = {
            "moe_aux_loss": cfg.router_aux_weight * load_balance
            + cfg.router_z_weight * z_loss,
            "moe_drop_frac": dropped,
        }
        return out, aux

    fn = shard_map(
        f, mesh=mesh,
        in_specs=(x_spec, router_spec, wg_spec, wg_spec, wd_spec),
        out_specs=(x_spec, {"moe_aux_loss": P(), "moe_drop_frac": P()}))
    out, aux = fn(x, params["router"], params["w_gate"], params["w_up"],
                  params["w_down"])
    if cfg.num_shared_experts:
        xt = x.reshape(B * S, D)
        out = out + layers.apply_mlp(params["shared"], xt,
                                     "swiglu").reshape(B, S, D)
    return out, aux
