"""Prefill interference: stall-admission vs chunked prefill on the
paged continuous engine, at an EQUAL token budget.

The measured pathology: the stall engine blocks the ENTIRE decode loop
for one ``(1, input_bucket)`` prefill per admission — and admissions
burst (several slots free in one step, several back-to-back prefills),
so live requests see inter-token-latency spikes proportional to the
burst size.  Chunked prefill (``prefill="chunked"``; repro.prefill)
packs a per-iteration token budget with decode tokens first plus at
most ``token_budget - decode_tokens`` prefill-chunk tokens, bounding
the worst-case stall by the budget knob instead of the burst.

Two measurements of the same bimodal workload (EOS disabled, exact
output lengths), both engines producing token-for-token identical
output (tests/test_chunked_prefill.py):

  * ``sim``    — persona latency model, deterministic (the acceptance
    numbers: chunked p99 ITL strictly below stall p99 ITL at equal
    amortized prefill cost and equal-throughput completion);
  * ``engine`` — the REAL JAX engine (tiny config on CPU), wall-clock
    per chunk/prefill/decode-step, demonstrating the same effect
    end-to-end (``prefill_stall_max_s``: worst prefill time injected
    between two consecutive decode steps).

Results land in experiments/bench/chunked_prefill.json.

    PYTHONPATH=src python -m benchmarks.prefill_interference [--seed N]
"""

from __future__ import annotations

import argparse
import gc
import time

from repro.core import scheduler as sched, simulator

from . import common
from .continuous_vs_batch import (build_workload as _shared_workload,
                                  persona_for_bench as _shared_persona,
                                  sim_tasks_for)

N_REQUESTS = 96
N_ENGINE = 32
SHORT, LONG = 12, 48
LONG_FRAC = 0.25
SLOTS = 8
INPUT_BUCKET = 64
# Both columns budget one prompt's worth of prefill per iteration
# (budget = decode width + bucket): the worst per-iteration stall is
# ONE prompt's prefill instead of a whole admission burst (when a wave
# of same-length requests evicts together, the stall engine injects
# that many back-to-back (1, 64) prefills before the next decode
# step), and prefill supply (64 tokens/iter) covers steady-state
# demand slots*bucket/mean_out = 8*64/21 ~ 24 with headroom, so the
# decode loop keeps near-parity throughput.  Sim and engine now share
# the same half-prompt chunk size: the FUSED ragged executable runs
# every scheduled chunk in ONE launch per iteration (see
# kernels/ragged_chunked_prefill.py), so sub-prompt chunks no longer
# multiply dispatches on this dispatch-bound CPU host — the engine's
# prefill_dispatch_trace records exactly one launch per iteration
# versus the stall column's admission bursts.
CHUNK = 32
BUDGET = SLOTS + INPUT_BUCKET
ENGINE_CHUNK = CHUNK
ENGINE_BUDGET = SLOTS + INPUT_BUCKET
KV_BLOCK = 16
SEED = 0


def build_workload(n=N_REQUESTS, seed=SEED):
    # continuous_vs_batch's bimodal workload with every request present
    # at t=0: same-length requests admitted together evict together, so
    # admissions recur in WAVES — exactly when stall prefill hurts the
    # still-running (long) requests most
    return _shared_workload(n, seed, short=SHORT, long_len=LONG,
                            long_frac=LONG_FRAC, window=0.0)


def persona_for_bench():
    return _shared_persona(batch_size=SLOTS)


def _tail_summary(res) -> dict:
    if isinstance(res, dict):
        out = {k: res[k] for k in
               ("mean_response_s", "throughput_per_min",
                "ttft_p50", "ttft_p99", "itl_p50", "itl_p99",
                "prefill_stall_s", "prefill_stall_max_s",
                "prefill_dispatches")}
        trace = res["prefill_dispatch_trace"]
    else:
        out = dict(res.summary(),
                   ttft_p50=res.ttft_p50, ttft_p99=res.ttft_p99,
                   itl_p50=res.itl_p50, itl_p99=res.itl_p99,
                   prefill_dispatches=res.prefill_dispatches)
        trace = res.prefill_dispatch_trace
    # the dispatch-overhead lever: the fused chunked engine issues at
    # most ONE prefill launch per iteration; stall admission issues one
    # per admission (bursts when several slots free together)
    out["prefill_dispatch_max_per_iter"] = max(trace, default=0)
    return out


def run_sim(policy_name="fifo", seed=SEED):
    """Deterministic persona-model column (the acceptance gate)."""
    persona = persona_for_bench()
    train, test, caps, arrivals = build_workload(seed=seed)
    profile = sched.offline_profile(train, persona, epochs=20, seed=seed)
    pcfg = profile.policy_config()
    out = {}
    for prefill, kw in (("stall", {}),
                        ("chunked", dict(prefill="chunked",
                                         chunk_size=CHUNK,
                                         token_budget=BUDGET))):
        tasks = sim_tasks_for(test, caps, arrivals, profile, persona)
        res = simulator.simulate_continuous(
            tasks, sched.POLICIES[policy_name](persona, pcfg),
            prompt_len=INPUT_BUCKET, **kw)
        out[prefill] = _tail_summary(res)
    out["itl_p99_ratio"] = (out["chunked"]["itl_p99"]
                            / max(out["stall"]["itl_p99"], 1e-12))
    out["throughput_ratio"] = (out["chunked"]["throughput_per_min"]
                               / out["stall"]["throughput_per_min"])
    return out


def run_engine(policy_name="fifo", n=N_ENGINE, seed=SEED, reps=5):
    """Same comparison on the real JAX engine (tiny config,
    wall-clock); output is token-for-token identical between the two
    prefill modes, which run_engine also verifies.

    Wall-clock on a CPU container is noisy (host hiccups land a handful
    of 3-5x outlier iterations in either column), so each mode is
    served ``reps`` times on one warmed engine and the reported numbers
    are per-metric MEDIANS across repetitions (per-rep values recorded
    alongside)."""
    import statistics

    import jax
    from repro import configs
    from repro.models import model as model_lib
    from repro.serving.engine import Request, ServingEngine

    persona = persona_for_bench()
    train, test, caps, arrivals = build_workload(n=n, seed=seed)
    profile = sched.offline_profile(train, persona, epochs=20, seed=seed)
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(jax.random.PRNGKey(seed), cfg)
    out = {}
    tokens = {}
    engines = {}
    for prefill, kw in (("stall", {}),
                        ("chunked", dict(prefill="chunked",
                                         chunk_size=ENGINE_CHUNK,
                                         token_budget=ENGINE_BUDGET))):
        policy = sched.POLICIES[policy_name](persona,
                                             profile.policy_config())
        eng = ServingEngine(params, cfg, policy, profile,
                            input_bucket=INPUT_BUCKET, max_new_tokens=LONG,
                            mode="continuous", eos_id=-1, kv="paged",
                            kv_block_size=KV_BLOCK, **kw)
        # untimed warmup: compile every executable (prefill/chunk shapes
        # + decode) so jit tracing spikes don't land in the measured
        # serves' inter-token latencies
        eng.serve([Request(text=t.text, arrival=0.0, task_id=i,
                           max_new_tokens=3)
                   for i, t in enumerate(test[:SLOTS + 1])])
        engines[prefill] = eng
    rep_rows = {"stall": [], "chunked": []}
    # repetitions INTERLEAVED (stall, chunked, stall, ...) so slow host
    # drift (throttling, background load) hits both columns alike
    for _ in range(reps):
        for prefill, eng in engines.items():
            reqs = [Request(text=t.text, arrival=a, task_id=i,
                            max_new_tokens=c)
                    for i, (t, c, a) in enumerate(zip(test, caps,
                                                      arrivals))]
            # GC pauses otherwise land multi-ms outlier iterations in
            # either column's ITL tail
            gc.disable()
            try:
                res = eng.serve(reqs)
            finally:
                gc.enable()
            eng.allocator.check_no_leaks()
            rep_rows[prefill].append(_tail_summary(res))
            tokens.setdefault(prefill, {t.task.task_id: t.task.out_tokens
                                        for t in res["tasks"]})
    for prefill, rows in rep_rows.items():
        out[prefill] = {k: statistics.median(r[k] for r in rows)
                        for k in rows[0]}
        out[prefill]["reps"] = rows
    assert tokens["stall"] == tokens["chunked"], \
        "chunked prefill changed the greedy output"
    out["token_parity"] = True
    # the acceptance claim, checked in-benchmark: fused chunked prefill
    # issues at most ONE launch per iteration (O(1)), versus the stall
    # column's per-admission bursts (O(#admissions))
    assert out["chunked"]["prefill_dispatch_max_per_iter"] <= 1
    assert out["stall"]["prefill_dispatch_max_per_iter"] > 1
    out["dispatch_ratio"] = (
        out["chunked"]["prefill_dispatches"]
        / max(out["stall"]["prefill_dispatches"], 1e-12))
    out["itl_p99_ratio"] = (out["chunked"]["itl_p99"]
                            / max(out["stall"]["itl_p99"], 1e-12))
    out["stall_max_ratio"] = (
        out["chunked"]["prefill_stall_max_s"]
        / max(out["stall"]["prefill_stall_max_s"], 1e-12))
    out["throughput_ratio"] = (out["chunked"]["throughput_per_min"]
                               / out["stall"]["throughput_per_min"])
    return out


def main(seed=SEED):
    t0 = time.time()
    sim = run_sim("fifo", seed=seed)
    eng = run_engine("fifo", seed=seed)
    payload = {
        "seed": seed,
        "input_bucket": INPUT_BUCKET,
        "chunk_size": CHUNK,
        "token_budget": BUDGET,
        "engine_chunk_size": ENGINE_CHUNK,
        "engine_token_budget": ENGINE_BUDGET,
        "num_slots": SLOTS,
        "kv_block_size": KV_BLOCK,
        "sim": sim,
        "engine": eng,
    }
    common.save("chunked_prefill", payload)
    common.emit(
        "chunked_prefill", time.time() - t0,
        f"sim_itl_p99_x={sim['itl_p99_ratio']:.2f},"
        f"sim_throughput_x={sim['throughput_ratio']:.2f},"
        f"engine_itl_p99_x={eng['itl_p99_ratio']:.2f},"
        f"engine_stall_max_x={eng['stall_max_ratio']:.2f},"
        f"engine_dispatch_max_per_iter="
        f"{eng['chunked']['prefill_dispatch_max_per_iter']:.0f}"
        f"_vs_stall_{eng['stall']['prefill_dispatch_max_per_iter']:.0f}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=SEED)
    main(seed=ap.parse_args().seed)
