"""Chunked-prefill scheduling (token-budgeted prefill/decode interleave).

See scheduler.ChunkScheduler — the host-side core shared by the real
serving engine (``ServingEngine(prefill="chunked")``) and the simulator
(``simulate_continuous(prefill="chunked")``).
"""

from .scheduler import ChunkJob, ChunkPlan, ChunkScheduler

__all__ = ["ChunkJob", "ChunkPlan", "ChunkScheduler"]
