"""Rate-limited warnings with countable fallback events.

The serving stack degrades silently in two places: ``use_pallas=None``
auto-detection falls back to the jnp kernel paths off-TPU, and AOT
warmup failure degrades to jit-on-first-call.  Both used to be ad-hoc
one-shot ``logger.warning`` patterns — visible once in stderr, then
gone, and never countable.  This module centralizes the pattern:

  * each degradation site calls ``warn_once(logger, key, msg, ...)``;
  * the FIRST occurrence per key logs at WARNING; repeats within
    ``min_interval_s`` are suppressed (rate limit, not one-shot — a
    long-lived process resurfaces a persistent fallback periodically);
  * EVERY occurrence increments the key's counter, so
    ``fallback_count()`` deltas make silent fallbacks countable in
    serve results (``ServingEngine._result["fallback_events"]``)
    instead of only greppable in stderr;
  * ``reset(key)`` re-arms logging without clearing counts — what
    ``generate.reset_fallback_warning`` maps onto, keeping the
    per-serve re-arm semantics of the old pattern.

A module-level singleton (``FALLBACKS``) backs the serving stack; unit
tests may construct private ``RateLimitedLogger`` instances.

Multi-replica scoping (PR 9): with R engine replicas in one process,
a purely process-global ledger makes per-replica accounting wrong in
both directions — replica 3's first jnp-fallback is rate-SUPPRESSED
because replica 0 logged the same key seconds earlier, and a
process-global count delta attributes every replica's events to
whichever engine computed the delta.  ``scope(ledger)`` pushes an
engine-owned ledger for the duration of its build/serve work:
``warn_once`` then counts the occurrence in BOTH the global ledger
(process-wide observability is still wanted) and every active scope,
while the emission decision comes from the innermost scope — so each
replica's first fallback logs, and ``ServingEngine`` reports
``fallback_events`` from its own ledger's counts.
"""

from __future__ import annotations

import contextlib
import time
from typing import Dict, List, Optional


class RateLimitedLogger:
    """Per-key rate-limited warning emitter with occurrence counters."""

    def __init__(self, min_interval_s: float = 300.0):
        self.min_interval_s = min_interval_s
        self._last_emit: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}
        self.suppressed: Dict[str, int] = {}

    # ------------------------------------------------------------------
    def note(self, key: str) -> bool:
        """Count one occurrence and decide (without logging) whether
        this ledger would emit it — the rate-limit bookkeeping half of
        ``warn``, reusable when the emission decision belongs to a
        different ledger (see ``warn_once``)."""
        self.counts[key] = self.counts.get(key, 0) + 1
        now = time.monotonic()
        last = self._last_emit.get(key)
        if last is not None and now - last < self.min_interval_s:
            self.suppressed[key] = self.suppressed.get(key, 0) + 1
            return False
        self._last_emit[key] = now
        return True

    def warn(self, logger, key: str, msg: str, *args) -> bool:
        """Count the occurrence; emit at WARNING unless the key logged
        within ``min_interval_s``.  Returns True when emitted."""
        if not self.note(key):
            return False
        logger.warning(msg, *args)
        return True

    # ------------------------------------------------------------------
    def reset(self, key: Optional[str] = None) -> None:
        """Re-arm emission (counts are NOT cleared — they are the
        observable record).  ``None`` re-arms every key."""
        if key is None:
            self._last_emit.clear()
        else:
            self._last_emit.pop(key, None)

    def count(self, key: Optional[str] = None) -> int:
        if key is not None:
            return self.counts.get(key, 0)
        return sum(self.counts.values())


#: process-wide fallback ledger for the serving stack.  Keys in use:
#:   "jnp-fallback"  — use_pallas auto-detection fell back off-TPU
#:   "aot-warmup"    — AOT warmup failed; degraded to jit-on-first-call
FALLBACKS = RateLimitedLogger()

#: active scoped ledgers, innermost last (``scope``) — each engine
#: replica pushes its own around factory build + serve
_SCOPES: List[RateLimitedLogger] = []


@contextlib.contextmanager
def scope(ledger: RateLimitedLogger):
    """Route ``warn_once`` bookkeeping into ``ledger`` for the block:
    occurrences count in the global ledger AND every active scope, and
    the innermost scope owns the rate-limit emission decision (so a
    fresh replica's first fallback is not suppressed by an earlier
    replica having logged the same key)."""
    _SCOPES.append(ledger)
    try:
        yield ledger
    finally:
        _SCOPES.pop()


def warn_once(logger, key: str, msg: str, *args) -> bool:
    """Module-level convenience over the shared ``FALLBACKS`` ledger
    plus any active ``scope`` ledgers (innermost decides emission)."""
    emit = FALLBACKS.note(key)
    for ledger in _SCOPES:
        emit = ledger.note(key)
    if emit:
        logger.warning(msg, *args)
    return emit


def fallback_count() -> int:
    """Total degradation events so far (all keys) — process-wide; a
    replica-accurate count comes from its engine's own scoped ledger
    (``ServingEngine.fallback_ledger.count()``)."""
    return FALLBACKS.count()
