from . import context, policy  # noqa: F401
