"""The jitted training step: loss -> grad -> clip -> optimizer update."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as model_lib

from . import optimizer as opt_lib


def make_train_step(cfg, opt: opt_lib.Optimizer, *, remat: bool = True,
                    clip_norm: float = 1.0):
    """Returns train_step(params, opt_state, batch) -> (params', opt_state',
    metrics).  Pure function of its inputs — jit/pjit it at the call site
    with the sharding policy's in/out shardings."""

    def loss_fn(params, batch):
        return model_lib.lm_loss(params, cfg, batch, remat=remat)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads, grad_norm = opt_lib.clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = opt_lib.apply_updates(params, updates)
        metrics = dict(metrics, grad_norm=grad_norm)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        _, metrics = model_lib.lm_loss(params, cfg, batch, remat=False)
        return metrics

    return eval_step
