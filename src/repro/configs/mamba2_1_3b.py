"""Mamba2-1.3B — SSD state-space model, attention-free [arXiv:2405.21060].

Assignment row: [ssm] 48L d_model=2048 (attn-free) d_ff=0 vocab=50280,
ssm_state=128.  Decode is an O(1) recurrent-state update, so all decode
shapes including long_500k are eligible.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    ssm_groups=1,
    source="arXiv:2405.21060 (Transformers are SSMs — Mamba-2 / SSD)",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-smoke", family="ssm", num_layers=2, d_model=256,
        vocab_size=2048, ssm_state=16, ssm_head_dim=32, ssm_expand=2,
        ssm_conv_width=4, ssm_chunk=8, source=CONFIG.source)
