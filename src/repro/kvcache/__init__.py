"""Paged KV-cache subsystem (vLLM-style block tables + prefix reuse).

PR 1's continuous engine reserves a contiguous ``(slots, max_len)`` KV
cache, so concurrency is pinned to the worst-case output length — the
exact uncertainty-inflated bound RT-LM identifies.  This package
decouples the two: KV memory is a pool of fixed-size blocks, sequences
own *block tables*, and memory scales with live tokens instead of
slots.  On top of that indirection, shared prompt PREFIXES (personas,
system prompts) can map many sequences to the same physical blocks.

  allocator.BlockAllocator — host-side free-list allocator with
      per-sequence block tables, per-block REFERENCE COUNTS (sharing /
      copy-on-write via ``share``/``cow_block``; a block frees only at
      refcount zero) and a ``reclaim`` hook for cache eviction under
      pool pressure.
  allocator.blocks_for_tokens — the shared memory formula
      ``ceil(tokens / block_size)`` used by the engine's admission gate
      and the simulator's block-budget model (they must agree exactly
      for engine-vs-sim parity).
  allocator.window_target_tokens — the multi-step decode-window
      extension target (eviction-lag accounting for the async host
      pipeline): pre-window allocation covers every USEFUL write of an
      N-step launch, clamped at the admission reservation so overhang
      writes past EOS/cap never touch foreign blocks and rejection
      decisions are independent of N.
  paged.PagedKVCache — device-side paged K/V store (one
      ``(num_blocks, block_size, kv_heads, head_dim)`` array pair per
      layer) plus the pure-jnp gather/scatter/copy primitives the
      model's paged decode path and the Pallas paged kernels are built
      on.
  prefix.PrefixCache — content-hash prefix index over written prompt
      blocks: longest-cached-prefix matching at block granularity
      (``block_hashes`` hash chain), LRU eviction of unreferenced
      cached blocks only under allocator pressure, and copy-on-write
      on the one divergent write the engine performs (the recomputed
      final position of a fully matched prompt).  Pure host-side,
      driven identically by the real engine and the simulator.

Wiring: models/transformer.py (``init_paged_cache`` / ``write_paged`` /
``copy_paged_block`` / paged decode + chunk attention),
serving/engine.py (``kv="paged"``, ``prefix_cache=True`` for
``mode="continuous"``), core/simulator.py (block-budget admission and
the same host-side prefix-cache model), kernels/ (Pallas
``paged_decode_attention`` and ``chunked_prefill_attention`` over block
tables).  See docs/ARCHITECTURE.md for the full configuration matrix.
"""

from .allocator import (BlockAllocator, blocks_for_tokens,  # noqa: F401
                        window_target_tokens)
from .paged import PagedKVCache  # noqa: F401
from .prefix import PrefixCache, block_hashes  # noqa: F401
