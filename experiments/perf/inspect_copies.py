import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, jax, re, collections
from repro import configs
from repro.launch import mesh as mesh_lib, specs, hlo_cost
from repro.sharding import context as shctx, policy as policy_lib

cfg = configs.get_config("yi-6b")
shape = configs.INPUT_SHAPES["decode_32k"]
mesh = mesh_lib.make_production_mesh()
policy = policy_lib.make_policy(mesh, fsdp=False); policy.serving = True
step = specs.make_step_fn(cfg, shape)
args, _ = specs.input_specs(cfg, shape)
in_sh, out_sh, donate = specs.step_shardings(cfg, shape, policy)
with mesh, shctx.use_policy(policy):
    compiled = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                       donate_argnums=donate).lower(*args).compile()
txt = compiled.as_text()
comps, entry = hlo_cost.parse_module(txt)
# find big no-metadata traffic ops
rows = []
for cname, comp in comps.items():
    for on in comp.order:
        op = comp.ops[on]
        if 'op_name=' in op.line: continue
        if op.kind not in hlo_cost._TRAFFIC_OPS: continue
        b = hlo_cost._shape_bytes(op.result_shapes)
        if b > 2**24:
            rows.append((b, cname, op.kind, op.line.strip()[:160]))
rows.sort(reverse=True)
for b, cname, kind, line in rows[:15]:
    print(f"{b/2**20:9.1f} MiB  {cname[:28]:28s} {kind:10s} {line[:110]}")
