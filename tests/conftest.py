"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the single real CPU device; only launch/dryrun.py (and
the dedicated dry-run subprocess tests) use 512 placeholder devices."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
