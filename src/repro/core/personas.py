"""Length/latency personas for the paper's five evaluated LMs.

The container is offline (no HuggingFace weights), so the five LMs —
DialoGPT-medium, GODEL-v1_1-base, BlenderBot-400M-distill, BART-base,
T5-base — are emulated as *personas*: per-model coefficient profiles that
map an input's true uncertainty to an output length and the output length
to a latency.  All published constants come straight from the paper
(§V-A Hyper-parameters: batch sizes C_f, malicious thresholds tau_f,
output-latency coefficients eta_f, input-latency coefficients phi_f; §V-H:
~415 ms mean inference latency).  The scheduler under test only ever sees
(features, predicted u, d, r), so fidelity of the *resource-management*
evaluation is preserved.

A sixth entry ("jax-tiny") binds a persona to the real JAX engine for the
end-to-end integration example.
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Persona:
    name: str
    batch_size: int          # C_f      (paper Fig. 8a)
    malicious_tau: float     # tau_f    (paper Fig. 8b, k=0.9)
    eta: float               # eta_f    s/output-token (paper §V-A)
    phi: float               # phi_f    s/input-token  (paper §V-A)
    base_output: float       # output-length intercept (tokens)
    uncertainty_gain: float  # tokens of output per unit true uncertainty
    noise_std: float         # output-length noise (tokens)
    setup_time: float        # per-batch fixed cost (s)
    cpu_slowdown: float      # CPU-lane execution multiplier
    max_output: int = 128
    item_time: float = 0.02  # per-batch-member cost (s) — memory-bandwidth
                             # term of batched decode; keeps oversize
                             # consolidated batches from being free

    def output_latency(self, out_len: float) -> float:
        return self.setup_time + self.eta * out_len + self.item_time

    def batch_latency(self, out_lens) -> float:
        """Batched autoregressive decode runs until the longest member."""
        return (self.setup_time + self.eta * max(out_lens)
                + self.item_time * len(out_lens))


PERSONAS: Dict[str, Persona] = {
    "dialogpt": Persona("dialogpt", 11, 35.0, 0.05, 0.08,
                        base_output=8.0, uncertainty_gain=2.6,
                        noise_std=2.5, setup_time=0.11, cpu_slowdown=3.0),
    "godel": Persona("godel", 24, 34.0, 0.04, 0.10,
                     base_output=10.0, uncertainty_gain=2.4,
                     noise_std=2.5, setup_time=0.13, cpu_slowdown=3.5),
    "blenderbot": Persona("blenderbot", 33, 29.0, 0.10, 0.13,
                          base_output=9.0, uncertainty_gain=2.0,
                          noise_std=2.0, setup_time=0.16, cpu_slowdown=4.0),
    "bart": Persona("bart", 11, 26.0, 0.05, 0.08,
                    base_output=7.0, uncertainty_gain=1.9,
                    noise_std=1.8, setup_time=0.08, cpu_slowdown=2.5),
    "t5": Persona("t5", 33, 22.0, 0.04, 0.07,
                  base_output=6.0, uncertainty_gain=1.6,
                  noise_std=1.6, setup_time=0.09, cpu_slowdown=2.5),
}

PERSONA_NAMES = tuple(PERSONAS)


def get_persona(name: str) -> Persona:
    return PERSONAS[name]


# ---------------------------------------------------------------------------
# hardware platforms (paper §V-E: edge server vs NVIDIA AGX Xavier)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    speed_factor: float        # execution-time multiplier vs edge server
    cpu_ratio_factor: float    # scales the GPU:CPU gap (embedded SoCs
                               # have a narrower gap: weaker GPU, same-die
                               # memory)


PLATFORMS = {
    # RTX A4500 + 96-core EPYC (Table II)
    "edge_server": Platform("edge_server", 1.0, 1.0),
    # Volta iGPU + 8-core Carmel; ~6x slower absolute, narrower GPU:CPU gap
    "agx_xavier": Platform("agx_xavier", 6.0, 0.7),
}


def on_platform(persona: Persona, platform_name: str) -> Persona:
    """Rescale a persona's latency model to another platform."""
    pf = PLATFORMS[platform_name]
    if pf.speed_factor == 1.0 and pf.cpu_ratio_factor == 1.0:
        return persona
    # NOTE: keep .name unchanged — datagen keys ground-truth output
    # lengths by persona name (lengths are model properties; only the
    # latency coefficients are platform properties).
    return dataclasses.replace(
        persona,
        eta=persona.eta * pf.speed_factor,
        phi=persona.phi * pf.speed_factor,
        setup_time=persona.setup_time * pf.speed_factor,
        item_time=persona.item_time * pf.speed_factor,
        cpu_slowdown=max(1.5, persona.cpu_slowdown * pf.cpu_ratio_factor),
    )
