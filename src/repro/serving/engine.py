"""Real serving engine: RT-LM scheduling over the actual JAX model.

This is the end-to-end integration of the paper's ecosystem with the
model substrate: requests (text + arrival time) flow through RULEGEN ->
m_theta -> the UASCHED policy, and execution happens on the REAL batched
prefill/greedy-decode JAX engine (tiny configs on CPU; the same code
path jit-lowers for the production mesh).

Two execution modes:

  * ``mode="batch"`` — the paper's run-to-completion model: the policy
    forms whole batches, each batch decodes until its LONGEST member
    finishes (head-of-line blocking on output-length variance — exactly
    the pathology RT-LM quantifies).
  * ``mode="continuous"`` — iteration-level batching: a persistent
    decode loop over C slots backed by one preallocated per-slot KV
    cache (transformer.init_slot_cache).  Finished sequences are evicted
    PER DECODE STEP and the policy's ``admit`` is consulted to fill each
    freed slot (uncertainty-aware admission instead of batch formation).
    Admission prefills the request into its slot through one jitted
    executable (bucketed (1, input_bucket) shape, traced slot index);
    the decode step reuses one jitted (C, 1) executable throughout.

Adaptation note (DESIGN.md §2): a CPU-only container has no heterogeneous
co-processor, so the "CPU lane" is a *bulk lane* — a second execution
queue drained only when the main lane is idle, emulating resource
isolation of high-uncertainty tasks.  On a TPU pod the same lane maps to
a dedicated low-priority replica slice.

Batches are padded to (policy.max_batch(), input_bucket) — b * C for the
consolidating UASCHED policies, C otherwise — so a dynamically
consolidated batch executes as ONE batch (as the simulator models it)
and the jitted prefill/decode executables are reused across batches.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import priority as prio
from repro.core import scheduler as sched_lib
from repro.core.personas import Persona
from repro.models import transformer

from . import generate

EOS_ID = 1


def hash_tokenize(text: str, vocab_size: int, max_len: int) -> List[int]:
    """Toy deterministic tokenizer: word -> stable hash id (2..V-1)."""
    toks = []
    for w in text.lower().split()[:max_len]:
        h = 2166136261
        for c in w.encode():
            h = ((h ^ c) * 16777619) & 0xFFFFFFFF
        toks.append(2 + (h % (vocab_size - 2)))
    return toks or [2]


@dataclasses.dataclass
class Request:
    text: str
    arrival: float
    task_id: int
    # optional per-request decode budget (None -> engine default); with
    # EOS disabled this IS the output length — how the benchmarks build
    # deterministic heterogeneous-output-length workloads.
    max_new_tokens: Optional[int] = None
    # filled at completion:
    start: float = -1.0
    finish: float = -1.0
    lane: str = ""
    out_len: int = 0
    slot: int = -1               # decode slot served in (continuous mode)

    @property
    def response_time(self) -> float:
        return self.finish - self.arrival


class ServingEngine:
    """Single-node engine with a pluggable scheduling policy.

    mode="batch": policy.select forms run-to-completion batches.
    mode="continuous": policy.admit fills decode slots per step.
    """

    def __init__(self, params, cfg, policy: sched_lib.Policy,
                 profile: sched_lib.OfflineProfile, *,
                 input_bucket: int = 32, max_new_tokens: int = 32,
                 xi: float = 2.0, mode: str = "batch",
                 eos_id: int = EOS_ID):
        if mode not in ("batch", "continuous"):
            raise ValueError(f"unknown mode {mode!r}")
        self.params = params
        self.cfg = cfg
        self.policy = policy
        self.profile = profile
        self.persona = policy.persona
        self.input_bucket = input_bucket
        self.max_new_tokens = max_new_tokens
        self.xi = xi
        self.mode = mode
        self.eos_id = eos_id
        self.max_len = input_bucket + max_new_tokens + 8
        # batch-mode executables are preallocated at the policy's max
        # consolidated batch (b * C for UASCHED, C otherwise) so a
        # consolidated batch runs as ONE batch, matching the simulator;
        # padded rows are capped at a single token (see _run_batch).
        self.batch_capacity = policy.max_batch()
        self._prefill = generate.make_prefill_fn(cfg, self.max_len)
        self._decode = generate.make_decode_fn(cfg)
        self._slot_prefill = generate.make_slot_prefill_fn(cfg, self.max_len)
        self.scheduler_overhead_s = 0.0
        # exposed for the slot-recycling tests: per-slot cache after the
        # last continuous serve, and the admission audit trail
        self.slot_cache = None
        self.admission_log: List[Dict] = []

    # ------------------------------------------------------------------
    def _to_sim_task(self, req: Request) -> prio.SimTask:
        t0 = time.perf_counter()
        u = self.profile.predictor.score(req.text)
        d = prio.priority_point(req.arrival, len(req.text.split()),
                                self.persona.phi, None, xi=self.xi)
        self.scheduler_overhead_s += time.perf_counter() - t0
        st = prio.SimTask(task=req, u=float(max(u, 0.0)), r=req.arrival,
                          d=d, input_len=float(len(req.text.split())),
                          true_out_len=0)
        return st

    def _tokenize_padded(self, text: str) -> np.ndarray:
        S = self.input_bucket
        arr = np.zeros((S,), np.int32)
        seq = hash_tokenize(text, self.cfg.vocab_size, S)
        arr[S - len(seq):] = seq                        # left-pad
        return arr

    def _cap(self, req: Request) -> int:
        cap = (req.max_new_tokens if req.max_new_tokens is not None
               else self.max_new_tokens)
        return max(1, min(cap, self.max_new_tokens))

    def _run_batch(self, batch: Sequence[prio.SimTask], lane: str,
                   now: float) -> float:
        """Execute a run-to-completion batch; returns finish time."""
        Cb = self.batch_capacity
        S = self.input_bucket
        arr = np.zeros((Cb, S), np.int32)
        for i, t in enumerate(batch):
            arr[i] = self._tokenize_padded(t.task.text)
        tokens = jnp.asarray(arr)
        # padded rows stop after one token so they never extend the
        # batch's decode horizon (the run-to-completion cost is set by
        # the longest REAL member, as in the simulator's latency model)
        caps = np.ones((Cb,), np.int32)
        caps[:len(batch)] = [self._cap(t.task) for t in batch]
        t0 = time.perf_counter()
        out_tokens, lengths = generate.generate(
            self.params, self.cfg, {"tokens": tokens},
            max_new_tokens=self.max_new_tokens, eos_id=self.eos_id,
            prefill_fn=self._prefill, decode_fn=self._decode,
            max_lens=caps)
        jax.block_until_ready(out_tokens)
        dur = time.perf_counter() - t0
        if lane == "cpu":
            dur *= self.persona.cpu_slowdown   # bulk-lane emulation
        finish = now + dur
        for i, t in enumerate(batch):
            t.start, t.finish, t.lane = now, finish, lane
            t.task.start, t.task.finish, t.task.lane = now, finish, lane
            t.task.out_len = int(lengths[i]) if i < len(lengths) else 0
        return finish

    # ------------------------------------------------------------------
    def serve(self, requests: Sequence[Request]) -> Dict:
        """Run a full trace (virtual-time arrivals, real execution)."""
        if self.mode == "continuous":
            return self._serve_continuous(requests)
        return self._serve_batch(requests)

    def _result(self, done: List[prio.SimTask], n: int) -> Dict:
        rts = np.array([t.response_time for t in done])
        return {
            "mean_response_s": float(rts.mean()),
            "max_response_s": float(rts.max()),
            "throughput_per_min": 60.0 * n / max(
                max(t.finish for t in done) - min(t.r for t in done), 1e-9),
            "scheduler_overhead_s": self.scheduler_overhead_s,
            "n_tasks": n,
            "tasks": done,
            "completion_order": [t.task.task_id for t in done],
            "mode": self.mode,
        }

    def _serve_batch(self, requests: Sequence[Request]) -> Dict:
        pending = sorted(requests, key=lambda r: r.arrival)
        sim_tasks = [self._to_sim_task(r) for r in pending]
        queue: List[prio.SimTask] = []
        bulk: List[prio.SimTask] = []
        done: List[prio.SimTask] = []
        now = 0.0
        i = 0
        n = len(sim_tasks)
        C = self.persona.batch_size
        while len(done) < n:
            while i < n and sim_tasks[i].r <= now + 1e-9:
                queue.append(sim_tasks[i])
                i += 1
            if queue and (len(queue) >= C
                          or now - min(t.r for t in queue) >= self.xi
                          or i >= n):
                t0 = time.perf_counter()
                gpu_b, cpu_b, rest = self.policy.select(list(queue), now)
                self.scheduler_overhead_s += time.perf_counter() - t0
                queue = list(rest)
                bulk.extend(cpu_b)
                if gpu_b:
                    Cb = self.batch_capacity
                    now = self._run_batch(gpu_b[:Cb], "gpu", now)
                    done.extend(gpu_b[:Cb])
                    queue.extend(gpu_b[Cb:])
                    continue
            if bulk and not queue:
                batch, bulk = bulk[:C], bulk[C:]
                now = self._run_batch(batch, "cpu", now)
                done.extend(batch)
                continue
            # idle: advance to next arrival / window expiry
            cand = []
            if i < n:
                cand.append(sim_tasks[i].r)
            if queue:
                cand.append(min(t.r for t in queue) + self.xi)
            future = [c for c in cand if c > now]
            if future:
                now = min(future)
            else:
                now += self.xi
        return self._result(done, n)

    # ------------------------------------------------------------------
    # continuous batching: persistent decode loop with slot recycling
    # ------------------------------------------------------------------

    def _serve_continuous(self, requests: Sequence[Request]) -> Dict:
        persona = self.persona
        C = persona.batch_size
        pending = sorted(requests, key=lambda r: r.arrival)
        sim_tasks = [self._to_sim_task(r) for r in pending]
        n = len(sim_tasks)
        queue: List[prio.SimTask] = []
        bulk: List[prio.SimTask] = []
        done: List[prio.SimTask] = []
        cache = transformer.init_slot_cache(self.cfg, C, self.max_len)
        slot_task: List[Optional[prio.SimTask]] = [None] * C
        slot_gen = [0] * C
        slot_cap = [0] * C
        tokens = np.zeros((C, 1), np.int32)     # host copy of next tokens
        self.admission_log = []
        now = 0.0
        i = 0
        step = 0
        while len(done) < n:
            while i < n and sim_tasks[i].r <= now + 1e-9:
                queue.append(sim_tasks[i])
                i += 1

            # --- admissions: fill freed slots, one policy call per slot
            while queue and None in slot_task:
                running = [t for t in slot_task if t is not None]
                t0 = time.perf_counter()
                task, lane, rest = self.policy.admit(list(queue), now,
                                                     running)
                self.scheduler_overhead_s += time.perf_counter() - t0
                if task is None:
                    break
                queue = list(rest)
                if lane == "cpu":
                    bulk.append(task)
                    continue
                slot = slot_task.index(None)
                batch = {"tokens": jnp.asarray(
                    self._tokenize_padded(task.task.text)[None, :])}
                t0 = time.perf_counter()
                cache, last_logits = self._slot_prefill(
                    self.params, cache, batch, jnp.int32(slot))
                first = int(jnp.argmax(last_logits))
                now += time.perf_counter() - t0
                task.start, task.lane = now, "gpu"
                task.task.start, task.task.lane = now, "gpu"
                task.task.slot = slot
                self.admission_log.append(
                    {"task_id": task.task.task_id, "slot": slot,
                     "step": step, "now": now})
                cap = self._cap(task.task)
                if first == self.eos_id or cap <= 1:
                    task.finish = now
                    task.task.finish, task.task.out_len = now, 1
                    done.append(task)
                else:
                    slot_task[slot] = task
                    slot_gen[slot], slot_cap[slot] = 1, cap
                    tokens[slot, 0] = first

            active = [s for s in range(C) if slot_task[s] is not None]
            if active:
                # --- one decode step over ALL slots (single executable)
                t0 = time.perf_counter()
                next_tok, _, cache = self._decode(
                    self.params, cache, jnp.asarray(tokens))
                next_host = np.array(jax.block_until_ready(next_tok))
                now += time.perf_counter() - t0
                step += 1
                for s in active:                 # evict per step, in order
                    slot_gen[s] += 1
                    tokens[s, 0] = int(next_host[s, 0])
                    task = slot_task[s]
                    if (int(next_host[s, 0]) == self.eos_id
                            or slot_gen[s] >= slot_cap[s]):
                        task.finish = now
                        task.task.finish = now
                        task.task.out_len = slot_gen[s]
                        done.append(task)
                        slot_task[s] = None
                        tokens[s, 0] = generate.PAD_ID
                continue

            if bulk and not queue:
                batch, bulk = bulk[:C], bulk[C:]
                now = self._run_batch(batch, "cpu", now)
                done.extend(batch)
                continue

            # idle: advance to the next arrival
            if i < n:
                now = max(now, sim_tasks[i].r)
            else:
                now += self.xi
        self.slot_cache = cache
        return self._result(done, n)
