"""Chunked-prefill scheduler: token-budgeted prefill/decode interleaving.

The stall-admission continuous engine (serving/engine.py) blocks the
ENTIRE decode loop for a full ``(1, input_bucket)`` prefill on every
admission — a head-of-line source of inter-token-latency jitter that
grows with the admission burst size (C back-to-back prefills when C
slots free together).  Sarathi-style chunked prefill removes the stall:
each admitted request's (padded) prompt is split into fixed-size
chunks, and every engine iteration packs a TOKEN BUDGET with

    decode tokens first  (one per active decode slot — decode is never
                          skipped; it is the latency-critical work)
  + prefill-chunk tokens (as many whole chunks as fit in the remainder)

so per-iteration prefill work — and therefore the ITL of every in-flight
request — is bounded by ``token_budget`` instead of by the admission
burst.

Chunk ordering is the RT-LM twist: pending jobs are ranked by the
scheduling policy's uncertainty priority (``Policy.assign_priority``,
higher first; admission order breaks ties FIFO), so low-uncertainty
(short-output-predicted) requests reach their first token sooner — the
same signal that orders admission also orders time-to-first-token.

This module is pure host-side Python, deliberately free of JAX: the
real engine (``ServingEngine(prefill="chunked")``) and the simulator
(``simulate_continuous(prefill="chunked")``) drive the SAME scheduler,
which is what makes their per-iteration budget traces and completion
orders comparable bit-for-bit in the parity tests.

Invariants (property-tested in tests/test_properties.py):

  * per-iteration budget: scheduled chunk tokens never exceed
    ``max(0, token_budget - decode_tokens)``;
  * in-order chunks: a job's chunks are scheduled at strictly
    increasing offsets covering ``[0, total)`` exactly once;
  * work conservation (no starvation): whenever jobs are pending and
    the budget remainder covers a whole chunk, at least one chunk is
    scheduled — under FIFO tie-break jobs therefore finish prefill in
    admission order and every job's wait is bounded by the backlog
    ahead of it.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass
class ChunkJob:
    """One admitted request's prefill work (the padded prompt bucket)."""

    task: object                 # prio.SimTask (engine) or SimTask (sim)
    slot: int                    # decode slot reserved for this request
    total: int                   # prompt tokens to prefill (input bucket)
    priority: float              # Policy.assign_priority at admission
    seq: int                     # admission order (FIFO tie-break)
    done: int = 0                # tokens prefetched so far

    @property
    def remaining(self) -> int:
        return self.total - self.done

    def next_chunk_len(self, chunk_size: int) -> int:
        """Whole chunks of ``chunk_size``; the tail chunk is smaller."""
        return min(chunk_size, self.remaining)


@dataclasses.dataclass
class ChunkPlan:
    """One chunk to execute this iteration."""

    job: ChunkJob
    start: int                   # position offset of the chunk
    length: int
    finishes: bool               # True -> this chunk completes the prompt


class ChunkScheduler:
    """Token-budgeted chunk packer shared by engine and simulator."""

    def __init__(self, chunk_size: int, token_budget: int):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        if token_budget < chunk_size:
            raise ValueError(
                f"token_budget={token_budget} < chunk_size={chunk_size}: "
                "an idle iteration could never fit one chunk and prefill "
                "would live-lock")
        self.chunk_size = chunk_size
        self.token_budget = token_budget
        self.jobs: List[ChunkJob] = []
        self._seq = 0

    # ------------------------------------------------------------------
    @property
    def has_jobs(self) -> bool:
        return bool(self.jobs)

    def slots_in_prefill(self) -> List[int]:
        return [j.slot for j in self.jobs]

    def add(self, task, slot: int, total: int, priority: float) -> ChunkJob:
        if total < 1:
            raise ValueError(f"total must be >= 1, got {total}")
        job = ChunkJob(task=task, slot=slot, total=total,
                       priority=priority, seq=self._seq)
        self._seq += 1
        self.jobs.append(job)
        return job

    def schedule(self, decode_tokens: int) -> List[ChunkPlan]:
        """Pack this iteration's budget; advances job progress.

        Decode tokens are charged first (decode always runs); the
        remainder is filled greedily in (priority desc, admission asc)
        order — a job may get several chunks in one iteration, and a
        lower-priority job's smaller tail chunk may ride along when the
        front-runner's next chunk no longer fits.  Completed jobs are
        removed; the caller executes the returned plans in order.
        """
        rem = max(0, self.token_budget - decode_tokens)
        plans: List[ChunkPlan] = []
        for job in sorted(self.jobs, key=lambda j: (-j.priority, j.seq)):
            while job.remaining:
                length = job.next_chunk_len(self.chunk_size)
                if length > rem:
                    break
                plans.append(ChunkPlan(
                    job=job, start=job.done, length=length,
                    finishes=(job.remaining == length)))
                job.done += length
                rem -= length
        self.jobs = [j for j in self.jobs if j.remaining]
        return plans
