"""Batched generation on top of model.prefill / model.decode_step.

Two drivers:
  * ``generate()`` — host-loop greedy decoding with early exit when every
    sequence hit EOS (used by the serving engine; the host loop is what a
    real-time scheduler interleaves with queue management).
  * ``generate_scan()`` — fully-jitted lax.scan decode for a fixed number
    of steps (used by benchmarks; no host round-trips).
"""

from __future__ import annotations

import functools
import logging
import weakref
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import model as model_lib, transformer
from repro.obs import log as obslog

PAD_ID = 0

logger = logging.getLogger(__name__)

#: obs.log key of the use_pallas auto-detection degradation
FALLBACK_KEY = "jnp-fallback"


def reset_fallback_warning() -> None:
    """Re-arm the rate-limited jnp-fallback warning.

    The engine calls this at every ``serve()`` start so the warning is
    emitted at least once PER SERVE, not per process — otherwise the
    first engine constructed in a long-lived multi-config process (or
    the first test in a session) consumes the warning and every later
    serve's silent CPU fallback goes unreported.  Occurrence COUNTS
    are never cleared (``repro.obs.log.FALLBACKS``): ``_result``
    reports them as ``fallback_events`` so the degradation is
    countable, not only greppable in stderr."""
    obslog.FALLBACKS.reset(FALLBACK_KEY)


def resolve_use_pallas(use_pallas: Optional[bool]) -> bool:
    """Resolve the ``use_pallas=None`` auto-detection: the compiled
    Pallas kernels on TPU, the exact jnp fallbacks elsewhere (the
    kernels would run in slow interpret mode).  Routes the silent
    fallback through the shared rate-limited ledger
    (``repro.obs.log``) — warned once per re-arm window AND counted
    every time."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
        if not use_pallas:
            obslog.warn_once(
                logger, FALLBACK_KEY,
                "use_pallas auto-detection: backend %r is not TPU — "
                "falling back to the exact jnp kernel paths (pass "
                "use_pallas=True to force the Pallas kernels in "
                "interpret mode)", jax.default_backend())
    return use_pallas


class JitExecutable:
    """A jitted entry point plus its AOT-compiled per-shape executables.

    Transparent to existing callers — ``__call__`` forwards to the jit
    function (trace-on-first-call as before).  The serving engine's
    warmup path additionally pins ahead-of-time executables per shape
    key: ``jax.jit(...).lower(avals).compile()`` does NOT populate the
    jit call cache, so the ``Compiled`` objects are stored here and
    invoked directly via ``call_aot`` — first-request TTFT then pays
    neither trace nor compile time.  A ``call_aot`` at an unwarmed key
    falls back to the jit function (static kwargs included), so warmup
    is strictly an optimization, never a correctness dependency.

    Every dispatch runs inside a ``jax.profiler.TraceAnnotation`` named
    scope (``dispatch:<name>`` — the factory kind, e.g.
    ``dispatch:ragged``), so a ``jax.profiler.trace()`` capture of a
    serve shows which executable each device launch belongs to; the
    annotation is a no-op when no profiler is attached.
    """

    def __init__(self, fn, name: str = "jit"):
        self.fn = fn
        self.name = f"dispatch:{name}"
        self.aot: dict = {}

    def __call__(self, *args, **kwargs):
        with jax.profiler.TraceAnnotation(self.name):
            return self.fn(*args, **kwargs)

    def warm(self, key, args, static_kwargs: Optional[dict] = None):
        """AOT-compile for the abstract ``args`` (ShapeDtypeStruct
        pytrees) under ``key``; idempotent per key."""
        if key not in self.aot:
            self.aot[key] = self.fn.lower(
                *args, **(static_kwargs or {})).compile()
        return self.aot[key]

    def call_aot(self, key, *args, **static_kwargs):
        """Dispatch through the warmed executable for ``key`` when one
        exists (array args only — statics were baked at lower time),
        else through the jit function."""
        with jax.profiler.TraceAnnotation(self.name):
            compiled = self.aot.get(key)
            if compiled is not None:
                return compiled(*args)
            return self.fn(*args, **static_kwargs)


# Factory memo: values are held WEAKLY, keyed by (kind, cfg, ...), so
# an executable's lifetime is bounded by the engines that hold it —
# dropping every engine for a config drops its traces and AOT
# executables with it (the unbounded-growth fix for long-lived
# multi-config processes).  A small strong LRU rides alongside so the
# common churn pattern (tests constructing engine after engine for ONE
# config) keeps its executables hot across instances; its capacity is
# the hard bound on what the module itself keeps alive.
_fn_memo: "weakref.WeakValueDictionary" = weakref.WeakValueDictionary()
_fn_lru: "OrderedDict" = OrderedDict()
_FN_LRU_CAP = 8


def _memoized(key, build) -> JitExecutable:
    """Bounded factory memo: engines sharing a (hashable) key reuse ONE
    ``JitExecutable`` — one trace cache AND one AOT store — for as long
    as any of them (or the strong LRU) keeps it alive.  An unhashable
    key skips the memo.  The key's leading element is the factory kind
    and becomes the executable's profiler-annotation name."""
    name = key[0] if isinstance(key, tuple) and key else "jit"
    try:
        cached = _fn_memo.get(key)
    except TypeError:                      # unhashable cfg: no memo
        return JitExecutable(build(), name)
    if cached is None:
        cached = JitExecutable(build(), name)
        _fn_memo[key] = cached
    _fn_lru[key] = cached
    _fn_lru.move_to_end(key)
    while len(_fn_lru) > _FN_LRU_CAP:
        _fn_lru.popitem(last=False)
    return cached


def make_prefill_fn(cfg, max_len: int):
    def build():
        @functools.partial(jax.jit, static_argnames=())
        def prefill_fn(params, batch):
            return model_lib.prefill(params, cfg, batch, max_len)

        return prefill_fn

    return _memoized(("prefill", cfg, max_len), build)


def make_decode_fn(cfg):
    def build():
        @jax.jit
        def decode_fn(params, cache, token):
            return model_lib.decode_step(params, cfg, cache, token)

        return decode_fn

    return _memoized(("decode", cfg), build)


def make_decode_steps_fn(cfg):
    """Jitted multi-step decode window over a per-slot contiguous cache
    (``model.decode_steps``): ``num_steps`` (static) scan iterations in
    ONE launch, returning the (B, num_steps) window tokens the engine
    reads back in arrears.  ``num_steps=1`` is bit-identical to
    ``make_decode_fn``'s single step."""
    def build():
        @functools.partial(jax.jit, static_argnames=("num_steps",))
        def decode_steps_fn(params, cache, token, *, num_steps):
            return model_lib.decode_steps(params, cfg, cache, token,
                                          num_steps=num_steps)

        return decode_steps_fn

    return _memoized(("decode_steps", cfg), build)


def make_slot_prefill_fn(cfg, max_len: int):
    """Jitted continuous-batching admission: prefill one (1, S) request
    into slot ``slot`` of a per-slot decode cache.  The slot index is a
    traced operand, so ONE executable serves every slot."""
    def build():
        @jax.jit
        def slot_prefill_fn(params, cache, batch, slot):
            return model_lib.prefill_into_slot(params, cfg, cache, batch,
                                               slot, max_len)

        return slot_prefill_fn

    return _memoized(("slot_prefill", cfg, max_len), build)


def make_paged_prefill_fn(cfg, max_len: int):
    """Jitted paged admission: prefill one (1, S) request into the page
    pool at the blocks named by ``table_row``.  Slot index and table
    are traced operands, so ONE executable serves every admission."""
    def build():
        @jax.jit
        def paged_prefill_fn(params, cache, batch, slot, table_row):
            return model_lib.prefill_into_paged(params, cfg, cache, batch,
                                                slot, table_row, max_len)

        return paged_prefill_fn

    return _memoized(("paged_prefill", cfg, max_len), build)


def make_paged_decode_fn(cfg, use_pallas: Optional[bool] = None):
    """Jitted paged decode step; block tables ride as a per-call operand
    (the engine extends them host-side on block-boundary crossings).

    use_pallas: route attention through the Pallas
    ``paged_decode_attention`` kernel (no transient contiguous gather).
    ``None`` auto-selects: on TPU the compiled kernel, elsewhere the
    exact jnp gather fallback (the kernel would run in slow interpret
    mode there)."""
    use_pallas = resolve_use_pallas(use_pallas)

    def build():
        @jax.jit
        def paged_decode_fn(params, cache, token, tables):
            return model_lib.decode_step_paged(params, cfg, cache, token,
                                               tables,
                                               use_pallas=use_pallas)

        return paged_decode_fn

    return _memoized(("paged_decode", cfg, use_pallas), build)


def make_paged_decode_steps_fn(cfg, use_pallas: Optional[bool] = None):
    """Jitted paged multi-step decode window (``model.decode_steps_paged``):
    ``num_steps`` (static) scan iterations against the page pool in ONE
    launch.  Block tables are fixed across the window — the engine
    pre-extends them to ``kvcache.window_target_tokens`` — so the scan
    needs no host round-trip."""
    use_pallas = resolve_use_pallas(use_pallas)

    def build():
        @functools.partial(jax.jit, static_argnames=("num_steps",))
        def paged_decode_steps_fn(params, cache, token, tables, *,
                                  num_steps):
            return model_lib.decode_steps_paged(
                params, cfg, cache, token, tables, num_steps=num_steps,
                use_pallas=use_pallas)

        return paged_decode_steps_fn

    return _memoized(("paged_decode_steps", cfg, use_pallas), build)


def make_chunk_prefill_fn(cfg, use_pallas: Optional[bool] = None):
    """Jitted chunked-prefill step: run one (1, T) prompt chunk of slot
    ``slot`` against the paged cache at traced context offset
    ``ctx_len``, scattering its K/V through ``table_row``.  Slot, table
    and offset are traced operands, so ONE executable serves every
    chunk of every request (one retrace per distinct chunk length).
    Memoized (weakly) per ``(cfg, use_pallas)``."""
    use_pallas = resolve_use_pallas(use_pallas)

    def build():
        @jax.jit
        def chunk_prefill_fn(params, cache, batch, slot, table_row,
                             ctx_len):
            return model_lib.prefill_chunk(params, cfg, cache, batch,
                                           slot, table_row, ctx_len,
                                           use_pallas=use_pallas)

        return chunk_prefill_fn

    return _memoized(("chunk", cfg, use_pallas), build)


def make_ragged_prefill_fn(cfg, use_pallas: Optional[bool] = None):
    """Jitted FUSED chunked prefill: every scheduled chunk of one
    engine iteration in a single launch (``model.prefill_chunks``).

    The packed token stream, per-token chunk ids, metadata rows
    ``[slot, ctx_len, chunk_len, q_offset]`` and per-chunk block
    tables all ride as traced operands; ``chunk_pad`` (the padded
    per-chunk view width) is static.  jit therefore memoizes one
    executable per padded shape key ``(padded_tokens, padded_chunks,
    padded_chunk_len)`` — the ``ChunkBatch.shape_key`` buckets —
    instead of retracing per ``(chunk_len, offset)`` pair.  Memoized
    (weakly) per ``(cfg, use_pallas)`` like ``make_chunk_prefill_fn``."""
    use_pallas = resolve_use_pallas(use_pallas)

    def build():
        @functools.partial(jax.jit, static_argnames=("chunk_pad",))
        def ragged_prefill_fn(params, cache, batch, token_chunk, meta,
                              tables, *, chunk_pad):
            return model_lib.prefill_chunks(params, cfg, cache, batch,
                                            token_chunk, meta, tables,
                                            chunk_pad=chunk_pad,
                                            use_pallas=use_pallas)

        return ragged_prefill_fn

    return _memoized(("ragged", cfg, use_pallas), build)


def make_copy_block_fn(cfg):
    """Jitted copy-on-write page copy: duplicate physical block ``src``
    into ``dst`` across every layer's page pools (the prefix cache's
    full-match admission).  ``src``/``dst`` ride as traced operands, so
    ONE executable serves every CoW copy."""
    del cfg  # the cache pytree fixes every shape

    def build():
        @jax.jit
        def copy_block_fn(cache, src, dst):
            return transformer.copy_paged_block(cache, src, dst)

        return copy_block_fn

    return _memoized(("copy_block",), build)


def generate(params, cfg, batch: dict, *, max_new_tokens: int,
             eos_id: int = 1, prefill_fn=None, decode_fn=None,
             max_lens=None):
    """Greedy-decode a batch. Returns (tokens (B, T<=max_new), lengths).

    max_lens: optional (B,) per-sequence output-length caps — a sequence
    stops contributing once it has produced its cap, but the batch keeps
    stepping until its LONGEST member finishes (the head-of-line effect
    run-to-completion batching suffers from, and the baseline the
    continuous-batching engine is measured against).
    """
    max_len = batch["tokens"].shape[1] + max_new_tokens + 8
    if cfg.frontend == "vision":
        max_len += cfg.num_patch_tokens
    prefill_fn = prefill_fn or make_prefill_fn(cfg, max_len)
    decode_fn = decode_fn or make_decode_fn(cfg)

    cache, last_logits = prefill_fn(params, batch)
    B = batch["tokens"].shape[0]
    token = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
    done = (token[:, 0] == eos_id)
    lengths = jnp.ones((B,), jnp.int32)
    if max_lens is not None:
        max_lens = jnp.asarray(max_lens, jnp.int32)
        done = done | (lengths >= max_lens)
    out = [token]
    for _ in range(max_new_tokens - 1):
        if bool(done.all()):
            break
        token, _, cache = decode_fn(params, cache, token)
        token = jnp.where(done[:, None], PAD_ID, token)
        lengths = lengths + (~done).astype(jnp.int32)
        done = done | (token[:, 0] == eos_id)
        if max_lens is not None:
            done = done | (lengths >= max_lens)
        out.append(token)
    return jnp.concatenate(out, axis=1), lengths


def generate_scan(params, cfg, batch: dict, *, max_new_tokens: int):
    """Fixed-length jitted decode (benchmarks / dry-run style)."""
    max_len = batch["tokens"].shape[1] + max_new_tokens + 8
    if cfg.frontend == "vision":
        max_len += cfg.num_patch_tokens

    @jax.jit
    def run(params, batch):
        cache, last_logits = model_lib.prefill(params, cfg, batch, max_len)
        token = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]

        def body(carry, _):
            token, cache = carry
            nt, _, cache = model_lib.decode_step(params, cfg, cache, token)
            return (nt, cache), token

        (_, _), tokens = lax.scan(
            body, (token, cache), None, length=max_new_tokens)
        return tokens[:, :, 0].T                       # (B, T)

    return run(params, batch)
