"""Serving launcher: RT-LM scheduler over the real JAX engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
        --policy rt-lm --n-requests 200 --beta 120,240

Runs the full RT-LM ecosystem end to end on the smoke variant of the
chosen architecture: offline profiling (predictor training, tau), then a
Poisson request trace served with real batched prefill/decode.
"""

from __future__ import annotations

import argparse
import json

import jax

from repro import configs
from repro.core import datagen, personas, scheduler as sched_lib, workload
from repro.models import model as model_lib
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--policy", default="rt-lm",
                    choices=tuple(sched_lib.POLICIES))
    ap.add_argument("--persona", default="dialogpt",
                    choices=personas.PERSONA_NAMES)
    ap.add_argument("--n-requests", type=int, default=200)
    ap.add_argument("--beta", default="120,240",
                    help="comma-separated per-minute arrival rates")
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke_config(args.arch)
    params = model_lib.init_params(jax.random.PRNGKey(args.seed), cfg)
    persona = personas.get_persona(args.persona)

    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], args.n_requests * 2,
        seed=args.seed)
    train, test = datagen.train_test_split(corpus, train_frac=0.5)
    test = test[:args.n_requests]
    print(f"[serve] offline profiling ({len(train)} train tasks)...")
    profile = sched_lib.offline_profile(train, persona, epochs=40,
                                        seed=args.seed)
    betas = [int(b) for b in args.beta.split(",")]
    arrivals = workload.poisson_trace(len(test), betas=betas,
                                      seed=args.seed + 1)
    reqs = [Request(text=t.text, arrival=a, task_id=i)
            for i, (t, a) in enumerate(zip(test, arrivals))]

    policy = sched_lib.POLICIES[args.policy](
        persona, profile.policy_config())
    engine = ServingEngine(params, cfg, policy, profile,
                           max_new_tokens=args.max_new_tokens)
    print(f"[serve] serving {len(reqs)} requests under {args.policy} "
          f"(arch={cfg.name})...")
    res = engine.serve(reqs)
    out = {k: v for k, v in res.items() if k != "tasks"}
    out["scheduler_overhead_ms_per_task"] = (
        1000.0 * res["scheduler_overhead_s"] / res["n_tasks"])
    print(json.dumps(out, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
