from . import engine, faults, generate, replica, router  # noqa: F401
