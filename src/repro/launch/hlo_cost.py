"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop body exactly ONCE,
which makes it useless for scan-over-layers programs (a 61-layer scan
reports ~1/61 of the real FLOPs).  This module re-derives per-device
costs from ``compiled.as_text()``:

  1. parse the module into computations and ops (shapes included),
  2. build the call graph (while bodies/conditions, fusions, calls,
     conditionals) with XLA's ``known_trip_count`` annotations,
  3. propagate execution multipliers from ENTRY,
  4. accumulate per-computation costs x multiplier:
       flops            — dot ops: 2 * prod(result dims) * contracted dim
       collective bytes — result-shape bytes of all-gather / all-reduce /
                          reduce-scatter / all-to-all / collective-permute
       hbm traffic      — for top-level (non-nested) ops: operand bytes +
                          result bytes of fusions/dots/gathers/... — the
                          "fusion boundary" model of HBM traffic.

This is the profiling substrate of EXPERIMENTS.md §Roofline / §Perf.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COMP_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:{[^}]*})?")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLEE_RES = (
    re.compile(r"body=%?([\w\.\-]+)"),
    re.compile(r"condition=%?([\w\.\-]+)"),
    re.compile(r"calls=%?([\w\.\-]+)"),
    re.compile(r"to_apply=%?([\w\.\-]+)"),
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")
# ops whose operands+results cross the fusion boundary (HBM traffic).
# TPU-target model: 'convert' and 'copy' are excluded — precision changes
# fuse into neighbors on TPU and while-boundary copies are elided by
# in-place loop state (the CPU backend materializes both: hoisted f32 KV
# copies and carry copies are CPU-lowering artifacts, see EXPERIMENTS.md
# §Roofline methodology).
_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "gather", "scatter", "sort",
    "dynamic-slice", "dynamic-update-slice",
    "broadcast", "reduce", "transpose", "reshape", "slice", "concatenate",
    "pad", "select", "compare", "iota", "rng", "exponential", "add",
    "multiply", "subtract", "divide", "maximum", "minimum", "tanh",
} | set(COLLECTIVE_OPS) | {c + "-start" for c in COLLECTIVE_OPS}


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    return [(d, tuple(int(x) for x in dims.split(",")) if dims else ())
            for d, dims in _SHAPE_RE.findall(text)]


def _shape_bytes(shapes) -> int:
    return sum(_DTYPE_BYTES.get(d, 4) * math.prod(dims)
               for d, dims in shapes)


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_shapes: list
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]

    def shape_of(self, operand: str):
        op = self.ops.get(operand)
        return op.result_shapes if op else []


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        m = _COMP_HEADER_RE.match(raw.strip()) if "{" in raw else None
        if m and ("->" in raw):
            cur = Computation(m.group(2), {}, [])
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if raw.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_RE.match(raw)
        if not om:
            continue
        name, result_txt, kind = om.groups()
        op = Op(name, kind, _parse_shapes(result_txt), raw)
        cur.ops[name] = op
        cur.order.append(name)
    return comps, entry or ""


def _callees(line: str) -> List[Tuple[str, int]]:
    """(callee, trip_multiplier) pairs referenced by an op line."""
    out = []
    trip = 1
    tm = _TRIP_RE.search(line)
    if tm:
        trip = int(tm.group(1))
    for rex in _CALLEE_RES:
        for m in rex.finditer(line):
            mult = trip if rex.pattern.startswith("body") else \
                (trip + 1 if rex.pattern.startswith("condition") else 1)
            out.append((m.group(1), mult))
    bm = _BRANCHES_RE.search(line)
    if bm:
        for b in bm.group(1).split(","):
            out.append((b.strip().lstrip("%"), 1))
    return out


def _dot_flops(comp: Computation, op: Op) -> float:
    # result element count
    res = math.prod(op.result_shapes[0][1]) if op.result_shapes else 0
    m = re.search(r"dot\(([^)]*)\)", op.line)
    lhs_dims_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not (m and lhs_dims_m):
        return 0.0
    # Operands may be typed ("f32[64,128]{1,0} %Arg_0.1") or bare
    # ("%Arg_0.1") depending on the HLO printer; layout braces contain
    # commas, so splitting the operand list on "," is unsafe.  Take the
    # first %name token as the lhs, and fall back to the inline operand
    # shape when the name doesn't resolve (e.g. cross-computation refs).
    operand_txt = m.group(1)
    name_m = re.search(r"%([\w\.\-]+)", operand_txt)
    lhs_shapes = comp.shape_of(name_m.group(1)) if name_m else []
    if not lhs_shapes:
        lhs_shapes = _parse_shapes(operand_txt.split("%")[0])
    if not lhs_shapes:
        return 2.0 * res  # unknown contraction — lower bound
    lhs_dims = lhs_shapes[0][1]
    contract = 1
    for d in lhs_dims_m.group(1).split(","):
        if d:
            contract *= lhs_dims[int(d)]
    return 2.0 * res * contract


_DATA_MOVE_TOKENS = {"wrapped", "convert", "copy", "transpose", "bitcast",
                     "fusion", "broadcast", "reshape", "slice", "pad",
                     "dynamic-update-slice", "dynamic-slice", "select"}


def _is_pure_move_fusion(name: str) -> bool:
    toks = [t for t in re.split(r"[._]", name) if t and not t.isdigit()]
    return bool(toks) and all(t in _DATA_MOVE_TOKENS for t in toks)


def _operand_names(line: str, kind: str) -> List[str]:
    m = re.search(re.escape(kind) + r"\(([^)]*)\)", line)
    if not m:
        return []
    return [t.strip().lstrip("%") for t in m.group(1).split(",")
            if t.strip().startswith("%")]


@dataclasses.dataclass
class HloCost:
    flops: float
    collective_bytes: float
    traffic_bytes: float
    collective_by_kind: Dict[str, float]
    collective_counts: Dict[str, float]
    # optional per-op breakdowns (op_name metadata -> bytes/flops), used by
    # the §Perf hypothesis loop to find the dominant contributors
    traffic_by_meta: Optional[Dict[str, float]] = None
    flops_by_meta: Optional[Dict[str, float]] = None
    collective_by_meta: Optional[Dict[str, float]] = None


_META_RE = re.compile(r'op_name="([^"]*)"')


def _meta_key(line: str) -> str:
    m = _META_RE.search(line)
    if not m:
        return "<no-metadata>"
    name = m.group(1)
    # collapse uniquifying suffixes: keep the jaxpr path head
    parts = name.split("/")
    return "/".join(parts[:8])


def module_cost(hlo_text: str, breakdown: bool = False) -> HloCost:
    comps, entry = parse_module(hlo_text)
    if not entry:
        return HloCost(0, 0, 0, {}, {})

    # execution multiplier per computation (call-graph walk, fixpoint)
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    # topological-ish: iterate until stable (call graph is a DAG)
    for _ in range(len(comps) + 2):
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        changed = False
        for cname, comp in comps.items():
            if mult[cname] == 0.0:
                continue
            for oname in comp.order:
                for callee, m in _callees(comp.ops[oname].line):
                    if callee in new:
                        new[callee] += mult[cname] * m
        for k in comps:
            if abs(new[k] - mult[k]) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break

    flops = 0.0
    coll = {k: 0.0 for k in COLLECTIVE_OPS}
    coll_n = {k: 0.0 for k in COLLECTIVE_OPS}
    traffic = 0.0
    t_meta: Dict[str, float] = {}
    f_meta: Dict[str, float] = {}
    c_meta: Dict[str, float] = {}
    for cname, comp in comps.items():
        w = mult[cname]
        if w == 0.0:
            continue
        fused = cname.startswith("fused_") or "fused_computation" in cname
        for oname in comp.order:
            op = comp.ops[oname]
            if op.kind == "dot":
                df = w * _dot_flops(comp, op)
                flops += df
                if breakdown:
                    k = _meta_key(op.line)
                    f_meta[k] = f_meta.get(k, 0.0) + df
            base_kind = op.kind[:-6] if op.kind.endswith("-start") else \
                op.kind
            if base_kind in COLLECTIVE_OPS and not op.kind.endswith("-done"):
                sizes = [_DTYPE_BYTES.get(d, 4) * math.prod(dims)
                         for d, dims in op.result_shapes]
                if sizes:
                    b = max(sizes) if op.kind.endswith("-start") \
                        else sum(sizes)
                    coll[base_kind] += w * b
                    coll_n[base_kind] += w
                    if breakdown:
                        k = _meta_key(op.line)
                        c_meta[k] = c_meta.get(k, 0.0) + w * b
            if not fused and op.kind in _TRAFFIC_OPS:
                operands = _operand_names(op.line, op.kind)
                is_dus = op.kind == "dynamic-update-slice" or (
                    op.kind == "fusion"
                    and "dynamic-update-slice" in op.name)
                if not is_dus and op.kind == "fusion" and \
                        _is_pure_move_fusion(op.name):
                    # precision/layout-change fusions (f32 weight copies,
                    # transposes for CPU dots) — fused away on TPU; the
                    # consuming dot already counts its operand reads.
                    continue
                if is_dus:
                    # in-place on TPU (donated buffers): traffic = read +
                    # write of the update slice = the smallest non-scalar
                    # operand, not the whole buffer.
                    sizes = [s for s in
                             (_shape_bytes(comp.shape_of(o))
                              for o in operands) if s > 4]
                    upd = min(sizes) if sizes else \
                        _shape_bytes(op.result_shapes)
                    total = 2 * upd
                else:
                    total = _shape_bytes(op.result_shapes) + sum(
                        _shape_bytes(comp.shape_of(o)) for o in operands)
                traffic += w * total
                if breakdown:
                    k = _meta_key(op.line)
                    t_meta[k] = t_meta.get(k, 0.0) + w * total
    return HloCost(
        flops=flops,
        collective_bytes=sum(coll.values()),
        traffic_bytes=traffic,
        collective_by_kind=coll,
        collective_counts=coll_n,
        traffic_by_meta=t_meta if breakdown else None,
        flops_by_meta=f_meta if breakdown else None,
        collective_by_meta=c_meta if breakdown else None,
    )
