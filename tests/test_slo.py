"""Windowed SLO monitor + uncertainty calibration ledger (ISSUE 8).

Acceptance properties:

  * metrics edge pins — ``Histogram.quantile`` on empty and
    single-observation histograms, out-of-range ``q`` validation even
    when empty, ``Gauge.mean`` before any ``set`` (the satellite
    hardening of ``obs.metrics``);
  * window rotation — ``WindowedHistogram`` rotates deterministically
    on the virtual clock, the merge of expired windows plus live
    windows is bit-equal to one histogram fed every sample, and (a
    deterministic stand-in for the hypothesis property — the container
    ships no hypothesis) windowed quantiles always lie between the
    live windows' min and max;
  * SLO semantics — per-class attainment judged at record time,
    unknown/empty classes resolve to the default class, idle windows
    report attainment 1.0 (never NaN);
  * calibration — streaming MAE/bias, power-of-two reliability
    buckets, and a drift score that is 0.0 until the baseline freezes
    and reaches 1.0 when the error distribution shifts entirely;
  * engine-vs-sim parity — with judgment-invariant targets
    (``inf`` always attains, ``-1.0`` never), per-class SLO counters,
    calibration counters, and snapshot observation vectors are
    bit-for-bit identical between a traced serve and a traced
    simulation at ``decode_steps in {1, 4}`` for stall and chunked;
  * off-by-default — SLO/calibration recording never alters
    scheduling, and without it the new result keys are empty;
  * slo_report — the CLI renders the checked-in mini trace
    (attainment table + reliability diagram + health table) and
    rejects schema violations.
"""

import dataclasses
import json
import math
import os
import sys

import numpy as np
import pytest

import jax

from repro import configs
from repro.core import datagen, personas, priority as prio
from repro.core import scheduler as sched, simulator, workload
from repro.obs import (CalibrationLedger, Observability, SLO_METRICS,
                       SLOMonitor, SLOSpec, TraceRecorder,
                       WindowedHistogram, timelines, u_bucket)
from repro.obs.metrics import Gauge, Histogram
from repro.serving.engine import Request, ServingEngine

SCRIPTS = os.path.join(os.path.dirname(__file__), "..", "scripts")
MINI_TRACE = os.path.join(os.path.dirname(__file__), "data",
                          "mini_trace.jsonl")

SLOTS = 3
MAX_NEW = 6
BUCKET = 8
BS = 4
CAPS = [2, 6, 1, 4, 6, 2, 3, 5, 1, 6, 2, 4]
CLS = ["interactive", "batch"] * (len(CAPS) // 2)

# judgment-invariant targets: +inf always attains; -1.0 never does
# (latencies are >= 0 — and 0.0 itself is a reachable boundary on the
# engine's clock, so 0.0 would NOT be parity-safe)
TARGETS = {"interactive": SLOSpec(),
           "batch": SLOSpec(ttft_s=-1.0, itl_s=-1.0, e2e_s=-1.0,
                            queue_wait_s=-1.0)}


# ---------------------------------------------------------------------------
# metrics hardening pins (satellite)
# ---------------------------------------------------------------------------


def test_histogram_quantile_empty_pinned():
    h = Histogram()
    for q in (0.0, 0.5, 1.0):
        assert h.quantile(q) == 0.0          # exactly 0.0, never NaN
    # out-of-range q raises even on an EMPTY histogram (validation
    # precedes the empty early-return)
    with pytest.raises(ValueError):
        h.quantile(-0.1)
    with pytest.raises(ValueError):
        h.quantile(1.1)


def test_histogram_quantile_single_observation_pinned():
    h = Histogram()
    h.record(3.7)
    for q in (0.0, 0.5, 1.0):
        assert h.quantile(q) == 3.7          # [min, max] clamp collapses


def test_gauge_mean_before_set_pinned():
    g = Gauge()
    assert g.mean == 0.0                     # not a ZeroDivisionError
    assert g.snapshot() == {"last": 0.0, "max": 0.0, "mean": 0.0}


# ---------------------------------------------------------------------------
# WindowedHistogram: rotation, lifetime equality, quantile bounds
# ---------------------------------------------------------------------------


def test_window_rotation_on_virtual_clock():
    w = WindowedHistogram(window_s=1.0, num_windows=3)
    for ts, v in ((0.5, 1.0), (1.5, 2.0), (2.5, 3.0)):
        w.record(ts, v)
    assert sorted(w.windows) == [0, 1, 2] and w.expired.count == 0
    w.record(3.5, 4.0)                       # epoch 3: epoch 0 expires
    assert sorted(w.windows) == [1, 2, 3]
    assert w.expired.count == 1 and w.count == 4
    assert w.merged().count == 3 and w.lifetime().count == 4
    w.advance(2.0)                           # clock is monotone: no-op
    assert sorted(w.windows) == [1, 2, 3]
    w.advance(10.0)                          # everything rotates out
    assert not w.windows and w.expired.count == 4
    assert w.quantile(0.5) == 0.0            # empty live view
    with pytest.raises(ValueError):
        WindowedHistogram(window_s=0.0)
    with pytest.raises(ValueError):
        WindowedHistogram(num_windows=0)


def test_expired_merge_equals_all_samples():
    """lifetime() == archive + live == one histogram fed every sample,
    bit-equal in buckets/count/min/max (merge is associative)."""
    rng = np.random.default_rng(3)
    w = WindowedHistogram(window_s=2.0, num_windows=3)
    ref = Histogram()
    ts = 0.0
    for _ in range(500):
        ts += float(rng.exponential(0.5))
        v = float(rng.lognormal(0.0, 1.5))
        w.record(ts, v)
        ref.record(v)
    assert w.expired.count > 0               # rotation actually happened
    lt = w.lifetime()
    assert lt.buckets == ref.buckets
    assert lt.count == ref.count == 500 == w.count
    assert lt.min == ref.min and lt.max == ref.max
    assert lt.total == pytest.approx(ref.total)
    for q in (0.0, 0.5, 0.9, 0.99, 1.0):
        assert lt.quantile(q) == ref.quantile(q)


def test_windowed_quantiles_within_live_extremes():
    """Deterministic stand-in for the hypothesis property (the
    container ships no hypothesis): for any record schedule, every
    windowed quantile lies within [min, max] of the live windows."""
    rng = np.random.default_rng(1234)
    checked = 0
    for _ in range(25):
        w = WindowedHistogram(window_s=float(rng.uniform(0.5, 3.0)),
                              num_windows=int(rng.integers(1, 5)))
        ts = 0.0
        for _ in range(int(rng.integers(5, 60))):
            ts += float(rng.exponential(1.0))
            w.record(ts, float(rng.lognormal(0.0, 2.0)))
        live = [h for h in w.windows.values() if h.count]
        if not live:
            continue
        lo = min(h.min for h in live)
        hi = max(h.max for h in live)
        for q in (0.0, 0.25, 0.5, 0.9, 1.0):
            assert lo <= w.quantile(q) <= hi
        checked += 1
    assert checked > 0


# ---------------------------------------------------------------------------
# SLOSpec + SLOMonitor semantics
# ---------------------------------------------------------------------------


def test_slospec_targets_and_json_roundtrip():
    s = SLOSpec(ttft_s=0.5, itl_s=0.1)
    assert s.target("ttft") == 0.5 and math.isinf(s.target("e2e"))
    with pytest.raises(KeyError):
        s.target("nope")
    assert s.to_json() == {"ttft_s": 0.5, "itl_s": 0.1}  # inf omitted
    assert SLOSpec.from_json(s.to_json()) == s


def test_monitor_resolves_unknown_class_to_default():
    mon = SLOMonitor()
    mon.observe("ttft", "", 0.0, 0.5)
    mon.observe("ttft", "never-declared", 0.0, 0.5)
    assert mon.resolve("") == "default"
    pc = mon.parity_counters()
    assert pc["slo.default.ttft.total"] == 2
    assert pc["slo.default.ttft.ok"] == 2    # default spec: all inf
    with pytest.raises(KeyError):
        mon.observe("nope", "", 0.0, 0.5)


def test_windowed_attainment_idle_and_rotation():
    mon = SLOMonitor({"a": SLOSpec(ttft_s=1.0)}, window_s=1.0,
                     num_windows=2)
    assert mon.windowed_attainment()["a"]["ttft"] == 1.0  # idle, not NaN
    mon.observe("ttft", "a", 0.5, 2.0)       # miss (epoch 0)
    assert mon.windowed_attainment()["a"]["ttft"] == 0.0
    mon.observe("ttft", "a", 1.5, 0.5)       # hit  (epoch 1)
    assert mon.windowed_attainment()["a"]["ttft"] == 0.5
    mon.observe("ttft", "a", 2.5, 0.5)       # epoch 2: epoch 0 rotates
    assert mon.windowed_attainment()["a"]["ttft"] == 1.0
    # the cumulative view never forgets
    att = mon.attainment()["a"]["ttft"]
    assert (att["ok"], att["total"]) == (2, 3)
    assert att["frac"] == pytest.approx(2 / 3)
    assert att["lifetime"]["count"] == 3
    assert mon.attainment()["a"]["completions"] == 0
    assert mon.complete("a") == "a"
    assert mon.attainment()["a"]["completions"] == 1


# ---------------------------------------------------------------------------
# CalibrationLedger
# ---------------------------------------------------------------------------


def test_calibration_mae_bias_and_reliability_buckets():
    assert u_bucket(0.5) == -1 and u_bucket(1.0) == 0
    assert u_bucket(2.0) == 1 and u_bucket(7.9) == 2
    led = CalibrationLedger()
    led.record(4.0, 2)                       # bucket 2, err +2
    led.record(8.0, 10, latency_s=1.0)       # bucket 3, err -2
    led.record(0.5, 1)                       # bucket -1, err -0.5
    assert led.count == 3
    assert led.mae == pytest.approx(4.5 / 3)
    assert led.bias == pytest.approx(-0.5 / 3)
    rel = led.reliability()
    assert [r["u_lo"] for r in rel] == [0.0, 4.0, 8.0]
    assert [r["n"] for r in rel] == [1, 1, 1]
    assert rel[1]["u_mean"] == 4.0 and rel[1]["real_mean"] == 2.0
    assert led.latency.count == 1            # only the one with latency
    s = led.summary()
    assert s["count"] == 3 and len(s["reliability"]) == 3
    p = led.parity()
    assert p["bucket_counts"] == {-1: 1, 2: 1, 3: 1}
    assert "latency" not in p                # wall stays out of parity


def test_calibration_drift_freezes_then_detects_shift():
    led = CalibrationLedger(drift_window=4, drift_windows=1,
                            baseline_n=4)
    for _ in range(3):
        led.record(10.0, 10)                 # |err| = 0
        assert not led.baseline_frozen and led.drift() == 0.0
    led.record(10.0, 10)
    assert led.baseline_frozen
    assert led.drift() == 0.0                # recent == baseline
    for _ in range(4):
        led.record(100.0, 10)                # |err| = 90, new epoch
    # recent window is now entirely shifted mass: total variation 1.0
    assert led.drift() == 1.0
    with pytest.raises(ValueError):
        CalibrationLedger(drift_window=0)
    with pytest.raises(ValueError):
        CalibrationLedger(drift_windows=0)


# ---------------------------------------------------------------------------
# workload traffic classes
# ---------------------------------------------------------------------------


def test_traffic_class_declarations_and_assignment():
    classes = workload.make_traffic_classes({
        "interactive": {"slo": {"ttft_s": 0.5, "itl_s": 0.1},
                        "weight": 3.0},
        "batch": {"e2e_s": 60.0},            # bare-shorthand form
    })
    by = {c.name: c for c in classes}
    assert by["interactive"].slo.ttft_s == 0.5
    assert by["interactive"].weight == 3.0
    assert by["batch"].slo.e2e_s == 60.0
    assert math.isinf(by["batch"].slo.ttft_s)
    assert workload.slo_targets(classes) == {"interactive":
                                             by["interactive"].slo,
                                             "batch": by["batch"].slo}
    a1 = workload.assign_classes(80, classes, seed=5)
    assert a1 == workload.assign_classes(80, classes, seed=5)
    assert set(a1) == {"interactive", "batch"}
    assert a1.count("interactive") > a1.count("batch")   # 3:1 weights
    assert workload.assign_classes(3, []) == ["", "", ""]


# ---------------------------------------------------------------------------
# trace plumbing: meta line, timeline class/calibration fields
# ---------------------------------------------------------------------------


def test_trace_meta_line_roundtrip(tmp_path):
    obs = Observability(slo={"a": SLOSpec(ttft_s=0.5)})
    assert obs.trace.meta == {"slo": {"a": {"ttft_s": 0.5}}}
    obs.event("enqueue", 0.0, 0, cls="a")
    path = obs.trace.to_jsonl(str(tmp_path / "t.jsonl"))
    with open(path) as f:
        first = json.loads(f.readline())
    assert first == {"type": "meta", "slo": {"a": {"ttft_s": 0.5}}}
    back = TraceRecorder.load_jsonl(path)
    assert back.meta == {"slo": {"a": {"ttft_s": 0.5}}}


def test_timelines_carry_class_and_calibration_fields():
    rec = TraceRecorder()
    rec.event("enqueue", 0.0, 7, cls="interactive")
    rec.event("admit", 0.5, 7, 0, slot=1, u=2.25, kv_blocks=3)
    rec.event("first_token", 0.6, 7, 0, slot=1)
    rec.event("complete", 0.7, 7, 1, lane="gpu", out_len=2)
    rec.event("snapshot", 0.8, None, 2, queue_depth=0, active=1,
              kv_util=0.5)                   # no task_id: not a timeline
    tls = timelines(rec)
    assert set(tls) == {7}
    t = tls[7]
    assert t.cls == "interactive"
    assert t.u == 2.25 and t.out_len == 2
    assert t.e2e == pytest.approx(0.7)


# ---------------------------------------------------------------------------
# engine-vs-sim parity (mirrors tests/test_obs.py fixtures)
# ---------------------------------------------------------------------------


def _make_obs():
    return Observability(slo=dict(TARGETS), calibration=True,
                         snapshot_every_steps=2)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("starcoder2-3b")
    from repro.models import model as model_lib
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 64, seed=0)
    train, test = datagen.train_test_split(corpus, train_frac=0.5)
    persona = dataclasses.replace(personas.get_persona("bart"),
                                  batch_size=SLOTS)
    profile = sched.offline_profile(train, persona, epochs=15)
    texts = [test[i % 4].text for i in range(len(CAPS))]
    return cfg, params, persona, profile, texts


def _requests(texts, caps):
    return [Request(text=t, arrival=0.0, task_id=i, max_new_tokens=c,
                    traffic_class=CLS[i])
            for i, (t, c) in enumerate(zip(texts, caps))]


def _sim_tasks(texts, caps, profile, persona, xi=2.0):
    out = []
    for i, (t, c) in enumerate(zip(texts, caps)):
        u = profile.predictor.score(t)
        d = prio.priority_point(0.0, len(t.split()), persona.phi,
                                None, xi=xi)
        out.append(prio.SimTask(
            task=Request(text=t, arrival=0.0, task_id=i,
                         traffic_class=CLS[i]),
            u=float(max(u, 0.0)), r=0.0, d=d,
            input_len=float(len(t.split())), true_out_len=int(c)))
    return out


def _sim_kwargs(prefill, n, kv_num_blocks):
    kw = dict(kv_block_size=BS, kv_num_blocks=kv_num_blocks,
              prompt_len=BUCKET, decode_steps=n)
    if prefill == "chunked":
        kw.update(num_slots=SLOTS, prefill="chunked", chunk_size=3,
                  token_budget=8)
    else:
        kw.update(num_slots=4)
    return kw


@pytest.fixture(scope="module")
def run(setup):
    """Memoized classed serve with the full PR-8 obs surface on."""
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    cache = {}

    def _run(prefill="stall", n=1, traced=True):
        key = (prefill, n, traced)
        if key not in cache:
            obs = _make_obs() if traced else None
            kw = dict(decode_steps=n, obs=obs)
            if prefill == "chunked":
                kw.update(num_slots=SLOTS, prefill="chunked",
                          chunk_size=3, token_budget=8)
            else:
                kw.update(num_slots=4, kv_num_blocks=7)
            eng = ServingEngine(
                params, cfg, sched.POLICIES["fifo"](persona, pcfg),
                profile, input_bucket=BUCKET, max_new_tokens=MAX_NEW,
                mode="continuous", eos_id=-1, kv="paged",
                kv_block_size=BS, **kw)
            cache[key] = (eng, eng.serve(_requests(texts, CAPS)), obs)
        return cache[key]

    return _run


@pytest.mark.parametrize("prefill,n", [("stall", 1), ("stall", 4),
                                       ("chunked", 1), ("chunked", 4)])
def test_engine_vs_sim_slo_parity(setup, run, prefill, n):
    """The tentpole acceptance: per-class SLO counters, calibration
    counters, and snapshot observation vectors are bit-for-bit
    identical between engine and simulator."""
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng, res, eobs = run(prefill, n)
    sobs = _make_obs()
    sim = simulator.simulate_continuous(
        _sim_tasks(texts, CAPS, profile, persona),
        sched.POLICIES["fifo"](persona, pcfg), obs=sobs,
        **_sim_kwargs(prefill, n, eng.kv_num_blocks))
    assert res["completion_order"] == [t.task.task_id for t in sim.tasks]
    # event-stream parity INCLUDING the new snapshot events (their
    # wall-dependent attainment/wall fields drop out of the view)
    assert eobs.trace.parity_events() == sobs.trace.parity_events()
    assert any(e.kind == "snapshot" for e in eobs.trace.events)
    assert eobs.metrics.counters() == sobs.metrics.counters()
    # per-class SLO attainment counters: bit-for-bit, and extreme
    # targets make the judgments themselves checkable
    pc = eobs.slo.parity_counters()
    assert pc == sobs.slo.parity_counters()
    for m in SLO_METRICS:
        assert pc[f"slo.interactive.{m}.ok"] \
            == pc[f"slo.interactive.{m}.total"] > 0
        assert pc[f"slo.batch.{m}.ok"] == 0 < pc[f"slo.batch.{m}.total"]
    assert pc["slo.interactive.completions"] == len(CAPS) // 2
    assert pc["slo.batch.completions"] == len(CAPS) // 2
    assert eobs.metrics.counters()["slo.completions.interactive"] \
        == len(CAPS) // 2
    # calibration: eos is disabled, so realized out_len == CAPS and the
    # ledger is exactly reproducible from the predictor's u scores
    cal = eobs.calibration.parity()
    assert cal == sobs.calibration.parity()
    assert cal["count"] == len(CAPS)
    exp_err = sum(t.u - c for t, c in
                  zip(_sim_tasks(texts, CAPS, profile, persona), CAPS))
    assert cal["err_sum"] == pytest.approx(exp_err)
    # health snapshots: same cadence (shared step coordinate), same
    # observation vector; wall extras only on the engine side
    eh, sh = eobs.health_trace, sobs.health_trace
    assert len(eh) == len(sh) > 0
    for a, b in zip(eh, sh):
        for k in ("step", "queue_depth", "active", "kv_util", "drift",
                  "calibration_count"):
            assert a[k] == b[k], k
    assert "wall" in eh[0] and "wall" not in sh[0]
    # result surfacing on both sides + the live-health accessor
    assert res["slo_attainment"] == eobs.slo.attainment()
    assert res["calibration"]["count"] == len(CAPS)
    assert res["health_trace"] == eh
    assert eng.health() == eh[-1]
    assert sim.slo_attainment == sobs.slo.attainment()
    assert sim.calibration["count"] == len(CAPS)
    assert sim.health_trace == sh


def test_slo_recording_changes_nothing(setup, run):
    """SLO/calibration/snapshot recording never alters scheduling, and
    without obs the new result keys are empty."""
    _, plain, none_obs = run("stall", 1, traced=False)
    _, traced, obs = run("stall", 1, traced=True)
    assert none_obs is None
    for key in ("completion_order", "prefill_dispatches",
                "decode_dispatches", "decode_steps_executed",
                "rejected_for_memory", "exec_cache_hits",
                "fallback_events"):
        assert plain[key] == traced[key], key
    assert plain["slo_attainment"] == {}
    assert plain["calibration"] == {}
    assert plain["health_trace"] == []
    assert plain["obs_overhead_s"] == 0.0


def test_sim_slo_recording_changes_nothing(setup):
    """Simulator twin of the off-by-default guard."""
    cfg, params, persona, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    runs = []
    for obs in (None, _make_obs()):
        runs.append(simulator.simulate_continuous(
            _sim_tasks(texts, CAPS, profile, persona),
            sched.POLICIES["fifo"](persona, pcfg),
            obs=obs, **_sim_kwargs("chunked", 2, 24)))
    plain, traced = runs
    assert [t.task.task_id for t in plain.tasks] \
        == [t.task.task_id for t in traced.tasks]
    assert plain.summary() == traced.summary()
    assert plain.slo_attainment == {} and plain.calibration == {}
    assert plain.health_trace == []
    assert traced.slo_attainment and traced.health_trace


# ---------------------------------------------------------------------------
# slo_report CLI on the checked-in mini trace
# ---------------------------------------------------------------------------


def _slo_report():
    sys.path.insert(0, SCRIPTS)
    try:
        import slo_report
    finally:
        sys.path.pop(0)
    return slo_report


def test_mini_trace_slo_report(capsys):
    sr = _slo_report()
    assert sr.main([MINI_TRACE]) == 0
    text = capsys.readouterr().out
    assert "class" in text and "reliability" in text
    assert "queue_depth" in text             # health table rendered
    assert sr.main([MINI_TRACE, "--json"]) == 0
    stats = json.loads(capsys.readouterr().out)
    assert stats["requests"] > 0 and stats["snapshots"] > 0
    assert "interactive" in stats["classes"]
    assert stats["calibration"]["count"] > 0


def test_slo_report_rejects_bad_traces(tmp_path):
    sr = _slo_report()
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"type": "event", "kind": "teleport",
                               "ts": 0.0, "task_id": 0}) + "\n")
    assert sr.main([str(bad)]) == 1
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert sr.main([str(empty)]) == 1
