"""Fault-injection harness + failure-aware serving (ISSUE 10).

Acceptance properties:

  * deterministic fault machinery — seeded capped-exponential backoff
    is a pure function of (seed, task, attempt); the circuit breaker
    walks closed -> open -> half_open -> closed on the placement
    counter; ``FaultPlan.validate`` rejects malformed schedules;
  * shedding order — doomed requests time out before admission, then
    bulk classes shed first, then the highest-``u`` predicted
    deadline-missers;
  * engine-vs-sim parity under faults — the same ``FaultPlan`` drives
    ``ReplicatedEngine`` and ``simulate_replicated`` to bit-identical
    placements, failover decisions, per-replica parity event streams
    and fault counters (mid-trace crash at R in {2, 4}, fifo and rt-lm
    policies; transient dispatch faults; breaker recovery with a
    ``replica_up`` probe; deadline timeouts and uncertainty-aware
    shedding on the single-replica twins);
  * unfaulted byte-identity — with ``faults=None`` no fault-gated
    result key, event kind or ``faults.*`` counter appears anywhere;
  * terminal conservation — every request ends in exactly one of
    {complete, timed_out, shed, dead_lettered} and the driver never
    hangs, under deterministic all-down schedules and a hypothesis
    sweep over ``random_plan`` (plus its always-on seeded mirror);
  * the completion worker survives a poisoned decode readback: the
    exception surfaces at the consume point, ``close()`` is idempotent
    and the engine's serve() teardown leaves no worker behind.
"""

import dataclasses
import types

import numpy as np
import pytest

import jax

from repro import configs
from repro.core import datagen, personas, priority as prio
from repro.core import scheduler as sched, simulator, workload
from repro.kvcache import BlockAllocator
from repro.obs import Observability
from repro.obs.slo import SLOSpec
from repro.serving.engine import Request, ServingEngine
from repro.serving.faults import (CircuitBreaker, CrashFault,
                                  FaultCoordinator, FaultPlan,
                                  ReplicaFaults, RetryPolicy, ShedPolicy,
                                  SlowFault, TransientFault, deadline_of,
                                  random_plan, shed_pass)
from repro.serving.pipeline import CompletionWorker
from repro.serving.replica import ReplicatedEngine
from repro.serving.router import Router

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAVE_HYPOTHESIS = False

SLOTS = 3
MAX_NEW = 6
BUCKET = 8
BS = 4
BLOCKS = 64                      # per-replica pool (generous: no rejects)

PERSONA = dataclasses.replace(personas.get_persona("bart"),
                              batch_size=SLOTS)
PCFG = sched.PolicyConfig(u_scale=30.0, tau=1e18)
SIM_KW = dict(xi=0.5, per_task_overhead_s=0.01, num_slots=SLOTS,
              kv_block_size=BS, kv_num_blocks=BLOCKS, prompt_len=BUCKET)

FAULT_KINDS = ("timeout", "shed", "retry", "failover", "replica_down",
               "replica_up", "dead_letter")


# ---------------------------------------------------------------------------
# unit: retry backoff, breaker, plan validation, shed ordering (no jax)
# ---------------------------------------------------------------------------


def test_backoff_deterministic_and_capped():
    rp = RetryPolicy(budget=3, base_s=0.5, cap_s=4.0, jitter_frac=0.25,
                     seed=7)
    a = [rp.backoff_s(11, k) for k in (1, 2, 3, 4, 5)]
    b = [RetryPolicy(budget=3, base_s=0.5, cap_s=4.0, jitter_frac=0.25,
                     seed=7).backoff_s(11, k) for k in (1, 2, 3, 4, 5)]
    assert a == b                               # pure function of inputs
    for k, v in enumerate(a, start=1):
        base = min(4.0, 0.5 * 2.0 ** (k - 1))
        assert base <= v <= base * 1.25
    # seed and task id both feed the jitter mix
    assert rp.backoff_s(11, 1) != RetryPolicy(seed=8).backoff_s(11, 1) \
        or rp.backoff_s(12, 1) != rp.backoff_s(11, 1)


def test_breaker_transitions_on_placement_counter():
    br = CircuitBreaker(2, failure_threshold=2, cooldown_placements=3)
    assert br.health(0, 0) == "closed"
    br.record_failure(0, 5)
    assert br.health(0, 5) == "closed"          # below threshold
    br.record_failure(0, 6)
    assert br.state[0] == "open"
    assert br.health(0, 7) == "open"            # cooling down
    assert br.health(0, 9) == "half_open"       # probe window
    br.close(0)
    assert br.health(0, 9) == "closed"
    br.record_failure(1, 0)
    br.record_success(1)                        # success resets the run
    br.record_failure(1, 1)
    assert br.state[1] == "closed"


def test_plan_validation():
    FaultPlan(crashes=(CrashFault(0, 2),)).validate(2)
    with pytest.raises(ValueError, match="out of range"):
        FaultPlan(crashes=(CrashFault(5, 2),)).validate(2)
    with pytest.raises(ValueError, match="at most one crash"):
        FaultPlan(crashes=(CrashFault(0, 2),
                           CrashFault(0, 9))).validate(2)
    with pytest.raises(ValueError, match="at_step"):
        FaultPlan(crashes=(CrashFault(0, -1),)).validate(2)
    with pytest.raises(ValueError, match="out of range"):
        FaultPlan(slowdowns=(SlowFault(9, 0, 4),)).validate(2)
    with pytest.raises(ValueError, match="factor"):
        FaultPlan(slowdowns=(SlowFault(0, 0, 4, factor=0.0),)).validate(2)
    with pytest.raises(ValueError, match="budget"):
        FaultPlan(retry=RetryPolicy(budget=-1)).validate(2)


def test_for_replica_slices_plan():
    plan = FaultPlan(crashes=(CrashFault(1, 4),),
                     slowdowns=(SlowFault(0, 2, 6, factor=3.0),
                                SlowFault(1, 0, 2)),
                     shed=ShedPolicy(queue_depth=8), deadlines=True)
    rf0, rf1 = plan.for_replica(0), plan.for_replica(1)
    assert rf0.crash_at_step is None and rf1.crash_at_step == 4
    assert rf0.slow_factor(3) == 3.0 and rf0.slow_factor(7) == 1.0
    assert rf1.slow_factor(1) == 2.0
    assert rf0.shed.queue_depth == 8 and rf0.deadlines


def test_random_plan_always_validates():
    for seed in range(20):
        rng = np.random.default_rng(seed)
        random_plan(rng, 4, seed=seed).validate(4)


def _qtask(i, u=1.0, arrival=0.0, cls="", out=2):
    task = types.SimpleNamespace(task_id=i, traffic_class=cls)
    return prio.SimTask(task=task, u=float(u), r=float(arrival), d=1e9,
                        input_len=8.0, true_out_len=out)


def test_deadline_of():
    obs = Observability(slo={"rush": SLOSpec(e2e_s=2.0)})
    assert deadline_of(1.0, "rush", obs.slo) == 3.0
    assert deadline_of(1.0, "other", obs.slo) == float("inf")
    assert deadline_of(1.0, "rush", None) == float("inf")


def test_shed_pass_timeouts_then_bulk_then_highest_u():
    obs = Observability(slo={"rush": SLOSpec(e2e_s=-1.0)})
    # rush deadline is arrival - 1.0: already-doomed requests time out
    # at the first pre-admission check
    rf_dead = ReplicaFaults(deadlines=True)
    kept, timed, shed = shed_pass([_qtask(0, cls="rush")], now=0.0,
                                  step=0, rf=rf_dead, slo=obs.slo,
                                  obs=obs)
    assert [t.task.task_id for t in timed] == [0]
    assert not kept and not shed
    # queue 6 > depth 2 -> shed 4: bulk classes first in queue order
    # (1, 2), then predicted missers by descending u (3 then 5); the
    # 'calm' class has no finite target and never sheds
    rf_shed = ReplicaFaults(shed=ShedPolicy(queue_depth=2,
                                            bulk_classes=("batch",)))
    queue = [_qtask(1, cls="batch", u=0.1), _qtask(2, cls="batch", u=0.1),
             _qtask(3, cls="rush", u=9.0), _qtask(4, cls="rush", u=2.0),
             _qtask(5, cls="rush", u=5.0), _qtask(6, cls="calm", u=99.0)]
    kept, timed, shed = shed_pass(queue, now=0.0, step=3, rf=rf_shed,
                                  slo=obs.slo, obs=obs)
    assert not timed
    assert [t.task.task_id for t in shed] == [1, 2, 3, 5]
    assert [t.task.task_id for t in kept] == [4, 6]
    counters = obs.metrics.counters()
    assert counters["faults.timed_out"] == 1
    assert counters["faults.shed"] == 4
    kinds = [e[0] for e in obs.trace.parity_events()]
    assert kinds.count("timeout") == 1 and kinds.count("shed") == 4
    # rf=None is the no-op passthrough
    assert shed_pass(queue, now=0.0, step=0, rf=None, slo=None,
                     obs=None) == (queue, [], [])


def test_coordinator_dead_letters_when_all_replicas_open():
    router = Router(2, "least_queue")
    obs = Observability()
    coord = FaultCoordinator(
        FaultPlan(crashes=(CrashFault(0, 1), CrashFault(1, 1))),
        2, router, obs, kv_num_blocks=BLOCKS)
    coord.note_crash(0)
    coord.note_crash(1)
    assert coord.place(coord.ledger_views(), task_id=7, u=1.0, cls="",
                       arrival=0.0, need=1) is None
    assert coord.dead_lettered == 1 and coord.dead_letter_ids == [7]
    kinds = [e[0] for e in obs.trace.parity_events()]
    assert kinds == ["dead_letter"]
    assert obs.metrics.counters()["faults.dead_lettered"] == 1


def test_allocator_free_all_clears_every_sequence():
    alloc = BlockAllocator(8, BS)
    alloc.allocate_n(1, 3)
    alloc.allocate_n(2, 2)
    assert alloc.num_free == 3
    alloc.free_all()
    assert alloc.num_free == 8
    alloc.check_no_leaks()


# ---------------------------------------------------------------------------
# simulator-level: conservation, slowdowns, all-down, determinism
# ---------------------------------------------------------------------------


def _sim_only_tasks(caps, classes=None, seed=0):
    rng = np.random.default_rng(seed)
    us = rng.uniform(0.5, 12.0, size=len(caps))
    return [prio.SimTask(
        task=types.SimpleNamespace(
            task_id=i, traffic_class=(classes[i] if classes else "")),
        u=float(us[i]), r=0.0, d=1e9, input_len=float(BUCKET),
        true_out_len=int(caps[i])) for i in range(len(caps))]


def test_slow_fault_stretches_the_virtual_clock_only():
    policy = sched.POLICIES["fifo"](PERSONA, PCFG)
    base = simulator.simulate_continuous(
        _sim_only_tasks([4] * 6), policy, **SIM_KW)
    slow = simulator.simulate_continuous(
        _sim_only_tasks([4] * 6), policy,
        faults=ReplicaFaults(slowdowns=(SlowFault(0, 0, 10**6,
                                                  factor=4.0),)),
        **SIM_KW)
    assert slow.makespan > base.makespan
    # same completions, same order: only the clock stretched
    assert [t.task.task_id for t in slow.tasks] \
        == [t.task.task_id for t in base.tasks]


def test_simulate_continuous_rejects_crash_faults():
    with pytest.raises(ValueError, match="replicated"):
        simulator.simulate_continuous(
            _sim_only_tasks([2]), sched.POLICIES["fifo"](PERSONA, PCFG),
            faults=ReplicaFaults(crash_at_step=2), **SIM_KW)


def test_faults_require_stall_prefill():
    with pytest.raises(ValueError, match="stall"):
        simulator.simulate_continuous(
            _sim_only_tasks([2]), sched.POLICIES["fifo"](PERSONA, PCFG),
            faults=ReplicaFaults(), prefill="chunked", chunk_size=4,
            token_budget=16, **SIM_KW)


def _conservation(res, n):
    """Every request reaches exactly one terminal outcome."""
    completed = sum(len(r.tasks) for r in res.replicas)
    total = completed + res.timed_out + res.shed + res.dead_lettered
    assert total == n, (completed, res.timed_out, res.shed,
                        res.dead_lettered)


def test_all_replicas_down_dead_letters_and_terminates():
    # r0 dies at step 1, r1 at step 2: r0's survivors fail over to r1,
    # then go down with it -- everything unfinished dead-letters, the
    # driver never hangs
    n = 10
    plan = FaultPlan(crashes=(CrashFault(0, 1), CrashFault(1, 2)),
                     retry=RetryPolicy(budget=3))
    obs = Observability()
    res = simulator.simulate_replicated(
        _sim_only_tasks([MAX_NEW] * n),
        sched.POLICIES["fifo"](PERSONA, PCFG), R=2,
        router=Router(2, "least_queue"), faults=plan, obs=obs, **SIM_KW)
    _conservation(res, n)
    assert res.dead_lettered == n        # nothing completes by step 2
    assert res.failovers > 0             # r0 -> r1 before r1 died
    assert all(r.crashed for r in res.replicas)
    c = obs.metrics.counters()
    assert c["faults.replica_down"] == 2
    assert c["faults.dead_lettered"] == n
    assert c["faults.failovers"] == res.failovers
    assert c["faults.retries"] == res.retries


def test_failover_disabled_dead_letters_survivors():
    # failover off: the crashed replica's survivors dead-letter
    # instead of re-dispatching; the live replica is untouched
    n = 8
    plan = FaultPlan(crashes=(CrashFault(1, 2),), failover=False)
    res = simulator.simulate_replicated(
        _sim_only_tasks([MAX_NEW] * n),
        sched.POLICIES["fifo"](PERSONA, PCFG), R=2,
        router=Router(2, "least_queue"), faults=plan, **SIM_KW)
    _conservation(res, n)
    assert res.failovers == 0
    assert res.dead_lettered == n // 2   # r1's whole share
    assert len(res.replicas[0].tasks) == n // 2


def test_faulted_sim_is_deterministic():
    plan = FaultPlan(crashes=(CrashFault(0, 2),),
                     transients=(TransientFault(at_placement=3),),
                     shed=ShedPolicy(queue_depth=4), deadlines=True)
    obs1, obs2 = Observability(), Observability()

    def run(obs):
        return simulator.simulate_replicated(
            _sim_only_tasks([3] * 12, seed=5),
            sched.POLICIES["rt-lm"](PERSONA, PCFG), R=3,
            router=Router(3, "rtlm"), faults=plan, obs=obs, **SIM_KW)

    r1, r2 = run(obs1), run(obs2)
    assert r1.placements == r2.placements
    assert r1.failover_placements == r2.failover_placements
    assert (r1.timed_out, r1.shed, r1.retries, r1.failovers,
            r1.dead_lettered) \
        == (r2.timed_out, r2.shed, r2.retries, r2.failovers,
            r2.dead_lettered)
    assert obs1.trace.parity_events() == obs2.trace.parity_events()
    assert obs1.metrics.counters() == obs2.metrics.counters()
    _conservation(r1, 12)


def _random_fault_conservation(seed, R, n):
    rng = np.random.default_rng(seed)
    plan = random_plan(rng, R, seed=seed)
    caps = [1 + int(rng.integers(0, MAX_NEW)) for _ in range(n)]
    res = simulator.simulate_replicated(
        _sim_only_tasks(caps, seed=seed),
        sched.POLICIES["fifo"](PERSONA, PCFG), R=R,
        router=Router(R, "least_queue"), faults=plan, **SIM_KW)
    _conservation(res, n)
    assert len(res.placements) == n
    assert all(-1 <= p < R for p in res.placements)
    # no KV block leaks under any fault schedule: crash eviction and
    # completion both release their reservations
    assert all(rep.kv_blocks_in_use == 0 for rep in res.replicas)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10**6), R=st.integers(1, 4),
           n=st.integers(1, 24))
    def test_property_terminal_conservation_under_random_faults(seed, R,
                                                                n):
        """Hypothesis sweep: under ANY seeded fault schedule every
        request reaches exactly one terminal outcome in {complete,
        timed_out, shed, dead_lettered} and the run terminates."""
        _random_fault_conservation(seed, R, n)
else:                                                # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_terminal_conservation_under_random_faults():
        pass


def test_deterministic_mirror_of_conservation_property():
    """The seeded mirror of the hypothesis sweep (always runs)."""
    for seed in (0, 3, 11, 42):
        rng = np.random.default_rng(seed)
        R = 1 + int(rng.integers(0, 4))
        n = 1 + int(rng.integers(0, 24))
        _random_fault_conservation(seed, R, n)


# ---------------------------------------------------------------------------
# engine-vs-sim parity under faults
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("starcoder2-3b")
    from repro.models import model as model_lib
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 64, seed=0)
    train, test = datagen.train_test_split(corpus, train_frac=0.5)
    profile = sched.offline_profile(train, PERSONA, epochs=15)
    texts = [test[i % 4].text for i in range(24)]
    return cfg, params, profile, texts


def _requests(texts, caps, classes=None):
    return [Request(text=t, arrival=0.0, task_id=i, max_new_tokens=c,
                    traffic_class=(classes[i] if classes else ""))
            for i, (t, c) in enumerate(zip(texts, caps))]


def _sim_tasks(texts, caps, profile, classes=None, xi=2.0):
    out = []
    for i, (t, c) in enumerate(zip(texts, caps)):
        u = profile.predictor.score(t)
        d = prio.priority_point(0.0, len(t.split()), PERSONA.phi,
                                None, xi=xi)
        out.append(prio.SimTask(
            task=Request(text=t, arrival=0.0, task_id=i,
                         traffic_class=(classes[i] if classes else "")),
            u=float(max(u, 0.0)), r=0.0, d=d,
            input_len=float(len(t.split())), true_out_len=int(c)))
    return out


def _engine_kw():
    return dict(input_bucket=BUCKET, max_new_tokens=MAX_NEW,
                mode="continuous", eos_id=-1, kv="paged",
                kv_block_size=BS, num_slots=SLOTS, kv_num_blocks=BLOCKS)


def _pool_parity(eobs, sobs, R):
    """Per-replica parity streams, unlabeled fault-event subsequences
    and counters must all compare bit-for-bit."""
    for r in range(R):
        assert eobs.trace.parity_events(replica=r) \
            == sobs.trace.parity_events(replica=r), f"replica {r}"
    for kind in FAULT_KINDS + ("route",):
        ee = [e for e in eobs.trace.parity_events() if e[0] == kind]
        se = [e for e in sobs.trace.parity_events() if e[0] == kind]
        assert ee == se, kind
    assert eobs.metrics.counters() == sobs.metrics.counters()


@pytest.mark.parametrize("R", [2, 4])
@pytest.mark.parametrize("policy_name", ["fifo", "rt-lm"])
def test_crash_failover_parity(setup, R, policy_name):
    """The tentpole acceptance: a mid-trace crash on replica R-1 whose
    survivors fail over through the shared coordinator — engine pool
    and simulator pool produce bit-identical placements, failover
    decisions, per-replica event streams and fault counters."""
    cfg, params, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    # least_queue on an all-at-t0 trace pins task i to replica i % R;
    # replica R-1 carries the long requests (cap 6) and crashes at its
    # local step 3 with all three still active, while the cap-1 groups
    # on the other replicas have already drained
    n = 3 * R
    caps = [MAX_NEW if i % R == R - 1 else 1 for i in range(n)]
    plan = FaultPlan(crashes=(CrashFault(R - 1, 3),),
                     retry=RetryPolicy(budget=3))
    eobs, sobs = Observability(), Observability()
    eng = ReplicatedEngine(
        params, cfg, sched.POLICIES[policy_name](PERSONA, pcfg),
        profile, replicas=R, router=Router(R, "least_queue"),
        faults=plan, obs=eobs, **_engine_kw())
    res = eng.serve(_requests(texts[:n], caps))
    sim = simulator.simulate_replicated(
        _sim_tasks(texts[:n], caps, profile),
        sched.POLICIES[policy_name](PERSONA, pcfg), R=R,
        router=Router(R, "least_queue"), faults=plan, obs=sobs,
        num_slots=SLOTS, kv_block_size=BS, kv_num_blocks=BLOCKS,
        prompt_len=BUCKET)

    assert res["placements"] == sim.placements
    assert res["placement_counts"] == sim.placement_counts()
    # the crash actually happened, with the whole long group surviving
    assert res["per_replica"][R - 1]["crashed"]
    assert sim.replicas[R - 1].crashed
    assert res["failover_placements"] == sim.failover_placements
    assert len(res["failover_placements"]) == 3
    assert all(src == R - 1 and dst != R - 1
               for _, src, dst in res["failover_placements"])
    assert (res["retries"], res["failovers"], res["dead_lettered"]) \
        == (sim.retries, sim.failovers, sim.dead_lettered) == (3, 3, 0)
    _pool_parity(eobs, sobs, R)
    # conservation on both sides: every request completes somewhere
    done_ids = sorted(tid for order in res["completion_orders"]
                      for tid in order)
    sim_ids = sorted(t.task.task_id for rep in sim.replicas
                     for t in rep.tasks)
    assert done_ids == sim_ids == list(range(n))


def test_transient_dispatch_fault_parity(setup):
    """A transient failure on the pool's second placement: the request
    retries onto the other replica on BOTH sides, with identical retry
    events and placements."""
    cfg, params, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    n, caps = 4, [1, 1, 1, 1]
    plan = FaultPlan(transients=(TransientFault(at_placement=1),),
                     retry=RetryPolicy(budget=2))
    eobs, sobs = Observability(), Observability()
    eng = ReplicatedEngine(
        params, cfg, sched.POLICIES["fifo"](PERSONA, pcfg), profile,
        replicas=2, router=Router(2, "least_queue"), faults=plan,
        obs=eobs, **_engine_kw())
    res = eng.serve(_requests(texts[:n], caps))
    sim = simulator.simulate_replicated(
        _sim_tasks(texts[:n], caps, profile),
        sched.POLICIES["fifo"](PERSONA, pcfg), R=2,
        router=Router(2, "least_queue"), faults=plan, obs=sobs,
        num_slots=SLOTS, kv_block_size=BS, kv_num_blocks=BLOCKS,
        prompt_len=BUCKET)
    # task 1's first attempt fails transiently -> lands on replica 0
    assert res["placements"] == sim.placements == [0, 0, 1, 1]
    assert res["retries"] == sim.retries == 1
    assert res["dead_lettered"] == sim.dead_lettered == 0
    _pool_parity(eobs, sobs, 2)


def test_breaker_recovery_replica_up_parity(setup):
    """Crash with recovery: after one further placement the breaker
    half-opens, the probe succeeds (``replica_up``) and failover load
    returns to the recovered replica — identically on both sides."""
    cfg, params, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    n = 6
    caps = [MAX_NEW if i % 2 else 1 for i in range(n)]
    plan = FaultPlan(
        crashes=(CrashFault(1, 3, recover_after_placements=1),),
        retry=RetryPolicy(budget=3), cooldown_placements=1)
    eobs, sobs = Observability(), Observability()
    eng = ReplicatedEngine(
        params, cfg, sched.POLICIES["fifo"](PERSONA, pcfg), profile,
        replicas=2, router=Router(2, "least_queue"), faults=plan,
        obs=eobs, **_engine_kw())
    res = eng.serve(_requests(texts[:n], caps))
    sim = simulator.simulate_replicated(
        _sim_tasks(texts[:n], caps, profile),
        sched.POLICIES["fifo"](PERSONA, pcfg), R=2,
        router=Router(2, "least_queue"), faults=plan, obs=sobs,
        num_slots=SLOTS, kv_block_size=BS, kv_num_blocks=BLOCKS,
        prompt_len=BUCKET)
    assert res["failover_placements"] == sim.failover_placements
    # first survivor goes to the live replica, the probe then recovers
    # replica 1 and the remaining two return to it
    dsts = [dst for _, _, dst in res["failover_placements"]]
    assert dsts == [0, 1, 1]
    eup = [e for e in eobs.trace.parity_events() if e[0] == "replica_up"]
    assert len(eup) == 1
    _pool_parity(eobs, sobs, 2)
    done_ids = sorted(tid for order in res["completion_orders"]
                      for tid in order)
    assert done_ids == list(range(n))


def test_deadline_timeout_parity_single_replica(setup):
    """Judgment-invariant deadlines (e2e -1.0 = doomed at the first
    check, inf = never) so the engine's wall clock and the simulator's
    model clock reach identical timeout decisions."""
    cfg, params, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    n = 6
    caps = [2] * n
    classes = ["doomed" if i % 2 else "lucky" for i in range(n)]
    targets = {"doomed": SLOSpec(e2e_s=-1.0), "lucky": SLOSpec()}
    rf = ReplicaFaults(deadlines=True)
    eobs = Observability(slo=dict(targets))
    sobs = Observability(slo=dict(targets))
    eng = ServingEngine(
        params, cfg, sched.POLICIES["fifo"](PERSONA, pcfg), profile,
        faults=rf, obs=eobs, **_engine_kw())
    res = eng.serve(_requests(texts[:n], caps, classes))
    sim = simulator.simulate_continuous(
        _sim_tasks(texts[:n], caps, profile, classes),
        sched.POLICIES["fifo"](PERSONA, pcfg), faults=rf, obs=sobs,
        num_slots=SLOTS, kv_block_size=BS, kv_num_blocks=BLOCKS,
        prompt_len=BUCKET)
    assert res["timed_out"] == sim.timed_out == 3
    assert res["timed_out_ids"] == [1, 3, 5]
    assert res["shed"] == sim.shed == 0
    assert eobs.trace.parity_events() == sobs.trace.parity_events()
    assert eobs.metrics.counters() == sobs.metrics.counters()
    assert eobs.slo.parity_counters() == sobs.slo.parity_counters()
    assert res["completion_order"] == [t.task.task_id for t in sim.tasks]


def test_uncertainty_shed_parity_single_replica(setup):
    """Queue pressure on a one-slot replica: bulk classes shed first,
    then the highest-``u`` predicted deadline-missers — the same
    victims, events and counters on both sides."""
    cfg, params, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    n = 6
    caps = [1] * n
    classes = ["rush", "batch", "rush", "batch", "rush", "rush"]
    targets = {"rush": SLOSpec(e2e_s=-1.0), "batch": SLOSpec()}
    rf = ReplicaFaults(shed=ShedPolicy(queue_depth=2,
                                       bulk_classes=("batch",)))
    eobs = Observability(slo=dict(targets))
    sobs = Observability(slo=dict(targets))
    kw = _engine_kw()
    kw["num_slots"] = 1
    eng = ServingEngine(
        params, cfg, sched.POLICIES["fifo"](PERSONA, pcfg), profile,
        faults=rf, obs=eobs, **kw)
    res = eng.serve(_requests(texts[:n], caps, classes))
    sim = simulator.simulate_continuous(
        _sim_tasks(texts[:n], caps, profile, classes),
        sched.POLICIES["fifo"](PERSONA, pcfg), faults=rf, obs=sobs,
        num_slots=1, kv_block_size=BS, kv_num_blocks=BLOCKS,
        prompt_len=BUCKET)
    # deadlines are OFF: nothing times out, pressure sheds 4 of 6 --
    # the two bulk requests first, then the two highest-u rush
    assert res["timed_out"] == sim.timed_out == 0
    assert res["shed"] == sim.shed == 4
    assert set(res["shed_ids"]) >= {1, 3}          # bulk always first
    assert eobs.trace.parity_events() == sobs.trace.parity_events()
    assert eobs.metrics.counters() == sobs.metrics.counters()
    assert res["completion_order"] == [t.task.task_id for t in sim.tasks]


def test_all_down_engine_counters_match_sim(setup):
    """Simultaneous crashes (both replicas at step 1): the engine's
    round-based failover and the simulator's interleaved one reach the
    same retry/failover/dead-letter totals and conservation — and
    neither side hangs."""
    cfg, params, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    n = 6
    caps = [MAX_NEW] * n
    plan = FaultPlan(crashes=(CrashFault(0, 1), CrashFault(1, 1)),
                     retry=RetryPolicy(budget=3))
    eobs, sobs = Observability(), Observability()
    eng = ReplicatedEngine(
        params, cfg, sched.POLICIES["fifo"](PERSONA, pcfg), profile,
        replicas=2, router=Router(2, "least_queue"), faults=plan,
        obs=eobs, **_engine_kw())
    res = eng.serve(_requests(texts[:n], caps))
    sim = simulator.simulate_replicated(
        _sim_tasks(texts[:n], caps, profile),
        sched.POLICIES["fifo"](PERSONA, pcfg), R=2,
        router=Router(2, "least_queue"), faults=plan, obs=sobs,
        num_slots=SLOTS, kv_block_size=BS, kv_num_blocks=BLOCKS,
        prompt_len=BUCKET)
    assert res["dead_lettered"] == sim.dead_lettered == n
    assert (res["retries"], res["failovers"]) \
        == (sim.retries, sim.failovers)
    assert eobs.metrics.counters()["faults.dead_lettered"] == n
    assert eobs.metrics.counters()["faults.replica_down"] == 2
    assert not any(res["completion_orders"])
    _conservation(sim, n)


def test_unfaulted_runs_carry_no_fault_keys(setup):
    """faults=None byte-identity: no fault-gated result key, fault
    event kind or faults.* counter leaks into unfaulted serves."""
    cfg, params, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eobs, sobs = Observability(), Observability()
    eng = ServingEngine(
        params, cfg, sched.POLICIES["fifo"](PERSONA, pcfg), profile,
        obs=eobs, **_engine_kw())
    res = eng.serve(_requests(texts[:4], [2] * 4))
    sim = simulator.simulate_continuous(
        _sim_tasks(texts[:4], [2] * 4, profile),
        sched.POLICIES["fifo"](PERSONA, pcfg), obs=sobs,
        num_slots=SLOTS, kv_block_size=BS, kv_num_blocks=BLOCKS,
        prompt_len=BUCKET)
    for key in ("timed_out", "shed", "crashed", "final_step",
                "survivor_ids"):
        assert key not in res
    assert sim.timed_out == 0 and sim.shed == 0 and not sim.crashed
    for obs in (eobs, sobs):
        assert not any(k.startswith("faults.")
                       for k in obs.metrics.counters())
        assert not any(e[0] in FAULT_KINDS
                       for e in obs.trace.parity_events())
    assert eobs.trace.parity_events() == sobs.trace.parity_events()


def test_faults_require_continuous_stall_engine(setup):
    cfg, params, profile, _ = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    policy = sched.POLICIES["fifo"](PERSONA, pcfg)
    with pytest.raises(ValueError, match="continuous"):
        ServingEngine(params, cfg, policy, profile,
                      faults=ReplicaFaults(), input_bucket=BUCKET,
                      max_new_tokens=MAX_NEW, mode="batch", eos_id=-1)
    with pytest.raises(ValueError, match="out of range"):
        ReplicatedEngine(params, cfg, policy, profile, replicas=2,
                         faults=FaultPlan(crashes=(CrashFault(7, 1),)),
                         **_engine_kw())


# ---------------------------------------------------------------------------
# completion-worker lifecycle (satellite: poisoned decode readback)
# ---------------------------------------------------------------------------


class _Poison:
    """An array-like whose host conversion raises — the worker-thread
    readback failure a dying device produces."""

    def __array__(self, *a, **k):
        raise RuntimeError("device readback poisoned")


def test_completion_worker_raises_at_collect_and_close_idempotent():
    w = CompletionWorker()
    w.submit(np.zeros(3), 0.0)
    host, _ = w.collect()
    assert host.shape == (3,)
    w.submit(_Poison(), 0.0)
    with pytest.raises(RuntimeError, match="poisoned"):
        w.collect()
    # the worker thread survived the exception and still drains
    w.submit(np.ones(2), 0.0)
    host, _ = w.collect()
    assert host.tolist() == [1.0, 1.0]
    w.close()
    assert not w._thread.is_alive()
    w.close()                       # idempotent: second close is a no-op
    with CompletionWorker() as cw:
        cw.submit(np.zeros(1), 0.0)
        cw.collect()
    assert not cw._thread.is_alive()


def test_engine_serve_unwinds_cleanly_on_poisoned_decode(setup,
                                                        monkeypatch):
    """A decode-window readback failure surfaces as the original
    exception (not a hang or teardown error) and the worker is torn
    down — serve() constructs the worker before the try so the finally
    always has one to close."""
    cfg, params, profile, texts = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    eng = ServingEngine(
        params, cfg, sched.POLICIES["fifo"](PERSONA, pcfg), profile,
        **_engine_kw())

    def poisoned_collect(self):
        raise RuntimeError("decode window poisoned")

    monkeypatch.setattr(CompletionWorker, "collect", poisoned_collect)
    with pytest.raises(RuntimeError, match="decode window poisoned"):
        eng.serve(_requests(texts[:2], [2, 2]))
    assert eng._worker is None          # torn down, not leaked


def test_workload_request_deadline():
    targets = {"interactive": SLOSpec(e2e_s=10.0)}
    assert workload.request_deadline(2.0, "interactive", targets) == 12.0
    assert workload.request_deadline(2.0, "other", targets) \
        == float("inf")
