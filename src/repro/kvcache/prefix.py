"""Copy-on-write prefix caching over the paged KV pool.

High-traffic real-time serving repeats prompt PREFIXES — persona /
system-prompt text shared by many concurrent requests — and the
stall/chunked prefill paths recompute the same KV entries for every
admission.  Because chunked prefill (PR 3) writes exact per-position
pages, a prefix that hashes identically can instead map to the SAME
physical blocks: this module indexes written prompt blocks by a content
hash chain and lets later sequences share them read-only.

Entry points (host-side, pure Python — shared verbatim by the real
engine, ``ServingEngine(prefix_cache=True)``, and the simulator,
``simulate_continuous(prefix_cache=True)``, which is what makes their
hit / CoW / eviction counters comparable bit-for-bit):

  * ``block_hashes(tokens, block_size)`` — the hash chain: one FNV-1a
    hash per FULL block of the (padded) prompt bucket, each folding in
    every preceding token, so matching is longest-prefix by
    construction; cache entries also store each block's token ids and
    a hit is honored only on verbatim token match, so a hash collision
    degrades to a miss instead of silently reusing wrong KV.
  * ``PrefixCache.admit(seq_id, tokens)`` — longest cached-prefix
    lookup; shares matched blocks into the sequence's table
    (``BlockAllocator.share`` refcounts pin them), allocates private
    blocks for the uncached suffix, and returns the position the
    caller's prefill must start at.
  * ``PrefixCache.commit(seq_id, tokens)`` — after the suffix prefill
    lands, registers the sequence's freshly written full blocks under
    their hashes (the cache takes one reference per indexed block).
  * ``PrefixCache.evict_lru`` — installed as the allocator's
    ``reclaim`` hook: under pool pressure, cached blocks nobody else
    references (refcount 1 — the cache's own pin) are evicted oldest
    first; blocks still read by live sequences are never touched.

Invariants (property-tested in tests/test_properties.py and
tests/test_prefix_cache.py):

  * a shared block is never freed or evicted while any sequence still
    holds a reference;
  * a sequence never WRITES a shared block: writes land either in
    private suffix blocks (match ends on a block boundary before the
    write position) or behind ``cow_block`` — on a FULL-prompt match
    the last position must be recomputed for its logits, which is a
    divergent write into a shared block, so ``admit`` replaces that
    table entry with a fresh private copy (the caller copies the page
    device-side: ``transformer.copy_paged_block``) and counts it in
    ``cow_copies``;
  * greedy output is token-for-token identical with the cache on or
    off: cached blocks were written by the same deterministic prefill
    executables at the same positions, and the suffix path reuses the
    chunked-prefill recipe (``model.prefill_chunk``), which is
    bit-identical to a full prefill (tests/test_chunked_prefill.py).

Kernel dispatch is unchanged by caching: suffix prefill runs the jnp
chunk attention (`layers.chunked_attention` over the gathered view) or
the Pallas ``chunked_prefill_attention`` kernel under ``use_pallas``,
and decode reads shared and private pages alike through the jnp gather
or the Pallas ``paged_decode_attention`` kernel — block tables already
indirect every access, so sharing is invisible to the device code.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple

from .allocator import BlockAllocator, blocks_for_tokens

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK = 0xFFFFFFFFFFFFFFFF


def _fnv(h: int, v: int) -> int:
    return ((h ^ v) * _FNV_PRIME) & _MASK


def block_hashes(tokens: Sequence[int], block_size: int) -> List[int]:
    """One chained FNV-1a hash per FULL block of ``tokens``.

    ``h[i]`` folds in every token of blocks ``0..i``, so two prompts
    share ``h[i]`` iff their first ``(i+1) * block_size`` tokens match
    (modulo hash collision) — the longest-cached-prefix walk is a plain
    front-to-back dictionary probe.  The trailing partial block (and
    everything a prompt shorter than one block) is never hashed: only
    fully written, immutable-content blocks are shareable.
    """
    out: List[int] = []
    h = _FNV_OFFSET
    for i in range(len(tokens) // block_size):
        for t in tokens[i * block_size:(i + 1) * block_size]:
            h = _fnv(h, int(t))
        out.append(h)
    return out


@dataclasses.dataclass
class PrefixAdmit:
    """What ``PrefixCache.admit`` decided for one admission."""

    start: int                       # first prompt position to compute
    matched_blocks: int              # full blocks reused from the cache
    cow: List[Tuple[int, int]]       # (src, dst) device page copies owed


class PrefixCache:
    """Content-hash index of written prompt blocks, LRU-evicted.

    Owns no device state: it drives a ``BlockAllocator`` (share /
    allocate / cow_block / drop_ref) and an insertion-ordered
    ``hash -> physical block`` map whose order IS the LRU order
    (entries are re-appended on every hit).  Cached block ids index ONE
    device page pool: by default the engine builds a fresh instance per
    ``serve()`` alongside a fresh pool, but with
    ``ServingEngine(persist_prefix_cache=True)`` the pool, allocator
    and this index survive across serves (repeat traffic hits warm) —
    the engine then calls ``reset_stats()`` at each serve start so the
    counters stay per-serve while the index persists.
    """

    def __init__(self, allocator: BlockAllocator, block_size: int):
        if block_size != allocator.block_size:
            raise ValueError(
                f"block_size {block_size} != allocator's "
                f"{allocator.block_size}")
        self.alloc = allocator
        self.block_size = block_size
        # hash -> (physical block, the block's own token ids).  The
        # token ids guard against chain-hash collisions: a hit is only
        # honored when the probed block's tokens match verbatim — and
        # since the walk is front-to-back, per-block verification
        # inductively verifies the whole prefix (FNV-1a is fast, not
        # collision-proof; a silent collision would violate the
        # token-for-token output invariant).
        self._entries: "OrderedDict[int, Tuple[int, Tuple[int, ...]]]" \
            = OrderedDict()
        # pressure valve: allocator pops cached refcount-1 blocks LRU
        # first when its free list runs dry
        allocator.reclaim = self.evict_lru
        # shared counter definitions — ServingEngine._result and
        # SimResult read these verbatim, so engine-vs-sim parity on the
        # hit/CoW/eviction numbers is equality of these fields
        self.lookup_blocks = 0           # full blocks probed
        self.hit_blocks = 0              # probes that hit
        self.tokens_reused = 0           # prompt tokens NOT recomputed
        self.cow_copies = 0
        self.evictions = 0
        # optional repro.obs MetricsRegistry: when set (the serve loops
        # assign it at serve start), the same counters also stream into
        # the shared registry under "prefix.*" — deterministic
        # quantities, so they stay engine-vs-sim parity-comparable
        self.metrics = None

    def reset_stats(self) -> None:
        """Zero the per-serve counters WITHOUT touching the index or
        its block references (persistent-cache serve start: metrics are
        per serve, cached content carries over)."""
        self.lookup_blocks = 0
        self.hit_blocks = 0
        self.tokens_reused = 0
        self.cow_copies = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    @property
    def num_cached_blocks(self) -> int:
        return len(self._entries)

    def hit_rate(self) -> float:
        return (self.hit_blocks / self.lookup_blocks
                if self.lookup_blocks else 0.0)

    def stats(self) -> Dict:
        return {
            "prefix_hit_rate": self.hit_rate(),
            "cached_tokens_reused": self.tokens_reused,
            "cow_copies": self.cow_copies,
            "prefix_evictions": self.evictions,
            "cached_blocks": len(self._entries),
        }

    # ------------------------------------------------------------------
    def admit(self, seq_id: int, tokens: Sequence[int]) -> PrefixAdmit:
        """Admission-side half: match, share, CoW, allocate the rest.

        After this returns, ``alloc.table(seq_id)`` holds the prompt's
        full ``blocks_for(len(tokens))`` table — matched shared blocks
        first (in prefix order), then fresh private blocks — and the
        caller must (a) perform the returned ``cow`` device page
        copies, then (b) prefill positions ``start ..`` only.
        """
        S = len(tokens)
        bs = self.block_size
        hashes = block_hashes(tokens, bs)
        self.lookup_blocks += len(hashes)
        matched: List[int] = []
        for i, h in enumerate(hashes):
            entry = self._entries.get(h)
            if entry is None:
                break
            blk, blk_tokens = entry
            if tuple(int(t) for t in tokens[i * bs:(i + 1) * bs]) \
                    != blk_tokens:
                break                      # hash collision: treat as miss
            self._entries.move_to_end(h)
            matched.append(blk)
        self.hit_blocks += len(matched)
        if self.metrics is not None:
            self.metrics.counter("prefix.lookup_blocks").inc(len(hashes))
            self.metrics.counter("prefix.hit_blocks").inc(len(matched))
        # share FIRST: the sequence's references pin the matched blocks
        # against the LRU reclaim the allocations below may trigger
        for blk in matched:
            self.alloc.share(seq_id, blk)
        start = len(matched) * self.block_size
        cow: List[Tuple[int, int]] = []
        if matched and start == S:
            # full-prompt match: every KV entry is cached, but the
            # sampler still needs the LAST position's logits, so
            # position S-1 is recomputed — a (numerically identical)
            # write into the last shared block, i.e. the divergent
            # write that triggers copy-on-write.
            start = S - 1
            cow.append(self.alloc.cow_block(seq_id, len(matched) - 1))
            self.cow_copies += 1
            if self.metrics is not None:
                self.metrics.counter("prefix.cow_copies").inc()
        self.tokens_reused += start
        need = blocks_for_tokens(S, self.block_size) \
            - len(self.alloc.table(seq_id))
        if need > 0:
            self.alloc.allocate_n(seq_id, need)
        return PrefixAdmit(start=start, matched_blocks=len(matched),
                           cow=cow)

    def commit(self, seq_id: int, tokens: Sequence[int]) -> int:
        """Completion-side half: index the freshly written full blocks.

        Runs when the sequence's prefill completes (synchronously for
        stall admission, on the final chunk for chunked prefill).  A
        hash another sequence registered in the meantime is only
        touched (LRU refresh) — the duplicate private block stays
        unindexed and is freed with its owner.  Returns the number of
        newly indexed blocks.
        """
        table = self.alloc.table(seq_id)
        bs = self.block_size
        added = 0
        for i, h in enumerate(block_hashes(tokens, bs)):
            if h in self._entries:
                self._entries.move_to_end(h)
                continue
            self.alloc.add_ref(table[i])     # the cache's own pin
            self._entries[h] = (
                table[i], tuple(int(t) for t in tokens[i * bs:(i + 1) * bs]))
            added += 1
        return added

    # ------------------------------------------------------------------
    def evict_lru(self) -> bool:
        """Free ONE cached block no sequence references (LRU first).

        Installed as the allocator's ``reclaim`` hook, so eviction
        happens exactly under pool pressure and never touches a block
        whose refcount exceeds the cache's own pin.  Evicting a chain
        interior leaves deeper entries unreachable for matching; they
        age out and are evicted by the same rule.
        """
        victim = None
        for h, (blk, _) in self._entries.items():  # oldest first
            if self.alloc.refcount(blk) == 1:
                victim = h
                break
        if victim is None:
            return False
        self.alloc.drop_ref(self._entries.pop(victim)[0])
        self.evictions += 1
        if self.metrics is not None:
            self.metrics.counter("prefix.evictions").inc()
        return True

    def clear(self) -> int:
        """Drop every cache reference (tests / end-of-serve leak
        checks); blocks referenced only by the cache return to the
        free list.  Returns the number of entries dropped."""
        n = len(self._entries)
        for blk, _ in self._entries.values():
            self.alloc.drop_ref(blk)
        self._entries.clear()
        self.alloc.reclaim = None
        return n
