"""Benchmark harness: one entry per paper table/figure + substrate
microbenches + the roofline report.

    PYTHONPATH=src python -m benchmarks.run [--only table3_max_response]
                                           [--seed N]

Prints ``name,us_per_call,derived`` CSV lines (harness contract) and
writes full payloads to experiments/bench/*.json.  ``--seed`` threads
through the serving benchmarks (continuous_vs_batch,
prefill_interference) so the recorded JSONs are deterministic and
reproducible for any seed.
"""

from __future__ import annotations

import argparse
import time
import traceback

from . import (chaos_failover, common, continuous_vs_batch, kernel_bench,
               paper_tables, prefill_interference, prefix_cache,
               roofline_report, router_policies, slo_calibration)


def run_paper_tables(only=None):
    for name, fn in paper_tables.ALL.items():
        if only and only != name:
            continue
        t0 = time.time()
        try:
            payload, derived = fn()
        except Exception as e:            # noqa: BLE001
            traceback.print_exc()
            common.emit(name, time.time() - t0, f"ERROR:{e}")
            continue
        common.save(name, payload)
        common.emit(name, time.time() - t0, derived)


def run_kernels(only=None):
    if only and only not in ("kernel_attention", "kernel_rmsnorm",
                             "ragged_prefill_kernel"):
        return
    if only is None or only == "kernel_attention":
        t0 = time.time()
        rows = kernel_bench.attention_bench()
        common.save("kernel_attention", rows)
        best = max(v["chunked_gflops"] for v in rows.values())
        common.emit("kernel_attention", time.time() - t0,
                    f"chunked_best={best}gflops_cpu")
    if only is None or only == "kernel_rmsnorm":
        t0 = time.time()
        rows = kernel_bench.rmsnorm_bench()
        common.save("kernel_rmsnorm", rows)
        best = max(v["effective_GBps"] for v in rows.values())
        common.emit("kernel_rmsnorm", time.time() - t0,
                    f"best={best}GBps_cpu")
    if only is None or only == "ragged_prefill_kernel":
        t0 = time.time()
        rows = kernel_bench.ragged_prefill_bench()
        common.save("ragged_prefill_kernel", rows)
        at4 = [v for v in rows.values() if v["num_chunks"] >= 4]
        worst = min(v["speedup"] for v in at4)
        common.emit("ragged_prefill_kernel", time.time() - t0,
                    f"min_speedup_at_ge4_chunks={worst}x")


def run_roofline(only=None):
    if only and only != "roofline":
        return
    t0 = time.time()
    rows = roofline_report.load()
    if not rows:
        common.emit("roofline", time.time() - t0,
                    "no dry-run artifacts (run repro.launch.dryrun_all)")
        return
    variants = [
        ("roofline_pod", dict(multi_pod=False)),
        ("roofline_multipod", dict(multi_pod=True)),
        ("roofline_pod_seqpar", dict(multi_pod=False, seq_parallel=True)),
        ("roofline_pod_serving", dict(multi_pod=False, fsdp=False,
                                      serving=True)),
    ]
    for name, kw in variants:
        tab = roofline_report.table(rows, **kw)
        if not any(r["status"] == "ok" for r in tab):
            continue
        s = roofline_report.summary(tab)
        common.save(name, tab)
        common.emit(name, time.time() - t0,
                    f"ok={s['ok']};mem_bound={s['memory_bound']};"
                    f"coll_bound={s['collective_bound']};"
                    f"compute_bound={s['compute_bound']};fits={s['fits']}")


def run_continuous(only=None, seed=0):
    if only == "decode_dispatch":
        t0 = time.time()
        dd = continuous_vs_batch.run_decode_dispatch("fifo", seed=seed)
        common.save("decode_dispatch", dd)
        spl = dd["stall"]["n%d" % dd["decode_steps"]]["steps_per_launch"]
        common.emit(
            "decode_dispatch", time.time() - t0,
            f"stall_dispatch_x={dd['stall']['dispatch_reduction_x']:.2f},"
            f"chunked_dispatch_x="
            f"{dd['chunked']['dispatch_reduction_x']:.2f},"
            f"steps_per_launch={spl:.0f}")
    if only is None or only in ("continuous_vs_batch_sim",
                                "continuous_vs_batch_engine",
                                "continuous_vs_batch",
                                "paged_vs_contiguous"):
        continuous_vs_batch.main(seed=seed)
    if only is None or only in ("chunked_prefill", "prefill_interference"):
        prefill_interference.main(seed=seed)
    if only is None or only == "prefix_cache":
        prefix_cache.main(seed=seed)
    if only is None or only == "slo_calibration":
        slo_calibration.main(seed=seed)
    if only is None or only == "router_policies":
        router_policies.main(seed=seed)
    if only is None or only == "chaos_failover":
        chaos_failover.main(seed=seed)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="workload/profile seed for the serving "
                         "benchmarks (deterministic JSONs per seed)")
    ap.add_argument("--summary", action="store_true",
                    help="collate experiments/bench/*.json into "
                         "BENCH_SUMMARY.json (runs no benchmarks)")
    args = ap.parse_args(argv)
    if args.summary:
        out = common.summarize()
        print(f"BENCH_SUMMARY.json: {out['n_benchmarks']} benchmarks")
        return 0
    print("name,us_per_call,derived")
    run_paper_tables(args.only)
    run_kernels(args.only)
    run_continuous(args.only, seed=args.seed)
    run_roofline(args.only)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
