"""Paper §V-G: robustness to adversarially crafted long-output tasks.

    PYTHONPATH=src python examples/malicious_robustness.py

Sweeps the malicious-task ratio 0..100% and compares FIFO vs RT-LM mean
response time (Fig. 14 reproduction at example scale).
"""

from repro.core import datagen, personas, scheduler, simulator, workload

persona = personas.get_persona("dialogpt")
print("ratio  fifo_mean  rtlm_mean  fifo_max  rtlm_max")
for pct in range(0, 101, 20):
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 1600, seed=pct,
        malicious_frac=pct / 100)
    train, test = datagen.train_test_split(corpus, train_frac=0.3)
    profile = scheduler.offline_profile(train, persona, epochs=30)
    arrivals = workload.poisson_trace(
        len(test), betas=list(range(40, 281, 40)), seed=pct + 1)
    tasks = scheduler.make_sim_tasks(test, profile, persona, arrivals)
    row = [f"{pct:3d}%"]
    for pol in ("fifo", "rt-lm"):
        res = simulator.run_policy(tasks, pol, persona,
                                   profile.policy_config())
        row.append(f"{res.mean_response:8.2f}")
    for pol in ("fifo", "rt-lm"):
        res = simulator.run_policy(tasks, pol, persona,
                                   profile.policy_config())
        row.append(f"{res.max_response:8.2f}")
    print("  ".join(row))
