"""Roofline-term derivation from the compiled dry-run artifact.

Three terms per (arch x shape x mesh), per the assignment:

    compute_s    = HLO_FLOPs        / (peak_FLOP/s per chip)
    memory_s     = HLO_bytes        / (HBM bandwidth per chip)
    collective_s = collective_bytes / (ICI link bandwidth per chip)

``compiled.cost_analysis()`` runs on the post-SPMD per-device module, so
its flops/bytes are already per-chip.  collective_bytes is NOT in
cost_analysis — we parse the optimized HLO (``compiled.as_text()``) and
sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (async "-start" variants
counted once, "-done" skipped).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_COLL_LINE_RE = re.compile(
    r"=\s*(?P<shapes>[^=]*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<variant>-start|-done)?\(")


def collective_stats(hlo_text: str) -> Dict[str, object]:
    """Per-op-kind result-shape bytes summed over the HLO module.

    Result-shape bytes are the standard traffic proxy: an all-gather
    *produces* the gathered bytes on every chip; a reduce-scatter reads
    the pre-reduce bytes (its operand = result x shards, but per-link
    traffic is ~result bytes x (shards-1)/shards ~= result bytes).  Async
    "-start" ops are counted once, "-done" skipped.
    """
    per_kind = {k: 0 for k in _COLL_OPS}
    counts = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        m = _COLL_LINE_RE.search(line)
        if not m or m.group("variant") == "-done":
            continue
        kind = m.group("op")
        sizes = [_shape_bytes(d, dims)
                 for d, dims in _SHAPE_RE.findall(m.group("shapes"))]
        if not sizes:
            continue
        # async -start results are (operand-alias, output) tuples: count
        # the output buffer only; sync tuple ops reduce N tensors: sum.
        total = max(sizes) if m.group("variant") == "-start" else sum(sizes)
        per_kind[kind] += total
        counts[kind] += 1
    return {
        "bytes_by_kind": per_kind,
        "counts": counts,
        "total_bytes": sum(per_kind.values()),
    }


@dataclasses.dataclass
class Roofline:
    flops: float                 # per chip
    hbm_bytes: float             # per chip
    collective_bytes: float      # per chip
    model_flops: float           # 6*N(_active)*tokens, per chip
    n_devices: int
    raw_flops_once: float = 0.0  # cost_analysis() (while bodies counted 1x)
    collective_by_kind: Optional[dict] = None
    collective_counts: Optional[dict] = None

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "model_flops_per_dev": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "n_devices": self.n_devices,
            "raw_flops_once": self.raw_flops_once,
            "collective_by_kind": self.collective_by_kind,
            "collective_counts": self.collective_counts,
        }


def model_flops(cfg, shape) -> float:
    """Analytic 6*N*D (dense) / 6*N_active*D (MoE) model FLOPs, global.

    Train counts fwd+bwd (6ND); prefill counts forward only (2ND);
    decode counts one token per sequence (2*N_active*B).
    """
    tokens = shape.global_batch * shape.seq_len
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch        # one decode step


def analyze(compiled, cfg, shape, n_devices: int) -> Roofline:
    """Roofline terms from the compiled per-device HLO.

    Uses the trip-count-aware HLO cost model (launch/hlo_cost.py) because
    ``compiled.cost_analysis()`` counts while-loop bodies once — fatally
    wrong for scan-over-layers programs.  The raw cost_analysis numbers
    are preserved in ``raw_cost_analysis`` for reference.
    """
    from . import hlo_cost

    cost = compiled.cost_analysis()
    if isinstance(cost, list):                        # older jax versions
        cost = cost[0]
    hlo = hlo_cost.module_cost(compiled.as_text())
    return Roofline(
        flops=hlo.flops,
        hbm_bytes=hlo.traffic_bytes,
        collective_bytes=hlo.collective_bytes,
        model_flops=model_flops(cfg, shape) / n_devices,
        n_devices=n_devices,
        raw_flops_once=float(cost.get("flops", 0.0)),
        collective_by_kind={k: v for k, v in
                            hlo.collective_by_kind.items()},
        collective_counts={k: v for k, v in
                           hlo.collective_counts.items()},
    )


def memory_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for key in ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes"):
        if hasattr(ma, key):
            out[key] = int(getattr(ma, key))
    args = out.get("argument_size_in_bytes", 0)
    alias = out.get("alias_size_in_bytes", 0)
    out["resident_bytes_per_device"] = (
        args + out.get("output_size_in_bytes", 0) - alias
        + out.get("temp_size_in_bytes", 0))
    return out
