"""Fused RMSNorm as a Pallas TPU kernel.

RMSNorm is the glue op between every pair of matmuls; unfused it costs
three HBM round-trips (square-mean reduce, rsqrt-scale, weight-scale).
The kernel fuses them into one read + one write per row block, with the
f32 reduction kept in VREGs.

Block shape: (block_rows, D) — D is the model's full feature dim (the
reduction axis must be unsplit), rows padded to a multiple of 8 for the
VPU sublane.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (bn, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    out = out * (1.0 + w_ref[...].astype(jnp.float32))[None, :]
    o_ref[...] = out.astype(o_ref.dtype)


def rms_norm(x, weight, *, eps: float = 1e-6, block_rows: int = 256,
             interpret: bool = False):
    """x: (..., D); weight: (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    xr = x.reshape(-1, D)
    N = xr.shape[0]
    block_rows = min(block_rows, max(N, 1))
    pn = (-N) % block_rows
    if pn:
        xr = jnp.pad(xr, ((0, pn), (0, 0)))
    nb = (N + pn) // block_rows

    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((N + pn), D), x.dtype),
        interpret=interpret,
    )(xr, weight)
    return out[:N].reshape(orig_shape)
