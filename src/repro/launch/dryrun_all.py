import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Orchestrate the full dry-run matrix: 10 archs x 4 shapes x meshes.

Each combination runs in-process sequentially (the 512 placeholder
devices are shared); results land in experiments/dryrun/*.json and a
summary CSV.  Skipped combinations (long_500k on quadratic-attention
archs) are recorded with their reason.

    PYTHONPATH=src python -m repro.launch.dryrun_all \
        [--outdir experiments/dryrun] [--archs a,b] [--shapes s1,s2]
        [--multi-pod] [--skip-existing]
"""

import argparse
import json
import time
import traceback

from repro import configs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--archs", default=",".join(configs.ARCH_IDS))
    ap.add_argument("--shapes", default=",".join(configs.INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--serving-layout", dest="serving", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    from repro.launch.dryrun import run_one

    os.makedirs(args.outdir, exist_ok=True)
    suffix = ("multipod" if args.multi_pod else "pod") + \
        ("" if args.fsdp else ".nofsdp") + \
        (f".{args.tag}" if args.tag else "")
    rows = []
    for arch in args.archs.split(","):
        for shape in args.shapes.split(","):
            out = os.path.join(args.outdir, f"{arch}.{shape}.{suffix}.json")
            if args.skip_existing and os.path.exists(out):
                print(f"[all] skip existing {out}")
                continue
            t0 = time.time()
            try:
                res = run_one(arch, shape, multi_pod=args.multi_pod,
                              fsdp=args.fsdp,
                              seq_parallel=args.seq_parallel,
                              serving=args.serving, verbose=False)
            except Exception as e:       # noqa: BLE001 — record and go on
                res = {"arch": arch, "shape": shape, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
            res["wall_s"] = round(time.time() - t0, 1)
            with open(out, "w") as f:
                json.dump(res, f, indent=1)
            status = res["status"]
            extra = ""
            if status == "ok":
                r = res["roofline"]
                gib = res["memory"]["resident_bytes_per_device"] / 2**30
                extra = (f"dom={r['dominant']} "
                         f"comp={r['compute_s']*1e3:.0f}ms "
                         f"mem={r['memory_s']*1e3:.0f}ms "
                         f"coll={r['collective_s']*1e3:.0f}ms "
                         f"{gib:.1f}GiB/dev")
            elif status == "error":
                extra = res["error"][:120]
            print(f"[all] {arch:24s} {shape:12s} {status:7s} "
                  f"{res['wall_s']:6.1f}s {extra}", flush=True)
            rows.append(res)
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    n_err = sum(r["status"] == "error" for r in rows)
    print(f"[all] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
