"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 200 --batch 8 --seq 256 [--mesh 2x4] [--checkpoint DIR]

On the CPU container this trains the reduced smoke variant of the chosen
architecture on the synthetic pipeline; on a real pod the same launcher
builds the production mesh and full config (--no-smoke).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.models import model as model_lib
from repro.sharding import context as shctx, policy as policy_lib
from repro.training import checkpoint as ckpt_lib, data as data_lib
from repro.training import optimizer as opt_lib, train_step as ts_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 => (data=2, model=4); default: no mesh")
    ap.add_argument("--optimizer", default=None,
                    choices=(None, "adamw", "adafactor"))
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    opt_name = args.optimizer or opt_lib.default_optimizer_name(cfg)
    opt = opt_lib.make_optimizer(opt_name, args.lr)
    step_fn = ts_lib.make_train_step(cfg, opt, remat=not args.smoke)

    mesh = policy = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(shape)] if len(shape) == 2 else \
            ("pod", "data", "model")
        mesh = mesh_lib.make_mesh(shape, axes)
        policy = policy_lib.make_policy(mesh)

    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(key, cfg)
    opt_state = opt.init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"optimizer={opt_name} mesh={args.mesh}")

    pipe = data_lib.SyntheticLMData(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        batch_size=args.batch, seed=args.seed)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    t0 = time.time()
    losses = []
    ctx = shctx.use_policy(policy) if policy else None
    if ctx:
        ctx.__enter__()
    if mesh:
        mesh.__enter__()
    try:
        for i, batch in enumerate(pipe.batches(args.steps)):
            batch = data_lib.add_modality_stub(batch, cfg, seed=i)
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"  step {i:5d} loss={losses[-1]:.4f} "
                      f"xent={float(metrics['xent']):.4f} "
                      f"grad_norm={float(metrics['grad_norm']):.3f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)")
    finally:
        if mesh:
            mesh.__exit__(None, None, None)
        if ctx:
            ctx.__exit__(None, None, None)

    if args.checkpoint:
        ckpt_lib.save(args.checkpoint, {"params": params}, step=args.steps)
        print(f"[train] checkpoint -> {args.checkpoint}")
    print(json.dumps({"first_loss": losses[0], "last_loss": losses[-1],
                      "steps": args.steps}))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
