"""Synthetic token pipeline (offline container: no external corpora).

Generates deterministic pseudo-language token streams with enough
structure for a ~100M model to show decreasing loss over a few hundred
steps: a mixture of (a) a first-order Markov chain over the vocabulary
with a sparse transition structure and (b) repeated n-gram "phrases",
which gives both local and copy-style predictability.  Also provides the
modality-stub tensors for the vlm/encdec batches.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    branching: int = 16          # out-degree of the Markov transition graph
    phrase_len: int = 8
    phrase_prob: float = 0.25

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        # sparse deterministic-ish transition table: V x branching
        self.table = rng.integers(0, V, size=(V, self.branching))
        self.table_p = rng.dirichlet(
            np.ones(self.branching) * 0.3, size=V).astype(np.float32)
        self.phrases = rng.integers(
            0, V, size=(64, self.phrase_len))

    def _sample_seq(self, rng) -> np.ndarray:
        V, S = self.vocab_size, self.seq_len + 1
        out = np.empty(S, np.int64)
        tok = rng.integers(0, V)
        i = 0
        while i < S:
            if rng.random() < self.phrase_prob:
                ph = self.phrases[rng.integers(0, len(self.phrases))]
                n = min(len(ph), S - i)
                out[i:i + n] = ph[:n]
                i += n
                tok = out[i - 1]
            else:
                j = rng.choice(self.branching, p=self.table_p[tok])
                tok = self.table[tok, j]
                out[i] = tok
                i += 1
        return out

    def batches(self, num_steps: Optional[int] = None) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed + 1)
        step = 0
        while num_steps is None or step < num_steps:
            seqs = np.stack([self._sample_seq(rng)
                             for _ in range(self.batch_size)])
            tokens = jnp.asarray(seqs[:, :-1], jnp.int32)
            labels = jnp.asarray(seqs[:, 1:], jnp.int32)
            yield {"tokens": tokens, "labels": labels}
            step += 1


def add_modality_stub(batch: dict, cfg, seed: int = 0) -> dict:
    """Attach stub patch/frame embeddings for vlm / encdec configs."""
    rng = np.random.default_rng(seed)
    B = batch["tokens"].shape[0]
    if cfg.frontend == "vision":
        batch = dict(batch, patches=jnp.asarray(
            rng.standard_normal((B, cfg.num_patch_tokens, cfg.d_model)),
            jnp.bfloat16))
    elif cfg.family == "encdec":
        batch = dict(batch, frames=jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq_len, cfg.d_model)),
            jnp.bfloat16))
    return batch
