"""Head-to-head: continuous (iteration-level) batching vs the paper's
run-to-completion batch mode, on a heterogeneous-output-length workload.

Two measurements of the same trace:

  * ``sim``    — persona latency model, deterministic (the number the
    acceptance gate asserts on: throughput ratio and per-request mean
    response).
  * ``engine`` — the REAL JAX engine (tiny config on CPU), wall-clock
    per prefill/decode-step, demonstrating the same effect end-to-end.

The workload is bimodal output lengths (short tail / long tail, EOS
disabled so lengths are exact): run-to-completion pays the longest
member of every formed batch, continuous batching recycles each slot
the step its sequence finishes.

    PYTHONPATH=src python -m benchmarks.continuous_vs_batch
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import datagen, personas, priority as prio
from repro.core import scheduler as sched, simulator

from . import common

N_REQUESTS = 96
SHORT, LONG = 4, 48
LONG_FRAC = 0.25
BATCH_SLOTS = 8
SEED = 0


def build_workload(n=N_REQUESTS, seed=SEED):
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], n + 64, seed=seed)
    train, test = datagen.train_test_split(corpus, train_frac=0.4)
    rng = np.random.default_rng(seed)
    caps = np.where(rng.random(n) < LONG_FRAC, LONG, SHORT).astype(int)
    # saturated regime: everything arrives inside the first batching
    # window, so the comparison isolates execution-model differences
    arrivals = np.sort(rng.uniform(0.0, 0.5, size=n))
    return train, test[:n], caps.tolist(), arrivals.tolist()


def persona_for_bench():
    return dataclasses.replace(personas.get_persona("bart"),
                               batch_size=BATCH_SLOTS)


def sim_tasks_for(test, caps, arrivals, profile, persona, xi=2.0):
    out = []
    for i, (t, c, r) in enumerate(zip(test, caps, arrivals)):
        u = profile.predictor.score(t.text)
        d = prio.priority_point(r, len(t.text.split()), persona.phi,
                                None, xi=xi)
        st = prio.SimTask(task=t, u=float(max(u, 0.0)), r=float(r), d=d,
                          input_len=float(len(t.text.split())),
                          true_out_len=int(c))
        out.append(st)
    return out


def run_sim(policy_name="fifo"):
    persona = persona_for_bench()
    train, test, caps, arrivals = build_workload()
    profile = sched.offline_profile(train, persona, epochs=20)
    tasks = sim_tasks_for(test, caps, arrivals, profile, persona)
    pcfg = profile.policy_config()
    rtc = simulator.run_policy(tasks, policy_name, persona, pcfg,
                               mode="batch")
    cont = simulator.run_policy(tasks, policy_name, persona, pcfg,
                                mode="continuous")
    return {
        "batch": rtc.summary(),
        "continuous": cont.summary(),
        "throughput_ratio": cont.throughput_per_min / rtc.throughput_per_min,
        "mean_response_ratio": cont.mean_response / rtc.mean_response,
    }


def run_engine(policy_name="fifo", n=32):
    """Same trace on the real JAX engine (tiny config, wall-clock)."""
    import jax
    from repro import configs
    from repro.models import model as model_lib
    from repro.serving.engine import Request, ServingEngine

    persona = persona_for_bench()
    train, test, caps, arrivals = build_workload(n=n)
    profile = sched.offline_profile(train, persona, epochs=20)
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    out = {}
    for mode in ("batch", "continuous"):
        policy = sched.POLICIES[policy_name](persona,
                                             profile.policy_config())
        eng = ServingEngine(params, cfg, policy, profile, input_bucket=8,
                            max_new_tokens=LONG, mode=mode, eos_id=-1)
        reqs = [Request(text=t.text, arrival=a, task_id=i,
                        max_new_tokens=c)
                for i, (t, c, a) in enumerate(zip(test, caps, arrivals))]
        res = eng.serve(reqs)
        out[mode] = {k: res[k] for k in
                     ("mean_response_s", "max_response_s",
                      "throughput_per_min", "scheduler_overhead_s")}
    out["throughput_ratio"] = (out["continuous"]["throughput_per_min"]
                               / out["batch"]["throughput_per_min"])
    out["mean_response_ratio"] = (out["continuous"]["mean_response_s"]
                                  / out["batch"]["mean_response_s"])
    return out


def main():
    t0 = time.time()
    sim = run_sim("fifo")
    common.save("continuous_vs_batch_sim", sim)
    common.emit("continuous_vs_batch_sim", time.time() - t0,
                f"throughput_x={sim['throughput_ratio']:.2f},"
                f"mean_response_x={sim['mean_response_ratio']:.2f}")
    t0 = time.time()
    eng = run_engine("fifo")
    common.save("continuous_vs_batch_engine", eng)
    common.emit("continuous_vs_batch_engine", time.time() - t0,
                f"throughput_x={eng['throughput_ratio']:.2f},"
                f"mean_response_x={eng['mean_response_ratio']:.2f}")


if __name__ == "__main__":
    main()
