"""Fused ragged chunked-prefill: every scheduled chunk in ONE launch.

The chunked-prefill engine used to issue one jnp scatter PLUS one
``chunked_prefill_attention`` launch per chunk per request — O(#chunks)
dispatches per iteration, which is exactly the dispatch-overhead regime
where the measured p99 ITL wins shrink on small-batch hosts.  This
kernel executes the whole per-iteration ``ChunkPlan`` batch at once:

  * queries arrive as a per-chunk padded view of the engine's PACKED
    ``(total_tokens, D)`` layout — chunk ``c`` owns rows
    ``q_offset[c] .. q_offset[c] + chunk_len[c] - 1`` of the packed
    stream, re-tiled host-side to ``(C, T_pad, H, D)`` (``T_pad`` is
    the launch's padded max chunk length; rows past ``chunk_len`` are
    padding whose output is undefined);
  * per-chunk metadata rides as a scalar-prefetch operand ``meta``
    with rows ``[slot, ctx_len, chunk_len, q_offset]`` next to the
    per-chunk block tables — the same indirection recipe as
    ``paged_decode_attention``;
  * the chunk's K/V SCATTER is fused in: page blocks are ALIASED
    outputs, and while the innermost grid dimension walks a chunk's
    table entries, any page overlapping logical positions
    ``ctx_len .. ctx_len + chunk_len - 1`` is rewritten with the
    chunk's fresh K/V rows (a one-hot MXU matmul, not a gather) —
    no separate ``kvcache.paged.scatter_*`` pass, no second HBM walk;
  * attention is split into two online-softmax phases: PREFIX pages
    (logical position < ctx_len) stream from the (pre-scatter) pool,
    and the CAUSAL-IN-CHUNK part runs against the chunk's own K/V
    inputs at the last grid step — summing to exactly the
    full-over-prefix / causal-in-chunk mask of the per-chunk kernel.

  grid = (C, KV, nb) — innermost sequential over table entries;
  per page step: q tile (T_pad*G, D) x page (bs, D) on the MXU masked
  by ``kv_pos < ctx_len[c]``, plus the aliased scatter write; at the
  last step the (T_pad*G, T_pad) in-chunk scores join the running
  (m, l, acc) scratch before the finalize.

Safety of the in-place page writes: distinct sequences own distinct
blocks (allocator invariant) and prefix-cache SHARED blocks are never
scatter targets (matches are block-granular and CoW covers the
full-match edge), so no grid step writes a page another chunk reads as
prefix; trash-table padding entries resolve to fully masked, unchanged
page copies.  The pure-jnp oracle is
``ref.ragged_chunked_prefill_ref`` (drop-mode packed scatter + the
gathered-view mask); the model's CPU fallback runs the same math
through ``layers.chunked_attention`` (models/transformer.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

META_SLOT, META_CTX, META_LEN, META_QOFF = 0, 1, 2, 3


def _rcp_kernel(meta_ref, tables_ref, q_ref, kn_ref, vn_ref, k_ref, v_ref,
                o_ref, ok_ref, ov_ref, m_scr, l_scr, acc_scr, *,
                scale: float, block_size: int, groups: int,
                chunk_pad: int):
    c = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    ctx = meta_ref[c, META_CTX]
    clen = meta_ref[c, META_LEN]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (T_pad*G, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bs, D) — page tables[c,ki]
    v = v_ref[0, 0].astype(jnp.float32)
    kn = kn_ref[0, 0]                            # (T_pad, D) chunk K (page dtype)
    vn = vn_ref[0, 0]

    # ---- fused scatter: rewrite this page's rows that fall inside the
    # chunk's logical span with the chunk's fresh K/V.  ``local`` maps
    # page row -> chunk row; the one-hot matmul is the TPU-friendly
    # gather (each selected row sums exactly one chunk row, so values
    # are bit-identical to a direct scatter).
    local = (ki * block_size
             + jax.lax.broadcasted_iota(jnp.int32, (block_size, 1), 0)[:, 0]
             - ctx)                              # (bs,)
    sel = (local >= 0) & (local < clen)
    onehot = ((local[:, None]
               == jax.lax.broadcasted_iota(jnp.int32,
                                           (block_size, chunk_pad), 1))
              & sel[:, None]).astype(jnp.float32)      # (bs, T_pad)
    k_rows = jax.lax.dot_general(
        onehot, kn.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(ok_ref.dtype)
    v_rows = jax.lax.dot_general(
        onehot, vn.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(ov_ref.dtype)
    ok_ref[0, 0] = jnp.where(sel[:, None], k_rows, k_ref[0, 0])
    ov_ref[0, 0] = jnp.where(sel[:, None], v_rows, v_ref[0, 0])

    # ---- prefix phase: attend the (pre-scatter) page, masked to
    # logical positions strictly below the chunk's first position.
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (T_pad*G, bs)
    kv_pos = (ki * block_size
              + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
    valid = kv_pos < ctx
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # re-mask after the shift (see paged_decode_attention: an all-masked
    # row would otherwise average garbage page contents)
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _chunk_and_finalize():
        # ---- in-chunk phase: causal attention against the chunk's own
        # K/V inputs (already page-dtype, so numerics match the
        # post-scatter page contents the per-chunk path would read).
        s2 = jax.lax.dot_general(
            q, kn.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (T_pad*G, T_pad)
        t_q = jax.lax.broadcasted_iota(jnp.int32, s2.shape, 0) // groups
        t_kv = jax.lax.broadcasted_iota(jnp.int32, s2.shape, 1)
        valid2 = (t_kv <= t_q) & (t_kv < clen)
        s2 = jnp.where(valid2, s2, NEG_INF)
        m_prev2 = m_scr[...]
        m_fin = jnp.maximum(m_prev2, s2.max(axis=-1))
        p2 = jnp.where(valid2, jnp.exp(s2 - m_fin[:, None]), 0.0)
        corr2 = jnp.exp(m_prev2 - m_fin)
        l_fin = l_scr[...] * corr2 + p2.sum(axis=-1)
        acc_fin = (acc_scr[...] * corr2[:, None]
                   + jax.lax.dot_general(
                       p2, vn.astype(jnp.float32), (((1,), (0,)), ((), ())),
                       preferred_element_type=jnp.float32))
        o_ref[0, 0] = (acc_fin
                       / jnp.maximum(l_fin, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def ragged_chunked_prefill(q, k_new, v_new, k_pages, v_pages, block_tables,
                           meta, *, interpret: bool = False):
    """q: (C, T_pad, H, D) per-chunk padded queries; k_new/v_new:
    (C, T_pad, KV, D) each chunk's fresh K/V (cast to the page dtype by
    the caller so in-chunk attention matches post-scatter numerics);
    pages: (N, bs, KV, D); block_tables: (C, nb) i32 physical page ids
    (pad with any valid id — typically the trash page); meta: (C, 4)
    i32 rows ``[slot, ctx_len, chunk_len, q_offset]``.

    Returns (out (C, T_pad, H, D), new_k_pages, new_v_pages): the
    attention output for rows ``0 .. chunk_len-1`` of each chunk (rows
    past ``chunk_len`` are undefined padding) and the page pools with
    every chunk's K/V scattered at logical positions
    ``ctx_len .. ctx_len + chunk_len - 1``.  A ``chunk_len == 0`` row
    is a padding chunk: it writes nothing and its output is undefined.
    """
    C, T, H, D = q.shape
    N, bs, KV, _ = k_pages.shape
    _, nb = block_tables.shape
    G = H // KV
    scale = 1.0 / (D ** 0.5)

    # row layout t-major: row = t * G + g, so row // G recovers t
    qt = (q.reshape(C, T, KV, G, D).transpose(0, 2, 1, 3, 4)
          .reshape(C, KV, T * G, D))
    knt = k_new.transpose(0, 2, 1, 3)            # (C, KV, T, D)
    vnt = v_new.transpose(0, 2, 1, 3)
    kt = k_pages.transpose(2, 0, 1, 3)           # (KV, N, bs, D)
    vt = v_pages.transpose(2, 0, 1, 3)
    tables = block_tables.astype(jnp.int32)
    meta = meta.astype(jnp.int32)

    kernel = functools.partial(_rcp_kernel, scale=scale, block_size=bs,
                               groups=G, chunk_pad=T)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # meta, block_tables
        grid=(C, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, T * G, D),
                         lambda c, h, i, m, t: (c, h, 0, 0)),
            pl.BlockSpec((1, 1, T, D),
                         lambda c, h, i, m, t: (c, h, 0, 0)),
            pl.BlockSpec((1, 1, T, D),
                         lambda c, h, i, m, t: (c, h, 0, 0)),
            # the indirection: page tables[c, i] streams into VMEM
            pl.BlockSpec((1, 1, bs, D),
                         lambda c, h, i, m, t: (h, t[c, i], 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda c, h, i, m, t: (h, t[c, i], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T * G, D),
                         lambda c, h, i, m, t: (c, h, 0, 0)),
            # aliased page outputs: the fused scatter writes back the
            # very blocks the walk just streamed in
            pl.BlockSpec((1, 1, bs, D),
                         lambda c, h, i, m, t: (h, t[c, i], 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda c, h, i, m, t: (h, t[c, i], 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((T * G,), jnp.float32),
            pltpu.VMEM((T * G,), jnp.float32),
            pltpu.VMEM((T * G, D), jnp.float32),
        ],
    )
    out, new_kt, new_vt = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C, KV, T * G, D), q.dtype),
            jax.ShapeDtypeStruct(kt.shape, kt.dtype),
            jax.ShapeDtypeStruct(vt.shape, vt.dtype),
        ],
        # operand indices include the scalar-prefetch args: meta=0,
        # tables=1, qt=2, knt=3, vnt=4, kt=5, vt=6
        input_output_aliases={5: 1, 6: 2},
        interpret=interpret,
    )(meta, tables, qt, knt, vnt, kt, vt)
    out = (out.reshape(C, KV, T, G, D).transpose(0, 2, 1, 3, 4)
           .reshape(C, T, H, D))
    return (out, new_kt.transpose(1, 2, 0, 3), new_vt.transpose(1, 2, 0, 3))
