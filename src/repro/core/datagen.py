"""Synthetic dialogue corpora built from the six-type uncertainty taxonomy.

The paper's own probe set was constructed the same way (§III-A: "we create
1,000 utterances for each of the six uncertainty types"); its four
benchmark datasets (Blended Skill Talk, PersonaChat, ConvAI2, Empathetic
Dialogues) are emulated as four corpora with different *mixes* of the six
types + plain utterances — the statistic that matters to the scheduler is
the induced distribution (variance) of uncertainty scores, which we match
qualitatively to Fig. 3.

Every utterance carries a ground-truth "true uncertainty" u* (derived
from its template slots, NOT from RULEGEN — the predictor must learn the
mapping) and per-persona output lengths sampled as

    len = clip(base_f + gain_f * u* + eps,  1, max_output)

reflecting Fig. 1a: vague/open/multi types induce the longest outputs,
semantic > structural/syntactic among the lexical ambiguities.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence

import numpy as np

from . import personas as personas_lib
from .rulegen import UNCERTAINTY_TYPES

# ---------------------------------------------------------------------------
# template banks (slot-filled)
# ---------------------------------------------------------------------------

_NAMES = ["john", "mary", "the officer", "my friend", "the teacher",
          "a student", "the doctor", "anna", "the researcher", "tom"]
_NOUNS = ["boy", "dog", "bird", "painting", "robot", "car", "statue",
          "kite", "drone", "violin"]
_PLACES = ["park", "garden", "museum", "street", "library", "station",
           "market", "forest", "harbor", "stadium"]
_INSTR = ["telescope", "camera", "umbrella", "flashlight", "map",
          "binoculars", "ladder", "net", "whistle", "radio"]
_AMBIG_SUBJ = ["rice", "time", "fruit", "sand", "dust", "seed", "water"]
_AMBIG_VERBS = ["flies", "runs", "walks", "races", "files", "rounds"]
_POLY = ["bat", "trunk", "monitor", "bank", "spring", "pitch", "crane",
         "seal", "bolt", "club", "match", "scale", "ring", "wave", "bar",
         "key", "bug", "mole", "port"]
_TOPICS = ["art", "music", "science", "philosophy", "technology",
           "medicine", "education", "architecture", "literature",
           "economics"]
_ISSUES = ["poverty", "climate change", "inequality", "urbanization",
           "automation", "migration", "pollution", "aging populations",
           "misinformation", "unemployment"]
_REGIONS = ["developing countries", "coastal cities", "rural areas",
            "modern societies", "large cities", "small towns"]
_PAIR_A = ["cats", "trains", "novels", "lakes", "pianos", "bees"]
_PAIR_B = ["dogs", "planes", "films", "rivers", "guitars", "ants"]
_ASPECTS = ["behavior", "diet", "cost", "history", "maintenance",
            "social interaction", "structure", "speed", "sound", "habitat"]
_PLAIN = [
    "i had pasta for dinner yesterday.",
    "the train leaves at seven tomorrow.",
    "my sister lives near the station.",
    "it rained all day on monday.",
    "please pass the salt.",
    "the meeting starts at noon.",
    "i bought two tickets for the show.",
    "she finished the report on friday.",
    "the shop closes at nine.",
    "we walked home after lunch.",
]


def _gen_one(utype: str, rng: random.Random):
    """Returns (text, true_uncertainty)."""
    if utype == "structural":
        n_pp = rng.choice([2, 2, 3])
        pps = rng.sample(
            [f"in the {rng.choice(_PLACES)}", f"with a {rng.choice(_INSTR)}",
             f"near the {rng.choice(_PLACES)}", f"by the {rng.choice(_PLACES)}"],
            n_pp)
        text = (f"{rng.choice(_NAMES)} saw a {rng.choice(_NOUNS)} "
                + " ".join(pps) + ".")
        u = 2.0 + 1.6 * (n_pp - 1) + rng.uniform(-0.4, 0.4)
    elif utype == "syntactic":
        n = rng.choice([1, 2, 2, 3])
        subj = rng.choice(_AMBIG_SUBJ)
        verb = rng.choice(_AMBIG_VERBS)
        tail = rng.choice(["like sand", "like an arrow", "like a bird",
                           "like water"])
        extra = " and ".join(rng.sample(_AMBIG_VERBS, max(0, n - 1)))
        text = f"{subj} {verb} {tail}" + (f" and {extra}." if extra else ".")
        u = 1.6 + 1.2 * n + rng.uniform(-0.4, 0.4)
    elif utype == "semantic":
        n = rng.choice([1, 2, 2, 3])
        words = rng.sample(_POLY, n)
        frame = rng.choice([
            "what's the best way to deal with {w}?",
            "i saw a {w} near the {p}.",
            "can you explain what a {w} is?",
            "the {w} by the {p} surprised everyone.",
        ])
        text = frame.format(w=words[0], p=rng.choice(_PLACES))
        for w in words[1:]:
            text += f" also, what about the {w}?"
        u = 3.0 + 1.8 * n + rng.uniform(-0.5, 0.5)
    elif utype == "vague":
        depth = rng.choice([1, 2, 2, 3])
        text = rng.choice([
            "tell me about the {a} of {t}.",
            "can you talk about the {a} of {t}?",
            "i want to know about the {a} of {t} in general.",
        ]).format(a=rng.choice(["history", "nature", "philosophy",
                                "meaning", "future"]),
                  t=rng.choice(_TOPICS))
        if depth >= 2:
            text += " cover many broad aspects."
        if depth >= 3:
            text += " include the whole general context."
        u = 5.5 + 1.8 * depth + rng.uniform(-0.6, 0.6)
    elif utype == "open_ended":
        depth = rng.choice([1, 2, 2, 3])
        text = rng.choice([
            "what are the causes and consequences of {i} in {r}?",
            "why do {i} keep getting worse in {r}?",
            "how could {r} address {i} over time?",
            "what do you think about {i}?",
        ]).format(i=rng.choice(_ISSUES), r=rng.choice(_REGIONS))
        if depth >= 2:
            text += " please give reasons and implications."
        if depth >= 3:
            text += " what is the long term significance?"
        u = 6.0 + 2.0 * depth + rng.uniform(-0.7, 0.7)
    elif utype == "multi_part":
        k = rng.choice([2, 3, 3, 4])
        aspects = rng.sample(_ASPECTS, k)
        text = (f"how do {rng.choice(_PAIR_A)} and {rng.choice(_PAIR_B)} "
                f"differ in {', '.join(aspects[:-1])}, and {aspects[-1]}?")
        if rng.random() < 0.4:
            text += " and which is better overall?"
        u = 5.0 + 1.7 * k + rng.uniform(-0.6, 0.6)
    else:  # plain
        text = rng.choice(_PLAIN)
        u = 0.4 + 0.08 * len(text.split()) + rng.uniform(-0.2, 0.2)
    return text, max(0.1, u)


@dataclasses.dataclass
class Task:
    """One inference request."""
    text: str
    utype: str
    true_u: float                       # ground-truth uncertainty
    out_lens: Dict[str, int]            # persona -> true output length
    task_id: int = -1
    arrival: float = 0.0                # r_J (set by the workload)
    deadline: Optional[float] = None    # user-specified t_J, usually None
    malicious: bool = False


def make_task(utype: str, rng: random.Random, task_id: int = -1,
              malicious: bool = False) -> Task:
    text, u = _gen_one(utype, rng)
    if malicious:
        # §V-G: adversarially crafted inputs elongating outputs — emulate
        # the attack of [56] by stacking uncertainty markers.
        text += (" i talk a lot and it is fun to learn about it with some"
                 " other guys. tell me about the history of art, the"
                 " meaning of life, and what you think about the future.")
        u = u + 12.0 + rng.uniform(0, 6.0)
    out_lens = {}
    for name, p in personas_lib.PERSONAS.items():
        ln = p.base_output + p.uncertainty_gain * u + \
            rng.gauss(0.0, p.noise_std)
        out_lens[name] = int(np.clip(round(ln), 1, p.max_output))
    return Task(text=text, utype=utype, true_u=u, out_lens=out_lens,
                task_id=task_id, malicious=malicious)


# ---------------------------------------------------------------------------
# corpora
# ---------------------------------------------------------------------------

ALL_TYPES = UNCERTAINTY_TYPES + ("plain",)

# four benchmark-dataset emulations: different six-type mixes
DATASET_MIXES = {
    # BST blends skills -> broad mix
    "blended_skill_talk": {"plain": .30, "structural": .08, "syntactic": .07,
                           "semantic": .15, "vague": .15, "open_ended": .15,
                           "multi_part": .10},
    # persona chit-chat -> mostly plain/vague
    "personachat": {"plain": .45, "structural": .05, "syntactic": .05,
                    "semantic": .10, "vague": .20, "open_ended": .10,
                    "multi_part": .05},
    # convai2 -> questions galore
    "convai2": {"plain": .30, "structural": .05, "syntactic": .05,
                "semantic": .10, "vague": .15, "open_ended": .20,
                "multi_part": .15},
    # empathetic -> open-ended heavy
    "empathetic_dialogues": {"plain": .35, "structural": .04,
                             "syntactic": .04, "semantic": .07,
                             "vague": .15, "open_ended": .25,
                             "multi_part": .10},
}

# §V-B variance subsets
VARIANCE_MIXES = {
    "small": {"plain": .60, "structural": .10, "syntactic": .10,
              "semantic": .20, "vague": 0.0, "open_ended": 0.0,
              "multi_part": 0.0},
    "normal": DATASET_MIXES["blended_skill_talk"],
    "large": {"plain": .25, "structural": .05, "syntactic": .05,
              "semantic": .10, "vague": .15, "open_ended": .20,
              "multi_part": .20},
}


def generate_corpus(mix: Dict[str, float], n: int, seed: int = 0,
                    malicious_frac: float = 0.0) -> List[Task]:
    rng = random.Random(seed)
    types = list(mix)
    weights = [mix[t] for t in types]
    tasks = []
    for i in range(n):
        utype = rng.choices(types, weights)[0]
        mal = rng.random() < malicious_frac
        tasks.append(make_task(utype, rng, task_id=i, malicious=mal))
    return tasks


def probe_set(n_per_type: int = 1000, seed: int = 0) -> Dict[str, List[Task]]:
    """§III-A probe: n utterances for each of the six types."""
    out = {}
    for j, utype in enumerate(UNCERTAINTY_TYPES):
        rng = random.Random(seed + 1000 * j)
        out[utype] = [make_task(utype, rng, task_id=i)
                      for i in range(n_per_type)]
    return out


def train_test_split(tasks: Sequence[Task], train_frac: float = 0.7,
                     seed: int = 0):
    idx = list(range(len(tasks)))
    random.Random(seed).shuffle(idx)
    cut = int(len(tasks) * train_frac)
    return [tasks[i] for i in idx[:cut]], [tasks[i] for i in idx[cut:]]
