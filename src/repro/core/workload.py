"""Poisson workload generation (paper §V-A Workload setup).

Inter-arrival times are sampled from an exponential distribution whose
rate evolves minute-by-minute through beta = 10..150 queries/min (the
paper iterates integer beta values, one minute each, light load to
high-traffic peak).  A wait-time interval xi (=2 s) groups arrivals for
batch processing — the simulator implements xi as its dispatch window.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np


def poisson_trace(n_tasks: int, *, beta_min: int = 10, beta_max: int = 150,
                  seed: int = 0,
                  betas: Optional[Sequence[int]] = None) -> List[float]:
    """Arrival times (s) for n_tasks, beta evolving one minute per value."""
    rng = np.random.default_rng(seed)
    if betas is None:
        betas = list(range(beta_min, beta_max + 1, 10))
    arrivals: List[float] = []
    t = 0.0
    minute_end = 60.0
    bi = 0
    while len(arrivals) < n_tasks:
        beta = betas[min(bi, len(betas) - 1)]
        mu = 60.0 / beta                       # mean inter-arrival (s)
        t = t + rng.exponential(mu)
        while t >= minute_end:
            minute_end += 60.0
            bi += 1
        arrivals.append(t)
    return arrivals


def constant_rate_trace(n_tasks: int, beta: float, seed: int = 0
                        ) -> List[float]:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(60.0 / beta, size=n_tasks)
    return list(np.cumsum(gaps))
