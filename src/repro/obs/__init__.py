"""Observability substrate shared by the engine and the simulator.

``repro.obs`` is the telemetry layer under the serving stack's
bit-parity twin discipline: the real engine
(``ServingEngine(obs=...)``) and the simulator
(``simulate_continuous(obs=...)``) drive the SAME recorder and the
SAME metrics registry from the same decision points, so

  * the lifecycle EVENT stream (``obs.trace``) compares equal between
    engine and simulator up to wall-clock fields, and
  * every COUNTER both sides emit compares bit-for-bit,

exactly like the dispatch/budget traces in ``_result``/``SimResult``.
Recording is OFF by default (``obs=None`` everywhere): the serve loops
only touch the recorder behind ``if obs is not None`` guards, and the
no-obs serve path is bit-identical to the pre-obs engine
(tests/test_obs.py::test_obs_none_results_unchanged).

Three pieces:

  * ``obs.trace``   — typed per-request lifecycle events + engine
    spans, JSONL sink, Chrome/Perfetto ``trace_event`` exporter;
  * ``obs.metrics`` — counters, gauges, log-bucketed streaming
    histograms with mergeable state and deterministic quantiles (the
    percentile substrate of ``_result``/``SimResult``);
  * ``obs.log``     — rate-limited warnings with countable fallback
    events (``fallback_events`` in serve results).

Failure-aware serving (``repro.serving.faults``) adds the fault
lifecycle kinds to ``EVENT_KINDS`` — ``timeout``, ``shed``, ``retry``,
``failover``, ``replica_down``, ``replica_up``, ``dead_letter`` — and
the ``faults.*`` counters (``faults.timed_out``, ``faults.shed``,
``faults.retries``, ``faults.failovers``, ``faults.dead_lettered``,
``faults.replica_down``).  All of them sit inside the
parity view: a faulted engine run and its faulted simulator twin emit
identical fault streams and counter values; runs without a fault plan
emit none of them (byte-identity with pre-fault recording).

``Observability`` bundles one recorder + one registry per run; build
one with ``Observability()`` and pass it to ``ServingEngine(obs=...)``
/ ``simulate_continuous(obs=...)``, then export with
``obs.trace.to_jsonl(path)`` and inspect with
``scripts/trace_report.py`` (waterfall + percentile table) or
``ui.perfetto.dev`` (via ``obs.trace.export_perfetto``).
"""

from __future__ import annotations

import time
from typing import Optional

from .calibration import CalibrationLedger, u_bucket
from .log import FALLBACKS, RateLimitedLogger, fallback_count, warn_once
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      percentiles)
from .slo import (SLO_METRICS, SLOMonitor, SLOSpec, WindowedHistogram)
from .trace import (EVENT_KINDS, WALL_FIELDS, Event, RequestTimeline,
                    Span, TraceRecorder, timelines)

__all__ = [
    "CalibrationLedger", "Counter", "Event", "EVENT_KINDS", "FALLBACKS",
    "Gauge", "Histogram", "MetricsRegistry", "Observability",
    "RateLimitedLogger", "RequestTimeline", "SLO_METRICS", "SLOMonitor",
    "SLOSpec", "Span", "TraceRecorder", "WALL_FIELDS",
    "WindowedHistogram", "fallback_count", "percentiles", "timelines",
    "u_bucket", "warn_once",
]


class Observability:
    """One serve/simulation run's telemetry bundle.

    ``trace`` and ``metrics`` may individually be disabled (``None``);
    the convenience emitters no-op for a disabled piece, so call sites
    need only the single outer ``if obs is not None`` guard.

    ``overhead_s`` accumulates the wall-clock the ENGINE measured
    around its per-iteration emission blocks (``measure()``) — the
    measured-overhead guard: recording never touches the engine's
    virtual clock (events are emitted outside the timed device
    regions), and the measured wall cost is reported alongside the
    results so regressions are visible, not guessed.
    """

    def __init__(self, *, trace: bool = True, metrics: bool = True,
                 max_events: int = 1_000_000,
                 slo=None, calibration=None,
                 snapshot_every_steps: int = 0):
        self.trace: Optional[TraceRecorder] = \
            TraceRecorder(max_events) if trace else None
        self.metrics: Optional[MetricsRegistry] = \
            MetricsRegistry() if metrics else None
        self.overhead_s = 0.0
        # --- PR 8: SLO monitor / calibration ledger / health snapshots
        # (all three default OFF so pre-PR construction is unchanged)
        if slo is True:
            slo = SLOMonitor()
        elif isinstance(slo, dict):
            slo = SLOMonitor(slo)
        self.slo: Optional[SLOMonitor] = slo
        if calibration is True:
            calibration = CalibrationLedger()
        self.calibration: Optional[CalibrationLedger] = calibration
        #: snapshot cadence in DECODE STEPS (the shared engine/sim
        #: iteration coordinate, so both sides snapshot at the same
        #: points); 0 disables snapshots
        self.snapshot_every_steps = int(snapshot_every_steps)
        self._snap_bucket: dict = {}   # per replica label (None=global)
        self.health_trace: list = []
        #: active replica id for multi-replica serving (PR 9): while
        #: set (an int), every event/span gains a ``replica`` field,
        #: counters additionally bump an ``r{label}.``-prefixed mirror,
        #: and SLO observations are double-counted per replica — so one
        #: shared bundle records R replicas with per-replica parity
        #: views (``TraceRecorder.parity_events(replica=r)``).  ``None``
        #: (the default, and the R=1 serving path) leaves every stream
        #: byte-identical to single-replica recording.
        self.replica_label: Optional[int] = None
        if self.trace is not None and self.slo is not None \
                and self.slo.classes:
            self.trace.meta["slo"] = self.slo.targets_json()

    # ------------------------------------------------------------------
    # no-op-safe emitters — each self-times into ``overhead_s``
    # ------------------------------------------------------------------
    def event(self, kind: str, ts: float, task_id=None, step=None,
              **fields) -> None:
        if self.trace is not None:
            t0 = time.perf_counter()
            if self.replica_label is not None and "replica" not in fields:
                fields["replica"] = self.replica_label
            self.trace.event(kind, ts, task_id, step, **fields)
            self.overhead_s += time.perf_counter() - t0

    def span(self, name: str, ts: float, dur: float,
             track: str = "engine", **fields) -> None:
        if self.trace is not None:
            t0 = time.perf_counter()
            if self.replica_label is not None and "replica" not in fields:
                fields["replica"] = self.replica_label
            self.trace.span(name, ts, dur, track, **fields)
            self.overhead_s += time.perf_counter() - t0

    def counter_sample(self, name: str, ts: float, value: float) -> None:
        if self.trace is not None:
            t0 = time.perf_counter()
            self.trace.counter(name, ts, value)
            self.overhead_s += time.perf_counter() - t0

    def inc(self, name: str, n: int = 1) -> None:
        if self.metrics is not None:
            t0 = time.perf_counter()
            self.metrics.counter(name).inc(n)
            if self.replica_label is not None:
                # per-replica counter mirror: pool totals stay in the
                # unprefixed counter, ``r{label}.*`` carries the split
                self.metrics.counter(
                    f"r{self.replica_label}.{name}").inc(n)
            self.overhead_s += time.perf_counter() - t0

    def gauge(self, name: str, value: float) -> None:
        if self.metrics is not None:
            t0 = time.perf_counter()
            self.metrics.gauge(name).set(value)
            self.overhead_s += time.perf_counter() - t0

    def observe(self, name: str, value: float, n: int = 1) -> None:
        if self.metrics is not None:
            t0 = time.perf_counter()
            self.metrics.histogram(name).record(value, n)
            self.overhead_s += time.perf_counter() - t0

    # ------------------------------------------------------------------
    # SLO / calibration / snapshot emitters (PR 8) — same no-op-safe,
    # self-timed discipline as the trace/metrics emitters above
    # ------------------------------------------------------------------
    def slo_observe(self, metric: str, cls: str, ts: float,
                    value: float, n: int = 1) -> None:
        """Record a latency observation for (traffic class, metric)."""
        if self.slo is not None:
            t0 = time.perf_counter()
            self.slo.observe(metric, cls, ts, value, n,
                             replica=self.replica_label)
            self.overhead_s += time.perf_counter() - t0

    def complete_request(self, cls: str, ts: float, *, u: float,
                         out_len: int,
                         latency_s: Optional[float] = None) -> None:
        """One request finished: count the completion for its class,
        judge its end-to-end latency, and ledger u vs realization."""
        if self.slo is None and self.calibration is None:
            return
        t0 = time.perf_counter()
        if self.slo is not None:
            resolved = self.slo.complete(cls,
                                         replica=self.replica_label)
            if latency_s is not None:
                self.slo.observe("e2e", cls, ts, latency_s,
                                 replica=self.replica_label)
            if self.metrics is not None:
                self.metrics.counter(
                    "slo.completions." + resolved).inc()
                if self.replica_label is not None:
                    self.metrics.counter(
                        f"r{self.replica_label}.slo.completions."
                        + resolved).inc()
        if self.calibration is not None:
            self.calibration.record(u, out_len, latency_s)
        self.overhead_s += time.perf_counter() - t0

    def maybe_snapshot(self, ts: float, step: int, *, queue_depth: int,
                       active: int, kv_util: float,
                       wall: Optional[dict] = None) -> None:
        """Emit a periodic health ``snapshot`` event (and append it to
        ``health_trace``) every ``snapshot_every_steps`` decode steps.

        Cadence keys off ``step`` — not the clock — so the engine and
        the simulator snapshot at identical iterations; ``attainment``
        (wall latencies) and ``wall`` (engine-only extras) are in
        ``WALL_FIELDS`` and drop out of the parity view, leaving the
        deterministic observation vector (queue depth, active, KV
        utilization, drift, calibration count) to compare bit-for-bit.
        """
        if self.snapshot_every_steps <= 0:
            return
        # cadence state is per replica label (None = single-replica):
        # replica 3 crossing a bucket boundary must not suppress
        # replica 0's next snapshot when R replicas share the bundle
        bucket = step // self.snapshot_every_steps
        if bucket <= self._snap_bucket.get(self.replica_label, 0):
            return
        t0 = time.perf_counter()
        self._snap_bucket[self.replica_label] = bucket
        fields: dict = {"queue_depth": int(queue_depth),
                        "active": int(active),
                        "kv_util": float(kv_util)}
        if self.replica_label is not None:
            fields["replica"] = self.replica_label
        if self.calibration is not None:
            fields["drift"] = self.calibration.drift()
            fields["calibration_count"] = self.calibration.count
        if self.slo is not None:
            fields["attainment"] = self.slo.windowed_attainment()
        if wall:
            fields["wall"] = dict(wall)
        self.health_trace.append({"ts": float(ts), "step": int(step),
                                  **fields})
        if self.trace is not None:
            self.trace.event("snapshot", ts, None, step, **fields)
        self.overhead_s += time.perf_counter() - t0

    def health(self) -> dict:
        """Latest health snapshot ({} before the first one) — the
        observation vector a future auto-tuner/router polls."""
        return self.health_trace[-1] if self.health_trace else {}

    # ------------------------------------------------------------------
    def measure(self):
        """Context manager accumulating wall time into ``overhead_s``."""
        return _Measure(self)

    def event_count(self) -> int:
        return len(self.trace.events) if self.trace is not None else 0


class _Measure:
    __slots__ = ("obs", "t0")

    def __init__(self, obs: Observability):
        self.obs = obs

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.obs.overhead_s += time.perf_counter() - self.t0
        return False
