#!/usr/bin/env python
"""Render SLO attainment + calibration from a repro.obs JSONL trace.

    PYTHONPATH=src python scripts/slo_report.py trace.jsonl
    PYTHONPATH=src python scripts/slo_report.py trace.jsonl --json

Reads the JSONL sink written by ``Observability`` /
``TraceRecorder.to_jsonl`` (engine or simulator — same schema) and
prints the PR-8 observability views:

  * a PER-CLASS ATTAINMENT TABLE — TTFT / inter-token latency / queue
    wait / end-to-end latency per traffic class, each judged against
    the per-class targets carried in the trace's ``meta`` line (written
    when the run declared SLO classes), with ok/total attainment
    fractions and p50/p90/p99;
  * a RELIABILITY DIAGRAM — predicted uncertainty u vs realized output
    length by power-of-two u bucket (``repro.obs.u_bucket``), an ASCII
    rendering of the calibration ledger's reliability rows;
  * a HEALTH TABLE — the periodic ``snapshot`` events (step, queue
    depth, active slots, KV utilization, calibration drift).

Latencies are reconstructed from the event stream via
``repro.obs.timelines`` — the same reconstruction the acceptance tests
check against the engine's result dict — so the report works on any
conforming trace, whichever side emitted it.

Exits non-zero on schema violations (unknown event kind — the typed
vocabulary is ``repro.obs.EVENT_KINDS``) or an empty trace, so CI can
smoke-check any committed trace.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs import (EVENT_KINDS, SLO_METRICS, CalibrationLedger,
                       SLOMonitor, SLOSpec, TraceRecorder, timelines)

SNAPSHOT_COLS = ("step", "queue_depth", "active", "kv_util", "drift",
                 "calibration_count")


def validate(rec: TraceRecorder) -> list:
    """Schema check: every event kind must be in the typed vocabulary."""
    return sorted({e.kind for e in rec.events} - EVENT_KINDS)


def monitor_from_trace(rec: TraceRecorder) -> SLOMonitor:
    """Replay the trace's per-request latencies through a fresh
    ``SLOMonitor`` built from the targets in the ``meta`` line (classes
    default to no targets when the trace carries none)."""
    targets = {name: SLOSpec.from_json(obj)
               for name, obj in (rec.meta.get("slo") or {}).items()}
    mon = SLOMonitor(targets or None)
    tls = timelines(rec)
    for tid in sorted(tls):
        t = tls[tid]
        if t.queue_wait is not None:
            mon.observe("queue_wait", t.cls, t.admit_ts, t.queue_wait)
        if t.ttft is not None:
            mon.observe("ttft", t.cls, t.first_token_ts, t.ttft)
        for itl in t.itls:
            mon.observe("itl", t.cls, t.complete_ts, itl)
        if t.complete_ts >= 0:
            mon.complete(t.cls)
            if t.e2e is not None:
                mon.observe("e2e", t.cls, t.complete_ts, t.e2e)
    return mon


def ledger_from_trace(rec: TraceRecorder) -> CalibrationLedger:
    """Replay completed requests carrying (u, out_len) into a fresh
    calibration ledger."""
    led = CalibrationLedger()
    tls = timelines(rec)
    for tid in sorted(tls):
        t = tls[tid]
        if t.u >= 0.0 and t.out_len >= 0:
            led.record(t.u, t.out_len, t.e2e)
    return led


def attainment_table(mon: SLOMonitor) -> str:
    rows = mon.attainment()
    if not rows:
        return "(no completed requests)"
    head = (f"{'class':<14} {'metric':<12} {'target_s':>10} {'ok':>6} "
            f"{'total':>6} {'frac':>7} {'p50':>10} {'p90':>10} "
            f"{'p99':>10}")
    lines = [head, "-" * len(head)]
    for cls in sorted(rows):
        row = rows[cls]
        for metric in SLO_METRICS:
            m = row[metric]
            tgt = m["target_s"]
            tgt_s = (f"{tgt:>10.4f}" if abs(tgt) != float("inf")
                     else f"{'-':>10}")
            snap = m.get("lifetime") or {}
            ps = "".join(f" {snap.get(p, 0.0):>10.4f}"
                         for p in ("p50", "p90", "p99"))
            lines.append(
                f"{cls:<14} {metric:<12} {tgt_s} {m['ok']:>6} "
                f"{m['total']:>6} {m['frac']:>7.3f}{ps}")
        lines.append(f"{cls:<14} {'completions':<12} {'':>10} "
                     f"{row['completions']:>6}")
    return "\n".join(lines)


def reliability_diagram(led: CalibrationLedger, width: int = 40) -> str:
    rows = led.reliability()
    if not rows:
        return "(no calibration samples — trace lacks u/out_len fields)"
    top = max(max(r["u_mean"], r["real_mean"]) for r in rows)
    top = max(top, 1e-9)

    def bar(v: float, ch: str) -> str:
        return ch * max(1, int(round(v / top * width)))

    lines = [f"reliability  (u bucket -> predicted 'u' vs realized '#', "
             f"full bar = {top:.2f})",
             f"{'u range':<16} {'n':>5} {'u_mean':>8} {'real':>8}  bars"]
    for r in rows:
        rng = f"[{r['u_lo']:g}, {r['u_hi']:g})"
        lines.append(f"{rng:<16} {r['n']:>5} {r['u_mean']:>8.2f} "
                     f"{r['real_mean']:>8.2f}  u|{bar(r['u_mean'], 'u')}")
        lines.append(f"{'':<16} {'':>5} {'':>8} {'':>8}  "
                     f"#|{bar(r['real_mean'], '#')}")
    lines.append(f"mae={led.mae:.3f}  bias={led.bias:+.3f}  "
                 f"drift={led.drift():.3f}  n={led.count}")
    return "\n".join(lines)


def health_table(rec: TraceRecorder) -> str:
    snaps = [e for e in rec.events if e.kind == "snapshot"]
    if not snaps:
        return "(no snapshot events — run with snapshot_every_steps>0)"
    head = "  ".join(f"{c:>12}" for c in SNAPSHOT_COLS)
    lines = [head, "-" * len(head)]
    for e in snaps:
        cells = []
        for c in SNAPSHOT_COLS:
            v = e.step if c == "step" else e.fields.get(c)
            if v is None:
                cells.append(f"{'-':>12}")
            elif isinstance(v, float):
                cells.append(f"{v:>12.4f}")
            else:
                cells.append(f"{v:>12}")
        lines.append("  ".join(cells))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="JSONL trace (TraceRecorder.to_jsonl)")
    ap.add_argument("--json", action="store_true",
                    help="emit attainment + calibration as JSON instead "
                         "of text (machine-readable smoke checks)")
    args = ap.parse_args(argv)

    rec = TraceRecorder.load_jsonl(args.trace)
    unknown = validate(rec)
    if unknown:
        print(f"schema violation: unknown event kinds {unknown} "
              f"(expected subset of {sorted(EVENT_KINDS)})",
              file=sys.stderr)
        return 1
    if not rec.events:
        print("empty trace", file=sys.stderr)
        return 1

    mon = monitor_from_trace(rec)
    led = ledger_from_trace(rec)
    snaps = sum(1 for e in rec.events if e.kind == "snapshot")

    if args.json:
        print(json.dumps({
            "events": len(rec.events),
            "requests": len(timelines(rec)),
            "snapshots": snaps,
            "classes": {cls: {"completions": row["completions"],
                              "frac": {m: row[m]["frac"]
                                       for m in SLO_METRICS}}
                        for cls, row in mon.attainment().items()},
            "calibration": {"count": led.count, "mae": led.mae,
                            "bias": led.bias, "drift": led.drift()},
        }))
        return 0

    print(f"{args.trace}: {len(rec.events)} events, "
          f"{len(timelines(rec))} requests, {snaps} snapshots, "
          f"slo meta: {json.dumps(rec.meta.get('slo') or {})}")
    print()
    print(attainment_table(mon))
    print()
    print(reliability_diagram(led))
    print()
    print(health_table(rec))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
