"""Sequence-parallel / serving-layout lowering equivalence: the §Perf
optimization flags must not change the computed function.  Runs on an
8-placeholder-device mesh in a subprocess."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch import mesh as mesh_lib
from repro.models import model as model_lib
from repro.sharding import context as shctx, policy as policy_lib
from repro.training import data as data_lib

mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
# smoke minitron analogue: heads NOT divisible by model axis (8 % ... use
# heads=6 to hit the seq-attention fallback on a 4-wide model axis)
import dataclasses
cfg = dataclasses.replace(configs.get_smoke_config("minitron-4b"),
                          num_heads=6, num_kv_heads=2, head_dim=32,
                          d_model=192, d_ff=384)
params = model_lib.init_params(key, cfg)
tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
labels = jnp.concatenate([tokens[:, 1:], -jnp.ones((4, 1), jnp.int32)], 1)
batch = {"tokens": tokens, "labels": labels}

loss_ref, _ = model_lib.lm_loss(params, cfg, batch)   # no policy

results = {}
for seq_parallel in (False, True):
    policy = policy_lib.make_policy(mesh)
    policy.seq_parallel = seq_parallel
    with mesh, shctx.use_policy(policy):
        loss, _ = jax.jit(
            lambda p, b: model_lib.lm_loss(p, cfg, b))(params, batch)
    results[seq_parallel] = float(loss)
    assert abs(float(loss) - float(loss_ref)) < 5e-2, \
        (seq_parallel, float(loss), float(loss_ref))

# decode with serving layout (kv=2 divides 4 -> also test kv=1 fallback)
cfg2 = dataclasses.replace(configs.get_smoke_config("yi-6b"),
                           num_kv_heads=1, num_heads=6, head_dim=32,
                           d_model=192, d_ff=384)
params2 = model_lib.init_params(key, cfg2)
batch2 = {"tokens": tokens}
cache_ref, logits_ref = model_lib.prefill(params2, cfg2, batch2, 48)
tok = jnp.argmax(logits_ref, -1).astype(jnp.int32)[:, None]
_, lg_ref, _ = model_lib.decode_step(params2, cfg2, cache_ref, tok)

policy = policy_lib.make_policy(mesh, fsdp=False)
policy.serving = True
with mesh, shctx.use_policy(policy):
    cache, logits = jax.jit(
        lambda p, b: model_lib.prefill(p, cfg2, b, 48))(params2, batch2)
    _, lg, _ = jax.jit(
        lambda p, c, t: model_lib.decode_step(p, cfg2, c, t))(
        params2, cache, tok)
err = float(jnp.abs(lg - lg_ref).max())
assert err < 0.5, err   # bf16 reduction-order differences
print("SP_OK")
"""


def test_perf_flags_preserve_semantics():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, timeout=480,
                       env=env, cwd=REPO)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SP_OK" in r.stdout
