"""Jitted public wrappers around the Pallas kernels.

Dispatch contract:
  * on TPU: compiled Pallas kernels (the production path);
  * elsewhere (this CPU container): ``interpret=True`` executes the same
    kernel bodies in Python for correctness validation, unless
    ``use_pallas=False`` falls back to the chunked-jnp implementations in
    ``repro.models.layers`` (the path the multi-pod dry-run lowers).

All wrappers are shape-polymorphic jit functions; block sizes are static
arguments so benchmarks can sweep them.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as jlayers

from . import (chunked_prefill_attention as _cpa,
               decode_attention as _fd, flash_attention as _fa,
               paged_decode_attention as _pfd,
               ragged_chunked_prefill as _rcp, ref as _ref, rmsnorm as _rn)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "use_pallas", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, use_pallas: bool = True,
                    interpret: Optional[bool] = None):
    """Prefill/train attention. q: (B,S,H,D); k/v: (B,S,KV,D)."""
    if not use_pallas:
        S = q.shape[1]
        pos = jnp.arange(S)
        return jlayers.chunked_attention(
            q, k, v, q_positions=pos, kv_positions=pos, causal=causal,
            window=window)
    interp = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interp)


@functools.partial(jax.jit, static_argnames=(
    "block_k", "use_pallas", "interpret"))
def flash_decode_attention(q, k_cache, v_cache, mask, *, block_k: int = 512,
                           use_pallas: bool = True,
                           interpret: Optional[bool] = None):
    """One-token decode attention. q: (B,H,D); caches: (B,S,KV,D);
    mask: (B,S) bool — valid cache slots (ring positions pre-resolved)."""
    if not use_pallas:
        B, H, D = q.shape
        S = k_cache.shape[1]
        # emulate via the layers decode path: mask -> positions trick
        kv_pos = jnp.where(mask[0], 0, 2**30)
        out = jlayers.decode_attention(
            q[:, None], k_cache, v_cache,
            q_position=jnp.int32(0), kv_positions=kv_pos,
            valid_len=jnp.int32(S))
        return out[:, 0]
    interp = _default_interpret() if interpret is None else interpret
    return _fd.flash_decode_attention(q, k_cache, v_cache, mask,
                                      block_k=block_k, interpret=interp)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                           use_pallas: bool = True,
                           interpret: Optional[bool] = None):
    """One-token decode attention over a paged KV cache.

    q: (B,H,D); pages: (N,bs,KV,D); block_tables: (B,nb) i32;
    seq_lens: (B,) i32.  ``use_pallas=False`` gathers the contiguous
    view in pure jnp (the path the model's paged decode lowers on CPU).
    """
    if not use_pallas:
        return _ref.paged_decode_attention_ref(q, k_pages, v_pages,
                                               block_tables, seq_lens)
    interp = _default_interpret() if interpret is None else interpret
    return _pfd.paged_flash_decode_attention(q, k_pages, v_pages,
                                             block_tables, seq_lens,
                                             interpret=interp)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def chunked_prefill_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                              *, use_pallas: bool = True,
                              interpret: Optional[bool] = None):
    """Chunked-prefill attention over a paged KV prefix.

    q: (B,T,H,D) chunk queries; pages: (N,bs,KV,D); block_tables:
    (B,nb) i32; ctx_lens: (B,) i32 prior-context lengths (pages already
    hold the chunk's K/V at ``ctx_lens .. ctx_lens+T-1``).
    ``use_pallas=False`` gathers the contiguous view in pure jnp (the
    path the model's chunked prefill lowers on CPU).
    """
    if not use_pallas:
        return _ref.chunked_prefill_attention_ref(q, k_pages, v_pages,
                                                  block_tables, ctx_lens)
    interp = _default_interpret() if interpret is None else interpret
    return _cpa.chunked_prefill_attention(q, k_pages, v_pages,
                                          block_tables, ctx_lens,
                                          interpret=interp)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ragged_chunked_prefill(q, k_new, v_new, k_pages, v_pages, block_tables,
                           meta, *, use_pallas: bool = True,
                           interpret: Optional[bool] = None):
    """Fused ragged chunked prefill: ALL scheduled chunks in one launch.

    q: (C,T_pad,H,D) per-chunk padded queries; k_new/v_new:
    (C,T_pad,KV,D) each chunk's fresh K/V; pages: (N,bs,KV,D);
    block_tables: (C,nb) i32; meta: (C,4) i32 rows
    ``[slot, ctx_len, chunk_len, q_offset]``.  Returns (out,
    new_k_pages, new_v_pages) — the chunk K/V scatter is fused in
    (aliased page outputs in the kernel; a drop-mode jnp scatter in the
    ``use_pallas=False`` oracle path).  Output rows past ``chunk_len``
    are undefined padding.
    """
    if not use_pallas:
        return _ref.ragged_chunked_prefill_ref(q, k_new, v_new, k_pages,
                                               v_pages, block_tables, meta)
    interp = _default_interpret() if interpret is None else interpret
    return _rcp.ragged_chunked_prefill(q, k_new, v_new, k_pages, v_pages,
                                       block_tables, meta,
                                       interpret=interp)


@functools.partial(jax.jit, static_argnames=(
    "eps", "block_rows", "use_pallas", "interpret"))
def rms_norm(x, weight, *, eps: float = 1e-6, block_rows: int = 256,
             use_pallas: bool = True, interpret: Optional[bool] = None):
    if not use_pallas:
        return jlayers.rms_norm(x, weight, eps)
    interp = _default_interpret() if interpret is None else interpret
    return _rn.rms_norm(x, weight, eps=eps, block_rows=block_rows,
                        interpret=interp)
