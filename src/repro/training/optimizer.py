"""Optimizers in pure JAX (no optax offline): AdamW and Adafactor.

Both follow the (init, update) functional convention:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

AdamW keeps two f32 moments per parameter (3x params memory in f32) —
fine up to ~10B-scale models on a pod.  Adafactor factors the second
moment of every matrix into row/col statistics (O(n+m) instead of O(nm))
and keeps no first moment — this is what the 1T-parameter Kimi-K2 config
uses (see configs/registry + launch/train.py: family "moe" defaults to
adafactor).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p + u.astype(p.dtype)).astype(p.dtype),
        params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def one(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * jnp.square(g)
            upd = -lr * ((mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return upd, mu, nu

        out = jax.tree.map(one, grads, state["mu"], state["nu"], params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), factored second moment, no momentum
# ---------------------------------------------------------------------------


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    def _factored(shape) -> bool:
        # canonical rule: factor only when both trailing dims are large —
        # keeps stacked-per-layer norm vectors (L, D) un-factored instead
        # of nonsensically factoring across the layer axis.
        return (len(shape) >= 2
                and min(shape[-2:]) >= min_dim_size_to_factor)

    def init(params):
        def one(p):
            if _factored(p.shape):
                row = jnp.zeros(p.shape[:-1], jnp.float32)
                col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return {"r": row, "c": col}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"step": jnp.zeros((), jnp.int32),
                "stats": jax.tree.map(one, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = jnp.maximum(step.astype(jnp.float32), 1.0)
        beta = 1.0 - t ** (-decay)

        def one(g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(g.shape):
                r = beta * s["r"] + (1 - beta) * g2.mean(axis=-1)
                c = beta * s["c"] + (1 - beta) * g2.mean(axis=-2)
                rc = r / jnp.maximum(
                    r.mean(axis=-1, keepdims=True), 1e-30)
                v = rc[..., None] * c[..., None, :]
                new_s = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                new_s = {"v": v}
            u = g / jnp.sqrt(jnp.maximum(v, 1e-30))
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            return -lr * u, new_s

        out = jax.tree.map(one, grads, state["stats"])
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        stats = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"step": step, "stats": stats}

    return Optimizer(init, update)


def make_optimizer(name: str, lr: float) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr)
    if name == "adafactor":
        return adafactor(lr=lr)
    raise ValueError(name)


def default_optimizer_name(cfg) -> str:
    """Per-arch default: factored states for >=100B-param models."""
    return "adafactor" if cfg.param_count() > 50e9 else "adamw"
