#!/usr/bin/env python
"""Render a serve/simulation trace (repro.obs JSONL) in the terminal.

    PYTHONPATH=src python scripts/trace_report.py trace.jsonl
    PYTHONPATH=src python scripts/trace_report.py trace.jsonl \
        --perfetto trace.json          # + Chrome trace_event export
    PYTHONPATH=src python scripts/trace_report.py trace.jsonl --width 100

Reads the JSONL sink written by ``Observability`` /
``TraceRecorder.to_jsonl`` (engine or simulator — same schema), then
prints:

  * a per-request WATERFALL — one row per request, phases drawn over
    the trace's time extent (``.`` queued, ``=`` prefill/admission,
    ``#`` decode, ``R`` rejection retries marker);
  * a PERCENTILE TABLE — TTFT / inter-token latency / queue wait
    reconstructed from the event stream via ``repro.obs.timelines``
    (the same reconstruction the acceptance test checks against the
    engine's result dict) plus per-request chunk counts;
  * a span/counter summary when the trace carries engine-side spans.

Exits non-zero on schema violations (unknown event kind — the typed
vocabulary is ``repro.obs.EVENT_KINDS``), so CI can smoke-check any
committed trace.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs import EVENT_KINDS, TraceRecorder, timelines
from repro.obs.metrics import Histogram


def validate(rec: TraceRecorder) -> list:
    """Schema check: every event kind must be in the typed vocabulary."""
    return sorted({e.kind for e in rec.events} - EVENT_KINDS)


def waterfall(rec: TraceRecorder, width: int = 72) -> str:
    tls = timelines(rec)
    if not tls:
        return "(no request events)"
    t0 = min(t.arrival for t in tls.values() if t.arrival >= 0)
    t1 = max(max(t.complete_ts, t.first_token_ts, t.admit_ts,
                 t.arrival) for t in tls.values())
    span = max(t1 - t0, 1e-9)

    def col(ts: float) -> int:
        return min(width - 1, max(0, int((ts - t0) / span * (width - 1))))

    lines = [f"waterfall  t0={t0:.3f}s  extent={span:.3f}s  "
             f"(. queued  = prefill  # decode  R rejected-retry)"]
    for tid in sorted(tls):
        t = tls[tid]
        row = [" "] * width
        anchors = [x for x in (t.arrival, t.admit_ts, t.first_token_ts,
                               t.complete_ts) if x >= 0]
        if not anchors:
            continue
        end = max(anchors)
        for marker, a, b in (
                (".", t.arrival, t.admit_ts),
                ("=", t.admit_ts, t.first_token_ts),
                ("#", t.first_token_ts, t.complete_ts)):
            if a < 0 or b < 0:
                continue
            for c in range(col(a), col(b) + 1):
                row[c] = marker
        if t.rejected:
            row[col(t.arrival if t.arrival >= 0 else end)] = "R"
        extra = f" chunks={t.chunks}" if t.chunks else ""
        rej = f" rejected×{t.rejected}" if t.rejected else ""
        lines.append(f"req {tid:>4} |{''.join(row)}|{extra}{rej}")
    return "\n".join(lines)


def percentile_table(rec: TraceRecorder) -> str:
    tls = timelines(rec)
    hists = {"ttft_s": Histogram(), "itl_s": Histogram(),
             "queue_wait_s": Histogram()}
    for t in tls.values():
        if t.ttft is not None:
            hists["ttft_s"].record(t.ttft)
        if t.queue_wait is not None:
            hists["queue_wait_s"].record(t.queue_wait)
        for itl in t.itls:
            hists["itl_s"].record(itl)
    head = (f"{'metric':<14} {'count':>7} {'mean':>10} {'p50':>10} "
            f"{'p90':>10} {'p99':>10} {'max':>10}")
    lines = [head, "-" * len(head)]
    for name, h in hists.items():
        if h.count == 0:
            lines.append(f"{name:<14} {0:>7}")
            continue
        lines.append(
            f"{name:<14} {h.count:>7} {h.mean:>10.4f} "
            f"{h.quantile(0.50):>10.4f} {h.quantile(0.90):>10.4f} "
            f"{h.quantile(0.99):>10.4f} {h.max:>10.4f}")
    return "\n".join(lines)


def span_summary(rec: TraceRecorder) -> str:
    if not rec.spans and not rec.counters:
        return ""
    by_name: dict = {}
    for s in rec.spans:
        h = by_name.setdefault(s.name, Histogram())
        h.record(s.dur)
    lines = ["", f"{'span':<16} {'count':>7} {'total_s':>10} "
                 f"{'mean_s':>10} {'p99_s':>10}"]
    for name in sorted(by_name):
        h = by_name[name]
        lines.append(f"{name:<16} {h.count:>7} {h.total:>10.4f} "
                     f"{h.mean:>10.6f} {h.quantile(0.99):>10.6f}")
    if rec.counters:
        names = sorted({n for n, _, _ in rec.counters})
        lines.append(f"counter tracks: {', '.join(names)} "
                     f"({len(rec.counters)} samples)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="JSONL trace (TraceRecorder.to_jsonl)")
    ap.add_argument("--width", type=int, default=72,
                    help="waterfall width in columns")
    ap.add_argument("--perfetto", metavar="OUT.json", default=None,
                    help="also export Chrome trace_event JSON "
                         "(open in ui.perfetto.dev)")
    ap.add_argument("--json", action="store_true",
                    help="emit the percentile table as JSON instead "
                         "of text (machine-readable smoke checks)")
    args = ap.parse_args(argv)

    rec = TraceRecorder.load_jsonl(args.trace)
    unknown = validate(rec)
    if unknown:
        print(f"schema violation: unknown event kinds {unknown} "
              f"(expected subset of {sorted(EVENT_KINDS)})",
              file=sys.stderr)
        return 1
    if not rec.events and not rec.spans:
        print("empty trace", file=sys.stderr)
        return 1

    if args.json:
        tls = timelines(rec)
        ttft = Histogram()
        for t in tls.values():
            if t.ttft is not None:
                ttft.record(t.ttft)
        print(json.dumps({
            "events": len(rec.events), "spans": len(rec.spans),
            "requests": len(tls),
            "snapshots": sum(1 for e in rec.events
                             if e.kind == "snapshot"),
            "routes": sum(1 for e in rec.events
                          if e.kind == "route"),
            "replicas": sorted({e.fields["replica"]
                                for e in rec.events
                                if "replica" in e.fields}),
            "ttft_p50": ttft.quantile(0.50),
            "ttft_p99": ttft.quantile(0.99)}))
    else:
        print(f"{args.trace}: {len(rec.events)} events, "
              f"{len(rec.spans)} spans, {len(rec.counters)} counter "
              f"samples, {len(rec.task_ids())} requests")
        print()
        print(waterfall(rec, width=args.width))
        print()
        print(percentile_table(rec))
        s = span_summary(rec)
        if s:
            print(s)
    if args.perfetto:
        rec.export_perfetto(args.perfetto)
        print(f"perfetto export: {args.perfetto} "
              f"(open in ui.perfetto.dev)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
