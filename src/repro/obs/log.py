"""Rate-limited warnings with countable fallback events.

The serving stack degrades silently in two places: ``use_pallas=None``
auto-detection falls back to the jnp kernel paths off-TPU, and AOT
warmup failure degrades to jit-on-first-call.  Both used to be ad-hoc
one-shot ``logger.warning`` patterns — visible once in stderr, then
gone, and never countable.  This module centralizes the pattern:

  * each degradation site calls ``warn_once(logger, key, msg, ...)``;
  * the FIRST occurrence per key logs at WARNING; repeats within
    ``min_interval_s`` are suppressed (rate limit, not one-shot — a
    long-lived process resurfaces a persistent fallback periodically);
  * EVERY occurrence increments the key's counter, so
    ``fallback_count()`` deltas make silent fallbacks countable in
    serve results (``ServingEngine._result["fallback_events"]``)
    instead of only greppable in stderr;
  * ``reset(key)`` re-arms logging without clearing counts — what
    ``generate.reset_fallback_warning`` maps onto, keeping the
    per-serve re-arm semantics of the old pattern.

A module-level singleton (``FALLBACKS``) backs the serving stack; unit
tests may construct private ``RateLimitedLogger`` instances.
"""

from __future__ import annotations

import time
from typing import Dict, Optional


class RateLimitedLogger:
    """Per-key rate-limited warning emitter with occurrence counters."""

    def __init__(self, min_interval_s: float = 300.0):
        self.min_interval_s = min_interval_s
        self._last_emit: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}
        self.suppressed: Dict[str, int] = {}

    # ------------------------------------------------------------------
    def warn(self, logger, key: str, msg: str, *args) -> bool:
        """Count the occurrence; emit at WARNING unless the key logged
        within ``min_interval_s``.  Returns True when emitted."""
        self.counts[key] = self.counts.get(key, 0) + 1
        now = time.monotonic()
        last = self._last_emit.get(key)
        if last is not None and now - last < self.min_interval_s:
            self.suppressed[key] = self.suppressed.get(key, 0) + 1
            return False
        self._last_emit[key] = now
        logger.warning(msg, *args)
        return True

    # ------------------------------------------------------------------
    def reset(self, key: Optional[str] = None) -> None:
        """Re-arm emission (counts are NOT cleared — they are the
        observable record).  ``None`` re-arms every key."""
        if key is None:
            self._last_emit.clear()
        else:
            self._last_emit.pop(key, None)

    def count(self, key: Optional[str] = None) -> int:
        if key is not None:
            return self.counts.get(key, 0)
        return sum(self.counts.values())


#: process-wide fallback ledger for the serving stack.  Keys in use:
#:   "jnp-fallback"  — use_pallas auto-detection fell back off-TPU
#:   "aot-warmup"    — AOT warmup failed; degraded to jit-on-first-call
FALLBACKS = RateLimitedLogger()


def warn_once(logger, key: str, msg: str, *args) -> bool:
    """Module-level convenience over the shared ``FALLBACKS`` ledger."""
    return FALLBACKS.warn(logger, key, msg, *args)


def fallback_count() -> int:
    """Total degradation events so far (all keys) — serve results report
    deltas of this."""
    return FALLBACKS.count()
