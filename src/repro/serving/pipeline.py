"""Async host pipeline: the detokenize/bookkeeping completion worker.

The continuous-decode loops used to serialize host scheduling with
device compute — dispatch one decode step, ``block_until_ready`` on the
scheduler thread, read back, and only then schedule the next iteration.
``CompletionWorker`` moves the blocking readback (device sync + the
device→host copy, i.e. the "detokenize" stage of a production server)
onto a daemon thread fed by a submit queue, so the scheduler thread is
free while the device works; combined with the N-step decode windows
(``model.decode_steps*``) this is the engine's async host pipeline.

Determinism contract: the worker performs NO scheduling — it only
syncs and converts arrays.  Results are collected strictly FIFO, and
the serve loops consume a window's completion BEFORE making any
eviction/admission decision that depends on it ("in arrears"
bookkeeping), so completion order, admission decisions and every parity
counter are identical to the synchronous loop — the engine-vs-sim
parity tests pin this down at N ∈ {1, 2, 4}.

The one pipelining the worker deliberately does NOT do is speculative
next-window dispatch before the previous window's readback: that would
stretch the eviction lag from N-1 to 2N-1 steps and break the N=1
bit-parity default, for a latency win the multi-step window already
captures.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Optional, Tuple

import numpy as np

import jax


class CompletionWorker:
    """Daemon thread draining device completions off the serve loop.

    ``submit(arrays, t0)`` enqueues an in-flight device result with its
    launch timestamp; the worker blocks until the arrays are ready,
    converts them to host numpy, and queues ``(host, dt)`` where ``dt``
    is the launch→ready wall-clock delta (what the serve loop charges
    to its virtual clock).  ``collect()`` returns results strictly in
    submission order; worker-side exceptions re-raise there, so device
    failures surface on the scheduler thread at the consume point.

    When a ``MetricsRegistry`` is supplied, each ``collect()`` records
    how long the scheduler thread actually blocked waiting on the
    worker into the ``pipeline.collect_wait_s`` histogram — near-zero
    waits mean the pipeline overlapped host work with device compute;
    waits tracking the device dt mean the loop is device-bound.
    """

    def __init__(self, name: str = "completion-worker", metrics=None):
        self._in: "queue.Queue" = queue.Queue()
        self._out: "queue.Queue" = queue.Queue()
        self._closed = False
        self._wait_hist = (metrics.histogram("pipeline.collect_wait_s")
                           if metrics is not None else None)
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._thread.start()

    # -- worker side ---------------------------------------------------
    def _run(self) -> None:
        while True:
            item = self._in.get()
            if item is None:                   # close() sentinel
                return
            arrays, t0 = item
            try:
                host = jax.tree.map(
                    lambda a: np.asarray(jax.block_until_ready(a)),
                    arrays)
                self._out.put((host, time.perf_counter() - t0, None))
            except BaseException as exc:       # re-raised at collect()
                self._out.put((None, time.perf_counter() - t0, exc))

    # -- scheduler side ------------------------------------------------
    def submit(self, arrays, t0: float) -> None:
        """Hand an in-flight device result (array or pytree) plus its
        launch timestamp to the worker."""
        self._in.put((arrays, t0))

    def collect(self) -> Tuple[object, float]:
        """Block for the OLDEST submitted result; returns (host, dt).
        Raises whatever the readback raised on the worker thread."""
        if self._wait_hist is not None:
            t0 = time.perf_counter()
            host, dt, exc = self._out.get()
            self._wait_hist.record(time.perf_counter() - t0)
        else:
            host, dt, exc = self._out.get()
        if exc is not None:
            raise exc
        return host, dt

    def wait_snapshot(self) -> dict:
        """Snapshot of the collect-wait histogram so far ({} when no
        registry was supplied) — the pipeline's contribution to the
        engine's health ``snapshot`` events (a ``wall`` field: purely
        wall-clock, excluded from the engine-vs-sim parity view)."""
        return (self._wait_hist.snapshot()
                if self._wait_hist is not None else {})

    def close(self, timeout: Optional[float] = 5.0) -> None:
        """Stop the worker and join its thread.  Idempotent — the
        serve() teardown path may reach an already-closed worker when
        an engine exception unwinds mid-window."""
        if self._closed:
            return
        self._closed = True
        self._in.put(None)
        self._thread.join(timeout=timeout)

    def __enter__(self) -> "CompletionWorker":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
