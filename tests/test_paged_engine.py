"""Paged KV-cache engine coverage.

Acceptance properties of the kvcache subsystem (ISSUE 2):

  * token parity — with the same slot count and an ample block budget,
    ``kv="paged"`` reproduces the contiguous continuous engine's output
    TOKEN FOR TOKEN (the paged gather view is bit-identical to the
    contiguous layout; masked tails contribute exp(-inf) == 0 exactly);
  * no leaks — after a full ``serve()`` every block is back on the free
    list;
  * engine-vs-sim parity extends to memory: with a tight block budget
    the engine's admission gate and the simulator's block-budget model
    make identical decisions (same completion order, same rejection
    count, same utilization trace);
  * capacity — at an equal KV-memory budget, paging admits strictly
    more concurrent sequences than the contiguous cache.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import datagen, personas, priority as prio
from repro.core import scheduler as sched, simulator
from repro.kvcache import paged as paged_lib
from repro.models import model as model_lib, transformer
from repro.serving.engine import Request, ServingEngine

SLOTS = 3
MAX_NEW = 6
BUCKET = 8
CAPS = [2, 6, 1, 4, 6, 2, 3, 5, 1, 6, 2, 4]


def _persona(batch_size=SLOTS):
    return dataclasses.replace(personas.get_persona("bart"),
                               batch_size=batch_size)


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke_config("starcoder2-3b")
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    corpus = datagen.generate_corpus(
        datagen.VARIANCE_MIXES["normal"], 64, seed=0)
    train, test = datagen.train_test_split(corpus, train_frac=0.5)
    persona = _persona()
    profile = sched.offline_profile(train, persona, epochs=15)
    return cfg, params, persona, profile, test


def _requests(test, caps):
    return [Request(text=t.text, arrival=0.0, task_id=i,
                    max_new_tokens=c)
            for i, (t, c) in enumerate(zip(test, caps))]


def _sim_tasks(test, caps, profile, persona, xi=2.0):
    out = []
    for i, (t, c) in enumerate(zip(test, caps)):
        u = profile.predictor.score(t.text)
        d = prio.priority_point(0.0, len(t.text.split()), persona.phi,
                                None, xi=xi)
        out.append(prio.SimTask(
            task=Request(text=t.text, arrival=0.0, task_id=i),
            u=float(max(u, 0.0)), r=0.0, d=d,
            input_len=float(len(t.text.split())), true_out_len=int(c)))
    return out


def _engine(setup, policy_name="fifo", **kw):
    cfg, params, persona, profile, _ = setup
    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    return ServingEngine(
        params, cfg, sched.POLICIES[policy_name](persona, pcfg), profile,
        input_bucket=BUCKET, max_new_tokens=MAX_NEW, mode="continuous",
        eos_id=-1, **kw)


def test_paged_matches_contiguous_token_for_token(setup):
    """Same slots, ample blocks: identical scheduling AND identical
    greedy tokens, request by request."""
    _, _, _, _, test = setup
    res = {}
    for kv in ("contiguous", "paged"):
        res[kv] = _engine(setup, kv=kv, kv_block_size=4).serve(
            _requests(test, CAPS))
    assert (res["paged"]["completion_order"]
            == res["contiguous"]["completion_order"])
    cont = {t.task.task_id: t.task for t in res["contiguous"]["tasks"]}
    pagd = {t.task.task_id: t.task for t in res["paged"]["tasks"]}
    for i, c in enumerate(CAPS):
        assert pagd[i].out_len == cont[i].out_len == c
        assert pagd[i].out_tokens == cont[i].out_tokens
    # the paged pool holds the same live tokens in fewer reserved
    # blocks: its utilization peak must come in strictly under the
    # contiguous engine's all-slots-busy 1.0
    assert res["paged"]["kv_util_peak"] < res["contiguous"]["kv_util_peak"]


def test_no_block_leaks_after_full_serve(setup):
    _, _, _, _, test = setup
    eng = _engine(setup, kv="paged", kv_block_size=4)
    res = eng.serve(_requests(test, CAPS))
    assert res["n_tasks"] == len(CAPS)
    eng.allocator.check_no_leaks()
    assert eng.allocator.num_free == eng.kv_num_blocks
    # memory metrics are reported
    assert res["kv"]["kind"] == "paged"
    assert 0.0 < res["kv_util_mean"] <= res["kv_util_peak"] <= 1.0
    assert res["rejected_for_memory"] == 0          # ample default budget


@pytest.mark.parametrize("policy_name", ["fifo", "rt-lm"])
def test_engine_vs_sim_parity_block_budget(setup, policy_name):
    """Tight budget (forces rejections): the engine's reservation gate
    and the simulator's block-budget model decide identically."""
    cfg, params, persona, profile, test = setup
    bs, nb, slots = 4, 7, 4      # worst case ceil((8+5)/4)=4 of 7 blocks
    eng = _engine(setup, policy_name, kv="paged", num_slots=slots,
                  kv_block_size=bs, kv_num_blocks=nb)
    res = eng.serve(_requests(test, CAPS))
    eng.allocator.check_no_leaks()
    assert res["rejected_for_memory"] > 0            # budget actually binds

    pcfg = dataclasses.replace(profile.policy_config(), tau=1e18)
    sim = simulator.simulate_continuous(
        _sim_tasks(test, CAPS, profile, persona),
        sched.POLICIES[policy_name](persona, pcfg),
        num_slots=slots, kv_block_size=bs, kv_num_blocks=nb,
        prompt_len=BUCKET)
    assert res["completion_order"] == [t.task.task_id for t in sim.tasks]
    assert res["rejected_for_memory"] == sim.kv_rejected
    np.testing.assert_allclose(res["kv_util_peak"], sim.kv_util_peak)
    np.testing.assert_allclose(res["kv_util_mean"], sim.kv_util_mean)


def test_paged_admits_more_concurrency_at_equal_budget():
    """Simulator form of the capacity acceptance gate: same KV-token
    budget, heterogeneous outputs — the block-table cache runs strictly
    more concurrent sequences than C contiguous slots (the real-engine
    version is benchmarks/continuous_vs_batch.py::run_paged)."""
    persona = _persona(batch_size=8)
    rng = np.random.default_rng(0)
    n = 96
    caps = np.where(rng.random(n) < 0.25, 48, 4).astype(int)
    arrivals = np.sort(rng.uniform(0.0, 0.5, n))

    def tasks():
        return [prio.SimTask(task=i, u=5.0, r=float(r), d=float(r) + 4.0,
                             input_len=5.0, true_out_len=int(c))
                for i, (c, r) in enumerate(zip(caps, arrivals))]

    pcfg = sched.PolicyConfig(u_scale=30.0, tau=1e18)
    bucket, max_new, bs = 8, 48, 16
    max_len = bucket + max_new + 8
    budget_blocks = paged_lib.default_num_blocks(persona.batch_size,
                                                 max_len, bs)
    cont = simulator.run_policy(tasks(), "fifo", persona, pcfg,
                                mode="continuous")
    paged = simulator.run_policy(tasks(), "fifo", persona, pcfg,
                                 mode="continuous",
                                 num_slots=3 * persona.batch_size,
                                 kv_block_size=bs,
                                 kv_num_blocks=budget_blocks,
                                 prompt_len=bucket)
    assert cont.peak_concurrency == persona.batch_size
    assert paged.peak_concurrency > cont.peak_concurrency
    assert paged.throughput_per_min > cont.throughput_per_min


def test_paged_validation():
    cfg = configs.get_smoke_config("starcoder2-3b")
    persona = _persona()
    pcfg = sched.PolicyConfig()
    policy = sched.POLICIES["fifo"](persona, pcfg)
    with pytest.raises(ValueError, match="continuous"):
        ServingEngine(None, cfg, policy, None, mode="batch", kv="paged")
    with pytest.raises(ValueError, match="deadlock"):
        ServingEngine(None, cfg, policy, None, mode="continuous",
                      kv="paged", kv_block_size=4, kv_num_blocks=2)
    # paging needs full attention / no recurrent state
    ssm_cfg = configs.get_smoke_config("mamba2-1.3b")
    with pytest.raises(NotImplementedError):
        transformer.init_paged_cache(ssm_cfg, 2, 8, 4)
    hyb_cfg = configs.get_smoke_config("recurrentgemma-9b")
    with pytest.raises(NotImplementedError):
        ServingEngine(None, hyb_cfg, policy, None, mode="continuous",
                      kv="paged")
