"""Trip-count-aware HLO cost model vs known programs."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_plain_matmul_flops():
    M, K, N = 64, 128, 32
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, N), jnp.float32))
    cost = hlo_cost.module_cost(c.as_text())
    assert cost.flops == pytest.approx(2 * M * K * N, rel=0.01)


def test_scan_trip_count_multiplies():
    L = 7

    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    cost = hlo_cost.module_cost(c.as_text())
    assert cost.flops == pytest.approx(L * 2 * 64 ** 3, rel=0.01)


def test_nested_scan():
    def f(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    cost = hlo_cost.module_cost(c.as_text())
    assert cost.flops == pytest.approx(15 * 2 * 32 ** 3, rel=0.01)


def test_cost_analysis_undercounts_scans_motivation():
    """Documents WHY hlo_cost exists: XLA counts while bodies once."""
    L = 9

    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    raw = c.cost_analysis()
    if isinstance(raw, list):
        raw = raw[0]
    assert raw["flops"] < 0.5 * L * 2 * 64 ** 3


def test_traffic_nonzero_and_finite():
    c = _compile(lambda a: jnp.tanh(a) @ a,
                 jax.ShapeDtypeStruct((128, 128), jnp.float32))
    cost = hlo_cost.module_cost(c.as_text())
    assert 0 < cost.traffic_bytes < 1e9
